"""Far-memory parameter streaming — the paper's scenario end-to-end.

A model whose weights do NOT fit in the near tier (think llama4-maverick
400B vs one pod's HBM) keeps layer weights in far memory and streams
them through the AMU with ``prefetch_depth`` layers in flight, while the
compute consumes the current layer — the paper's stream pattern plus its
bandwidth-aggregation argument, measurable here via the simulated clock:

  blocking  : t_total ~= L * (t_fetch + t_compute)
  AMU depth2: t_total ~= t_fetch + L * max(t_fetch, t_compute)

Run:  PYTHONPATH=src python examples/far_memory_stream.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core import (AMU, AccessConfig, FarMemoryTier, QoS, SimBackend,
                        StreamingPrefetcher)

L = 16                        # layers
BYTES_PER_LAYER = 64 << 20    # 64 MiB per layer block
FAR_BW = 6.4e9                # host->device link (PCIe-ish)
FAR_LAT = 5e-6                # far-memory latency (paper's upper band)
T_COMPUTE = 8e-3              # per-layer compute time


def run(depth: int):
    backend = SimBackend(base_latency=FAR_LAT, bandwidth=FAR_BW)
    amu = AMU(backend=backend, max_outstanding=max(2, depth + 1),
              default_config=AccessConfig(granularity_bytes=4 << 20,
                                          qos=QoS.BULK))
    tier = FarMemoryTier(amu)
    rng = np.random.default_rng(0)
    for i in range(L):
        tier.offload(i, np.zeros(BYTES_PER_LAYER // 4, np.float32),
                     async_=False)
    backend.now = 0.0

    if depth == 0:            # blocking load/store: fetch, then compute
        t = 0.0
        for i in range(L):
            rid = tier.prefetch(i)
            tier.get(i)                      # blocks until landed
            backend.advance(T_COMPUTE)       # compute with link idle
            tier.evict(i)
        return backend.now

    pf = StreamingPrefetcher(tier, list(range(L)), depth=depth)
    pf.start()
    for i in range(L):
        pf.step()                            # waits only if not landed yet
        backend.advance(T_COMPUTE)           # compute overlaps next fetch
        tier.evict(i)
    return backend.now


def main():
    t_fetch = BYTES_PER_LAYER / FAR_BW
    print(f"[stream] {L} layers x {BYTES_PER_LAYER >> 20} MiB, "
          f"t_fetch={t_fetch*1e3:.1f} ms, t_compute={T_COMPUTE*1e3:.1f} ms")
    t_block = run(0)
    for depth in (1, 2, 4):
        t = run(depth)
        print(f"[stream] depth={depth}: {t*1e3:7.1f} ms  "
              f"(blocking {t_block*1e3:7.1f} ms, "
              f"speedup {t_block/t:4.2f}x)")
    t2 = run(2)
    # with >=2 requests in flight the (multi-channel) far link overlaps
    # fetches too, so the floor is compute-bound: first fetch + L computes
    floor = t_fetch + L * T_COMPUTE
    print(f"[stream] depth=2 vs compute-bound floor {floor*1e3:.1f} ms: "
          f"{t2/floor:.2f}x (1.00 = perfect overlap)")
    assert t2 < t_block * 0.65, "AMU streaming must beat blocking by >1.5x"


if __name__ == "__main__":
    main()

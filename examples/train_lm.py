"""End-to-end training example: ~100M-param LM, few hundred steps.

Uses the full stack: AMU-prefetched data pipeline, pjit train step
(remat + grad accumulation), AdamW, async atomic checkpoints, straggler
detection — on a ~115M-parameter phi4-family model that fits CPU.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]

The synthetic corpus is a learnable affine-recurrence task, so the loss
drops from ~ln(V) toward ~0 as the model memorises the transition pool —
a real end-to-end signal, not noise.
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import dataclasses

from repro.configs import get_smoke
from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M model: scale the phi4 smoke config up
    base = get_smoke("phi4-mini-3.8b")
    cfg100m = dataclasses.replace(
        base, name="phi4-100m", num_layers=12, d_model=640, num_heads=8,
        num_kv_heads=4, head_dim=80, d_ff=2560, vocab_size=32000)
    print(f"[example] {cfg100m.name}: ~{cfg100m.param_count()/1e6:.0f}M params")

    # register it temporarily so the CLI path stays the single entry point
    import repro.configs as C
    mod = type(sys)("_tmp_cfg")
    mod.CONFIG = cfg100m
    mod.SMOKE = cfg100m
    C._ARCH_MODULES["phi4-100m"] = "_tmp_cfg"
    sys.modules["repro.configs._tmp_cfg"] = mod

    losses = train_mod.main([
        "--arch", "phi4-100m", "--smoke",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "128",
        "--lr", "1e-3",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        "--log-every", "20",
    ])
    assert losses[-1] < losses[0], "loss should decrease"
    print(f"[example] final loss {losses[-1]:.3f} "
          f"(from {losses[0]:.3f}) — checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()

"""Serving example: continuous batching with the event-driven scheduler.

Submits a burst of mixed-length requests against a small dense model and
shows the engine admitting new requests into slots the moment others
finish (no drain barrier), with finished sequences' KV parked in the
host far tier through the AMU.

Run:  PYTHONPATH=src python examples/serve_engine.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_smoke
from repro.models import init_params
from repro.serve.engine import Engine


def main():
    cfg = get_smoke("mistral-nemo-12b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=4, max_len=96,
                 prefill_buckets=(16, 32, 64), offload_finished=True)

    rng = np.random.default_rng(7)
    n_requests = 10
    for i in range(n_requests):
        plen = int(rng.integers(4, 24))
        new = int(rng.integers(4, 12))
        eng.submit(rng.integers(0, cfg.vocab_size, plen), max_new_tokens=new)
    out = eng.run()

    total = sum(len(v) for v in out.values())
    occ = total / max(1, eng.stats["steps"] * eng.max_batch)
    print(f"[serve] {len(out)} requests -> {total} tokens in "
          f"{eng.stats['steps']} decode steps "
          f"(occupancy {occ:.2f}; 4 slots, mixed depths)")
    print(f"[serve] prefills {eng.stats['prefills']} "
          f"(bucketed: {sorted(set(k[0] for k in eng._prefills))})")
    print(f"[serve] far-tier AMU ops: {dict(eng.kv_tier.tier.amu.stats)}")
    for rid in sorted(out)[:3]:
        print(f"  request {rid}: {out[rid]}")
    assert len(out) == n_requests


if __name__ == "__main__":
    main()

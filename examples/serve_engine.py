"""Serving example: continuous batching with the event-driven scheduler
over an *oversubscribed* device page pool.

Submits a burst of mixed-length requests against a small dense model and
shows the engine admitting new requests into slots the moment others
finish (no drain barrier), KV paged over a device pool smaller than the
aggregate demand — cold pages park in the far tier via BULK astore and
come back hot-tail-first via LATENCY aload — with finished sequences'
KV parked page-by-page in the same host far tier through the AMU.

Run:  PYTHONPATH=src python examples/serve_engine.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_smoke
from repro.models import init_params
from repro.serve import (ChunkingConfig, Engine, EngineConfig,
                         PagingConfig)


def main():
    cfg = get_smoke("mistral-nemo-12b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    # 4 slots x 12 pages would want 48 device pages; give it 12 so the
    # engine must oversubscribe: preempt cold pages, prefetch on resume.
    # chunk_tokens=8: admission is the chunk queue — prompts prefill in
    # 8-token chunks fused with running decodes (no admission bubble).
    eng = Engine(cfg, params, EngineConfig(
        max_batch=4, max_len=96, prefill_buckets=(16, 32, 64),
        paging=PagingConfig(page_size=8, device_pages=12,
                            offload_finished=True),
        chunking=ChunkingConfig(chunk_tokens=8)))

    rng = np.random.default_rng(7)
    n_requests = 10
    for i in range(n_requests):
        plen = int(rng.integers(4, 24))
        new = int(rng.integers(4, 12))
        eng.submit(rng.integers(0, cfg.vocab_size, plen), max_new_tokens=new)
    out = eng.run()

    total = sum(len(v) for v in out.values())
    occ = total / max(1, eng.stats["steps"] * eng.max_batch)
    print(f"[serve] {len(out)} requests -> {total} tokens in "
          f"{eng.stats['steps']} decode steps "
          f"(occupancy {occ:.2f}; 4 slots, mixed depths)")
    print(f"[serve] chunked prefill: {eng.stats['chunks']} chunks over "
          f"{eng.stats['mixed_steps']} mixed steps "
          f"({eng.stats['prefills']} dense fallbacks)")
    print(f"[serve] page pool: {eng.page_pool.n_pages} pages x "
          f"{eng.page_size} tok, preemptions {eng.stats['preemptions']}, "
          f"resumes {eng.stats['resumes']}")
    print(f"[serve] pager ops: {dict(eng.pager.stats)}")
    print(f"[serve] far-tier AMU ops: {dict(eng.far_tier.amu.stats)}")
    for rid in sorted(out)[:3]:
        print(f"  request {rid}: {out[rid]}")
    assert len(out) == n_requests


if __name__ == "__main__":
    main()

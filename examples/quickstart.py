"""Quickstart: the AMU programming model in 80 lines.

Mirrors the paper's Listing 1 — issue aload, do other work, poll getfin,
consume from SPM — at both of this framework's levels:

  1. the *runtime* AMU (host <-> device far-memory tier),
  2. the *kernel* AMU (HBM -> VMEM DMA inside a Pallas matmul).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import (AMU, AccessConfig, FAILURE_CODE, QoS, SimBackend,
                        StreamPattern, granules)
from repro.kernels import matmul

# --------------------------------------------------------------------------
# 1. Listing-1 style: aload -> overlap other work -> getfin -> consume
# --------------------------------------------------------------------------
print("== runtime AMU (paper Listing 1) ==")
amu = AMU(backend=SimBackend(base_latency=3e-6, bandwidth=50e9),
          max_outstanding=64,
          default_config=AccessConfig(granularity_bytes=4096,
                                      qos=QoS.STANDARD))

far_data = [np.full(1024, i, np.float32) for i in range(8)]
rids = [amu.aload(x) for x in far_data]          # returns ids immediately
print(f"issued {len(rids)} aloads; outstanding={amu.outstanding}")

other_work = 0
done = []
while len(done) < len(rids):
    rid = amu.getfin()                            # never blocks
    if rid == FAILURE_CODE:
        other_work += 1                           # overlap useful work
        amu.backend.advance(1e-6)                 # (virtual clock here)
        continue
    done.append(rid)
print(f"all requests landed; did {other_work} units of work while waiting")
print(f"first landed buffer head: {amu.result(done[0])[:4]}")

# variable granularity: one pattern, two request counts
pat = StreamPattern(total_bytes=1 << 20)
print(f"1 MiB stream = {granules(pat, 512)} requests @512B "
      f"vs {granules(pat, 65536)} @64KiB  (variable granularity)")

# --------------------------------------------------------------------------
# 2. The same model inside a kernel: double-buffered DMA matmul
# --------------------------------------------------------------------------
print("\n== kernel AMU (Pallas, interpret mode on CPU) ==")
x = jnp.asarray(np.random.default_rng(0).standard_normal((256, 512)),
                jnp.float32)
w = jnp.asarray(np.random.default_rng(1).standard_normal((512, 256)),
                jnp.float32)
out = matmul(x, w, impl="interpret", bm=128, bk=128, bn=128)
ref = x @ w
print(f"amu_matmul max err vs jnp: {float(jnp.abs(out - ref).max()):.2e}")
print("kernel pipeline: aload tile k+2 while MXU consumes tile k "
      "(see src/repro/kernels/amu_matmul.py)")

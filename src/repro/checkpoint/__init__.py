"""repro.checkpoint"""
from repro.checkpoint.checkpoint import (save, restore, latest_step, all_steps, wait_pending, prune)
__all__ = ["save", "restore", "latest_step", "all_steps", "wait_pending", "prune"]

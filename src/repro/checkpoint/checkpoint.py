"""Sharded checkpointing: atomic, resumable, elastic.

Layout on disk::

    <dir>/step_<N>/
        manifest.json        # tree structure, shapes, dtypes, metadata
        shard_<i>.npz        # leaf groups (~512 MB per shard file)
    <dir>/LATEST             # atomic pointer (written last)

Properties needed at 1000-node scale, scaled down honestly:
  * **atomic**: writes go to ``step_<N>.tmp`` and are renamed only after
    fsync — a killed writer never corrupts the latest checkpoint,
  * **resumable**: ``latest_step`` + ``restore`` bring back params,
    optimizer state and data-pipeline step,
  * **elastic reshard**: values are stored unsharded (gathered); restore
    ``device_put``s onto whatever mesh/shardings the *new* topology
    defines, so restarting with a different DP width just works,
  * **async**: ``save(..., async_=True)`` stores through the AMU far
    tier (astore) and returns; ``wait_pending`` drains before the next
    save (checkpoint I/O hides behind training compute).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save", "restore", "latest_step", "all_steps", "wait_pending",
           "prune"]

_SHARD_BYTES = 512 * 1024 * 1024
_pending: List[threading.Thread] = []


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    return flat, treedef


def _plan_shards(flat: Dict[str, np.ndarray]) -> List[List[str]]:
    shards, cur, cur_bytes = [], [], 0
    for name, arr in flat.items():
        if cur and cur_bytes + arr.nbytes > _SHARD_BYTES:
            shards.append(cur)
            cur, cur_bytes = [], 0
        cur.append(name)
        cur_bytes += arr.nbytes
    if cur:
        shards.append(cur)
    return shards


def save(directory, step: int, tree, *, metadata: Optional[dict] = None,
         async_: bool = False) -> Path:
    """Write checkpoint for ``step``; returns its final path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"

    # gather to host before any thread handoff (donated buffers etc.)
    host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)

    def _write():
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat, treedef = _flatten(host_tree)
        shards = _plan_shards(flat)
        manifest = {
            "step": step,
            # tree structure comes from the caller's ``target`` at restore
            # (structures with NamedTuples don't proto-serialize); record
            # a human-readable summary instead.
            "treedef": str(jax.tree_util.tree_structure(host_tree)),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                           "shard": si}
                       for si, names in enumerate(shards)
                       for k, v in ((n, flat[n]) for n in names)},
            "n_shards": len(shards),
            "metadata": metadata or {},
        }
        for si, names in enumerate(shards):
            np.savez(tmp / f"shard_{si}.npz", **{n: flat[n] for n in names})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        os.sync()
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        latest = directory / "LATEST"
        tmp_latest = directory / "LATEST.tmp"
        tmp_latest.write_text(str(step))
        tmp_latest.rename(latest)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        _pending.append(t)
    else:
        _write()
    return final


def wait_pending() -> None:
    global _pending
    for t in _pending:
        t.join()
    _pending = []


def latest_step(directory) -> Optional[int]:
    p = Path(directory) / "LATEST"
    if not p.exists():
        return None
    try:
        return int(p.read_text().strip())
    except ValueError:
        return None


def all_steps(directory) -> List[int]:
    d = Path(directory)
    if not d.exists():
        return []
    out = []
    for c in d.iterdir():
        if c.is_dir() and c.name.startswith("step_") and \
                not c.name.endswith(".tmp"):
            out.append(int(c.name.split("_")[1]))
    return sorted(out)


def restore(directory, step: Optional[int] = None, *,
            target: Any = None, shardings: Any = None) -> Tuple[Any, dict]:
    """Load a checkpoint.  ``target`` (a matching pytree — contents
    ignored) supplies the tree structure; ``shardings`` (optional pytree
    of NamedSharding) places leaves onto the *current* mesh — this is
    where elastic rescale happens."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = directory / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    arrays: Dict[str, np.ndarray] = {}
    for si in range(manifest["n_shards"]):
        with np.load(path / f"shard_{si}.npz") as z:
            for k in z.files:
                arrays[k] = z[k]
    leaves = [arrays[f"leaf_{i}"] for i in range(len(arrays))]
    if target is not None:
        treedef = jax.tree_util.tree_structure(target)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
    else:
        tree = leaves
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest["metadata"]


def prune(directory, keep: int = 3) -> None:
    """Delete all but the newest ``keep`` checkpoints."""
    steps = all_steps(directory)
    for s in steps[:-keep]:
        shutil.rmtree(Path(directory) / f"step_{s:08d}", ignore_errors=True)

"""repro.runtime"""

"""Fault tolerance & elasticity: watchdog, retry, stragglers, rescale.

Everything here is topology-agnostic logic that a 1000-node deployment
would drive from its coordinator; on this single-process container it is
exercised by tests with simulated failures.

Components:
  * :class:`Heartbeat`       — per-step liveness; watchdog flags stalls,
  * :class:`StragglerDetector` — per-step timing outliers + mitigation
    decision (the AMU analogy holds: a straggling *node* is a
    long-latency request; the cure is the same — keep enough outstanding
    work that one slow element doesn't stall the pipeline),
  * :func:`run_with_retries` — step wrapper: on failure, restore the
    latest checkpoint and continue (bounded retries),
  * :func:`elastic_plan`     — after losing nodes, choose the best new
    (data, model) mesh from the survivors and describe the reshard.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

__all__ = ["Heartbeat", "StragglerDetector", "run_with_retries",
           "elastic_plan", "ElasticPlan", "StepFailure"]


class StepFailure(RuntimeError):
    """Raised by a training step that should trigger recovery."""


class Heartbeat:
    """Liveness tracking: ``beat()`` each step; ``stalled()`` if silent."""

    def __init__(self, timeout_s: float = 300.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self.clock = clock
        self.last_beat = clock()
        self.beats = 0

    def beat(self) -> None:
        self.last_beat = self.clock()
        self.beats += 1

    def stalled(self) -> bool:
        return (self.clock() - self.last_beat) > self.timeout_s


@dataclass
class StragglerReport:
    step: int
    duration: float
    median: float
    ratio: float


class StragglerDetector:
    """Flags steps slower than ``threshold`` x rolling median.

    At cluster scale the same detector runs per-host on collective wait
    times; the mitigation hook decides re-shard / eject / ignore.
    """

    def __init__(self, threshold: float = 2.0, window: int = 32,
                 min_samples: int = 5):
        self.threshold = threshold
        self.window = window
        self.min_samples = min_samples
        self.durations: List[float] = []
        self.reports: List[StragglerReport] = []
        self._step = 0

    def record(self, duration: float) -> Optional[StragglerReport]:
        self._step += 1
        history = self.durations[-self.window:]
        self.durations.append(duration)
        if len(history) < self.min_samples:
            return None
        med = sorted(history)[len(history) // 2]
        if med > 0 and duration > self.threshold * med:
            rep = StragglerReport(step=self._step, duration=duration,
                                  median=med, ratio=duration / med)
            self.reports.append(rep)
            return rep
        return None

    @property
    def straggler_fraction(self) -> float:
        return len(self.reports) / max(1, self._step)


def run_with_retries(
    step_fn: Callable[[Any], Any],
    state: Any,
    *,
    restore_fn: Callable[[], Any],
    checkpoint_fn: Optional[Callable[[Any], None]] = None,
    max_retries: int = 3,
    on_failure: Optional[Callable[[BaseException, int], None]] = None,
) -> Any:
    """Run one step with recovery: on exception, restore + retry.

    Mirrors the coordinator loop of a real deployment: the step function
    is pure (state in, state out), so recovery is restore-and-replay.
    """
    attempt = 0
    while True:
        try:
            return step_fn(state)
        except Exception as e:          # noqa: BLE001 — recovery boundary
            attempt += 1
            if on_failure is not None:
                on_failure(e, attempt)
            if attempt > max_retries:
                raise
            state = restore_fn()


@dataclass(frozen=True)
class ElasticPlan:
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    lost_devices: int
    batch_per_replica_change: float
    needs_reshard: bool
    note: str = ""


def elastic_plan(
    old_shape: Sequence[int],
    axes: Sequence[str],
    surviving_devices: int,
    *,
    keep_model_axis: bool = True,
) -> ElasticPlan:
    """Choose the new mesh after failures.

    Policy: the ``model`` axis carries intra-layer sharding whose reshape
    would re-layout every weight, so keep it; shrink the data axis to the
    largest size the survivors support.  (pod, data, model) meshes fold
    the pod axis into data first.
    """
    old_shape = tuple(old_shape)
    axes = tuple(axes)
    total_old = math.prod(old_shape)
    sizes = dict(zip(axes, old_shape))
    model = sizes.get("model", 1)
    if not keep_model_axis:
        model = 1
    if surviving_devices < model:
        raise ValueError(
            f"survivors ({surviving_devices}) cannot host the model axis "
            f"({model}); full re-plan required")
    new_data = surviving_devices // model
    # fold pods into data on shrink
    new_shape_map = {"data": new_data, "model": model}
    new_axes = tuple(a for a in axes if a in new_shape_map) or ("data", "model")
    new_shape = tuple(new_shape_map[a] for a in new_axes)
    used = new_data * model
    return ElasticPlan(
        old_shape=old_shape,
        new_shape=new_shape,
        axes=new_axes,
        lost_devices=total_old - surviving_devices,
        batch_per_replica_change=(sizes.get("data", 1)
                                  * sizes.get("pod", 1)) / max(1, new_data),
        needs_reshard=True,
        note=(f"dropping {surviving_devices - used} spare devices"
              if used != surviving_devices else ""),
    )

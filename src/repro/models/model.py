"""Composable model builder: one init/apply pair per architecture family.

Families (``cfg.family``):
  * ``dense`` / ``moe`` (and VLM backbones) — decoder-only transformer;
    MoE layers placed every ``cfg.moe_every`` layers, scanned in groups,
  * ``ssm``    — RWKV6 stack (attention-free),
  * ``hybrid`` — Mamba2 stack with a *shared* attention block every
    ``cfg.shared_attn_every`` layers (Zamba2),
  * ``encdec`` — encoder (bidirectional) + decoder (causal + cross-attn),
    with a stub frontend providing precomputed frame/patch embeddings.

All layer stacks are ``lax.scan`` over stacked params (compile time stays
flat in depth); remat is applied to the scan body per ``remat`` policy.
The LM loss streams over sequence chunks so full-vocab logits are never
materialised (vocabs here reach 256k).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import (attention_block, attn_init,
                                    chunked_attention, decode_attention_block,
                                    init_kv_cache, init_paged_kv_cache,
                                    paged_decode_attention_block,
                                    paged_prefill_block, paged_verify_block)
from repro.models.layers import (embed, embed_init, rms_norm, rms_norm_init,
                                 swiglu, swiglu_init, unembed)
from repro.models.moe import moe_block, moe_init

Params = Dict[str, Any]

__all__ = [
    "init_params", "train_loss", "prefill", "decode_step", "verify_step",
    "init_cache", "PagedCache", "init_paged_cache", "prefill_chunk",
    "encode_cross", "chunked_cross_entropy", "count_params",
]


def _cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ===========================================================================
# Layer init/apply per family
# ===========================================================================

def _dense_layer_init(key, cfg: ModelConfig, *, moe: bool, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": rms_norm_init(cfg.d_model, dtype),
        "attn": attn_init(k1, cfg, dtype),
        "mlp_norm": rms_norm_init(cfg.d_model, dtype),
        "mlp": (moe_init(k2, cfg, dtype) if moe
                else swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype)),
    }


def _dense_layer_apply(p, cfg: ModelConfig, x, positions, *, moe: bool,
                       causal=True, compute_dtype=jnp.bfloat16):
    from repro.dist import act_sharding as acts
    # Megatron-SP: residual stream sequence-sharded over model between
    # layers (row-parallel outputs reduce-scatter instead of all-reduce).
    # MoE layers need the full sequence per row for sort-based dispatch;
    # gated off for families where it regressed in the §Perf sweep:
    # MoE-every-layer (no dense stretch to amortise the reshard) and
    # hybrid (mamba blocks would ping-pong with the shared attn block).
    eligible = (cfg.family in ("dense", "moe")
                and not (moe and cfg.moe_every == 1))
    rspec = acts.residual_spec(x.shape[1], gather=moe) if eligible else None
    if rspec is not None:
        x = acts.constrain(x, rspec)
    with acts.residual_layout(rspec is not None and not moe):
        a, _ = attention_block(p["attn"], cfg,
                               rms_norm(p["attn_norm"], x, cfg.norm_eps),
                               positions, causal=causal,
                               compute_dtype=compute_dtype)
        x = x + a
        h = rms_norm(p["mlp_norm"], x, cfg.norm_eps)
        if moe:
            m, aux = moe_block(p["mlp"], cfg, h, compute_dtype=compute_dtype)
        else:
            m, aux = (swiglu(p["mlp"], h, compute_dtype),
                      jnp.zeros((), jnp.float32))
        x = x + m
    if rspec is not None:
        x = acts.constrain(x, rspec)
    return x, aux


def _encdec_dec_layer_init(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_norm": rms_norm_init(cfg.d_model, dtype),
        "self_attn": attn_init(k1, cfg, dtype),
        "cross_norm": rms_norm_init(cfg.d_model, dtype),
        "cross_attn": attn_init(k2, cfg, dtype),
        "mlp_norm": rms_norm_init(cfg.d_model, dtype),
        "mlp": swiglu_init(k3, cfg.d_model, cfg.d_ff, dtype),
    }


# ===========================================================================
# init_params
# ===========================================================================

def init_params(cfg: ModelConfig, key) -> Params:
    """Build the full parameter pytree (layer stacks stacked on axis 0)."""
    dtype = _pdtype(cfg)
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": embed_init(keys[0], cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": rms_norm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(keys[1], cfg.padded_vocab, cfg.d_model, dtype)

    fam = cfg.family
    if fam in ("dense", "moe"):
        if cfg.num_experts and cfg.moe_every > 1:
            n_groups = cfg.num_layers // cfg.moe_every
            gk = jax.random.split(keys[2], n_groups)

            def group_init(k):
                kd, km = jax.random.split(k)
                dks = jax.random.split(kd, cfg.moe_every - 1)
                return {
                    "dense": jax.vmap(lambda kk: _dense_layer_init(
                        kk, cfg, moe=False, dtype=dtype))(dks),
                    "moe": _dense_layer_init(km, cfg, moe=True, dtype=dtype),
                }

            params["groups"] = jax.vmap(group_init)(gk)
        else:
            lk = jax.random.split(keys[2], cfg.num_layers)
            params["layers"] = jax.vmap(lambda k: _dense_layer_init(
                k, cfg, moe=bool(cfg.num_experts), dtype=dtype))(lk)
    elif fam == "ssm":
        lk = jax.random.split(keys[2], cfg.num_layers)
        params["layers"] = jax.vmap(lambda k: ssm_mod.rwkv6_init(k, cfg, dtype))(lk)
    elif fam == "hybrid":
        every = cfg.shared_attn_every or cfg.num_layers
        n_groups = cfg.num_layers // every
        tail = cfg.num_layers - n_groups * every
        gk = jax.random.split(keys[2], max(n_groups, 1))
        params["mamba_groups"] = jax.vmap(
            lambda k: jax.vmap(lambda kk: ssm_mod.mamba2_init(kk, cfg, dtype))(
                jax.random.split(k, every)))(gk)
        if tail:
            tk = jax.random.split(keys[3], tail)
            params["mamba_tail"] = jax.vmap(
                lambda k: ssm_mod.mamba2_init(k, cfg, dtype))(tk)
        params["shared_attn"] = _dense_layer_init(keys[4], cfg, moe=False,
                                                  dtype=dtype)
    elif fam == "encdec":
        ek = jax.random.split(keys[2], cfg.encoder_layers)
        params["encoder"] = jax.vmap(lambda k: _dense_layer_init(
            k, cfg, moe=False, dtype=dtype))(ek)
        dk = jax.random.split(keys[3], cfg.num_layers)
        params["decoder"] = jax.vmap(lambda k: _encdec_dec_layer_init(
            k, cfg, dtype))(dk)
        params["enc_norm"] = rms_norm_init(cfg.d_model, dtype)
    else:
        raise ValueError(f"unknown family {fam!r}")
    return params


def count_params(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


# ===========================================================================
# forward passes (full sequence)
# ===========================================================================

def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "block":
        return jax.checkpoint(fn, prevent_cse=False)
    if remat == "dots":
        return jax.checkpoint(
            fn, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(f"unknown remat policy {remat!r}")


def _decoder_stack(params, cfg: ModelConfig, x, positions, *, remat="block",
                   causal=True):
    """Run the layer stack for dense/moe/ssm/hybrid; returns (x, aux)."""
    cdt = _cdtype(cfg)
    fam = cfg.family

    if fam in ("dense", "moe"):
        is_moe = bool(cfg.num_experts)
        if is_moe and cfg.moe_every > 1:
            def group_body(carry, gp):
                x = carry
                def dense_body(x, lp):
                    y, _ = _dense_layer_apply(lp, cfg, x, positions, moe=False,
                                              causal=causal, compute_dtype=cdt)
                    return y, None
                x, _ = jax.lax.scan(_maybe_remat(dense_body, remat), x, gp["dense"])
                x, aux = _maybe_remat(
                    lambda x, p: _dense_layer_apply(p, cfg, x, positions, moe=True,
                                                    causal=causal, compute_dtype=cdt),
                    remat)(x, gp["moe"])
                return x, aux
            x, auxs = jax.lax.scan(group_body, x, params["groups"])
            return x, auxs.sum()
        def body(x, lp):
            y, aux = _dense_layer_apply(lp, cfg, x, positions, moe=is_moe,
                                        causal=causal, compute_dtype=cdt)
            return y, aux
        x, auxs = jax.lax.scan(_maybe_remat(body, remat), x, params["layers"])
        return x, auxs.sum()

    if fam == "ssm":
        def body(x, lp):
            return ssm_mod.rwkv6_block(lp, cfg, x, compute_dtype=cdt), None
        x, _ = jax.lax.scan(_maybe_remat(body, remat), x, params["layers"])
        return x, jnp.zeros((), jnp.float32)

    if fam == "hybrid":
        shared = params["shared_attn"]
        def group_body(x, gp):
            def mamba_body(x, lp):
                return ssm_mod.mamba2_block(lp, cfg, x, compute_dtype=cdt), None
            x, _ = jax.lax.scan(_maybe_remat(mamba_body, remat), x, gp)
            y, _ = _maybe_remat(
                lambda x, p: _dense_layer_apply(p, cfg, x, positions, moe=False,
                                                causal=causal, compute_dtype=cdt),
                remat)(x, shared)
            return y, None
        x, _ = jax.lax.scan(group_body, x, params["mamba_groups"])
        if "mamba_tail" in params:
            def mamba_body(x, lp):
                return ssm_mod.mamba2_block(lp, cfg, x, compute_dtype=cdt), None
            x, _ = jax.lax.scan(_maybe_remat(mamba_body, remat), x,
                                params["mamba_tail"])
        return x, jnp.zeros((), jnp.float32)

    raise ValueError(f"_decoder_stack: bad family {fam}")


def _encode(params, cfg: ModelConfig, src_embeds, *, remat="block"):
    """Bidirectional encoder over stub frontend embeddings (B, S, d)."""
    cdt = _cdtype(cfg)
    x = src_embeds.astype(cdt)
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), x.shape[:2])

    def body(x, lp):
        y, _ = _dense_layer_apply(lp, cfg, x, positions, moe=False,
                                  causal=False, compute_dtype=cdt)
        return y, None

    x, _ = jax.lax.scan(_maybe_remat(body, remat), x, params["encoder"])
    return rms_norm(params["enc_norm"], x, cfg.norm_eps)


def _decode_stack_encdec(params, cfg: ModelConfig, x, positions, enc_out, *,
                         remat="block"):
    cdt = _cdtype(cfg)

    def body(x, lp):
        a, _ = attention_block(lp["self_attn"], cfg,
                               rms_norm(lp["self_norm"], x, cfg.norm_eps),
                               positions, causal=True, compute_dtype=cdt)
        x = x + a
        c, _ = attention_block(lp["cross_attn"], cfg,
                               rms_norm(lp["cross_norm"], x, cfg.norm_eps),
                               positions, kv=enc_out, compute_dtype=cdt)
        x = x + c
        h = rms_norm(lp["mlp_norm"], x, cfg.norm_eps)
        return x + swiglu(lp["mlp"], h, cdt), None

    x, _ = jax.lax.scan(_maybe_remat(body, remat), x, params["decoder"])
    return x


def forward(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray], *,
            remat: str = "block") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward to final hidden states.  Returns (x, aux)."""
    cdt = _cdtype(cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed(params["embed"], tokens, cdt)
    if cfg.mrope_sections:
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S), (3, B, S))
    else:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if cfg.family == "encdec":
        enc_out = _encode(params, cfg, batch["src_embeds"], remat=remat)
        x = _decode_stack_encdec(params, cfg, x, positions, enc_out, remat=remat)
        aux = jnp.zeros((), jnp.float32)
    else:
        x, aux = _decoder_stack(params, cfg, x, positions, remat=remat)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


# ===========================================================================
# loss (chunked over sequence — never materialises (B, S, V) logits)
# ===========================================================================

def chunked_cross_entropy(x, table, labels, *, logit_scale=1.0,
                          chunk: int = 512, z_coef: float = 0.0):
    """Mean next-token xent.  x: (B,S,d) hidden; labels: (B,S) int32,
    -1 = ignore.  Streams over S in chunks of ``chunk``."""
    B, S, d = x.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n = (S + pad) // c
    xc = x.reshape(B, n, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, c).transpose(1, 0, 2)

    def body(carry, xs):
        tot, cnt, zacc = carry
        xb, lb = xs
        logits = (xb.astype(jnp.bfloat16) @ table.astype(jnp.bfloat16).T)
        logits = (logits * logit_scale).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(lb, 0)[..., None],
                                   axis=-1)[..., 0]
        valid = (lb >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((lse - gold) * valid)
        zacc = zacc + jnp.sum(jnp.square(lse) * valid)
        cnt = cnt + valid.sum()
        return (tot, cnt, zacc), None

    (tot, cnt, zacc), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
               jnp.zeros((), jnp.float32)), (xc, lc))
    cnt = jnp.maximum(cnt, 1.0)
    return tot / cnt + z_coef * zacc / cnt


def train_loss(params, cfg: ModelConfig, batch, *, remat: str = "block",
               z_coef: float = 0.0):
    """Scalar loss + metrics dict."""
    x, aux = forward(params, cfg, batch, remat=remat)
    table = params["embed"]["table"] if cfg.tie_embeddings else \
        params["lm_head"]["table"]
    xent = chunked_cross_entropy(x, table, batch["labels"],
                                 logit_scale=cfg.logit_scale, z_coef=z_coef)
    loss = xent + aux
    return loss, {"loss": loss, "xent": xent, "aux": aux}


# ===========================================================================
# inference: prefill + decode_step
# ===========================================================================

class Cache(NamedTuple):
    """Decode-time state for any family (unused fields are empty dicts)."""
    kv: Dict[str, jnp.ndarray]         # attention KV (stacked over layers)
    ssm: Any                           # RWKVState/MambaState stacked or ()
    cross: Dict[str, jnp.ndarray]      # encdec: cross-attn KV + enc_out
    pos: jnp.ndarray                   # next absolute position (scalar)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               src_len: Optional[int] = None) -> Cache:
    kv, ssm_state, cross = {}, (), {}
    fam = cfg.family
    if fam in ("dense", "moe", "encdec"):
        kv = init_kv_cache(cfg, batch, max_len)
    if fam == "ssm":
        s = ssm_mod.rwkv6_state_init(cfg, batch)
        ssm_state = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape), s)
    if fam == "hybrid":
        every = cfg.shared_attn_every or cfg.num_layers
        n_groups = cfg.num_layers // every
        s = ssm_mod.mamba2_state_init(cfg, batch)
        ssm_state = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape), s)
        kv = init_kv_cache(cfg, batch, max_len, n_layers=n_groups)
    if fam == "encdec":
        Ssrc = max_len if src_len is None else src_len
        cdt = _cdtype(cfg)
        cross = {
            "k": jnp.zeros((cfg.num_layers, batch, Ssrc, cfg.num_kv_heads,
                            cfg.head_dim), cdt),
            "v": jnp.zeros((cfg.num_layers, batch, Ssrc, cfg.num_kv_heads,
                            cfg.head_dim), cdt),
            "enc_out": jnp.zeros((batch, Ssrc, cfg.d_model), cdt),
        }
    return Cache(kv=kv, ssm=ssm_state, cross=cross,
                 pos=jnp.zeros((batch,), jnp.int32))


class PagedCache(NamedTuple):
    """Decode-time state with attention KV in the paged pool layout.

    ``kv`` holds ``k_pages`` / ``v_pages`` of shape
    ``(L, n_frames, page, Hkv, D)`` — the device :class:`repro.paging.
    PagePool`'s frames, stacked over layers — plus the per-slot
    ``page_table`` (B, pages_per_seq) of physical frame ids.  Non-KV
    state (SSM, cross-attn, positions) keeps the dense per-slot layout:
    it is tiny relative to the KV and is never paged.
    """

    kv: Dict[str, jnp.ndarray]         # k_pages / v_pages / page_table
    ssm: Any                           # RWKVState/MambaState stacked or ()
    cross: Dict[str, jnp.ndarray]      # encdec: cross-attn KV + enc_out
    pos: jnp.ndarray                   # next absolute position, (B,)


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int,
                     n_frames: int, page_size: int,
                     src_len: Optional[int] = None) -> PagedCache:
    """Like :func:`init_cache` but with the KV in pool-frame layout.

    Frame ``n_frames - 1`` is the *trash frame*: unmapped page-table
    entries (and every entry of an empty decode slot) point there, so
    garbage decode writes never corrupt a live sequence's page.
    """
    base = init_cache(cfg, batch, max_len, src_len=src_len)
    fam = cfg.family
    if fam not in ("dense", "moe", "encdec", "hybrid"):
        raise ValueError(f"family {fam!r} has no KV to page")
    n_layers = None
    if fam == "hybrid":
        every = cfg.shared_attn_every or cfg.num_layers
        n_layers = cfg.num_layers // every
    kv = init_paged_kv_cache(cfg, n_frames, page_size, batch, max_len,
                             n_layers=n_layers)
    return PagedCache(kv=kv, ssm=base.ssm, cross=base.cross, pos=base.pos)


def decode_step(params, cfg: ModelConfig, cache: Cache,
                tokens: jnp.ndarray,
                src_embeds: Optional[jnp.ndarray] = None,
                *, impl: str = "auto") -> Tuple[jnp.ndarray, Cache]:
    """One-token decode.  tokens: (B, 1) int32.  Returns (logits (B, V), cache).

    Accepts either a dense :class:`Cache` or a :class:`PagedCache`; for
    the latter, attention computes directly on the paged pool layout
    (``impl`` selects the paged-gather backend: the Pallas kernel on
    TPU, the XLA gather elsewhere).
    """
    cdt = _cdtype(cfg)
    pos = cache.pos
    x = embed(params["embed"], tokens, cdt)
    fam = cfg.family
    paged = isinstance(cache, PagedCache)

    if paged:
        if fam == "ssm":
            raise ValueError("family 'ssm' has no KV to page")
        pt = cache.kv["page_table"]
        kkey, vkey = "k_pages", "v_pages"

        def attn(p, h, kl, vl):
            return paged_decode_attention_block(
                p, cfg, h, (kl, vl), pt, pos, compute_dtype=cdt, impl=impl)
    else:
        kkey, vkey = "k", "v"

        def attn(p, h, kl, vl):
            return decode_attention_block(p, cfg, h, (kl, vl), pos,
                                          compute_dtype=cdt)

    if fam == "ssm":
        def body(carry, xs):
            x = carry
            lp, st = xs
            y, st2 = ssm_mod.rwkv6_step(lp, cfg, x, ssm_mod.RWKVState(*st),
                                        compute_dtype=cdt)
            return y, tuple(st2)
        x, new_state = jax.lax.scan(body, x, (params["layers"],
                                              tuple(cache.ssm)))
        kv = cache.kv
        cache = cache._replace(ssm=ssm_mod.RWKVState(*new_state))
    else:
        x, kn, vn, new_state = _decode_families(
            params, cfg, x, cache, cache.kv[kkey], cache.kv[vkey], attn, cdt)
        kv = dict(cache.kv, **{kkey: kn, vkey: vn})
        if new_state is not None:
            cache = cache._replace(ssm=new_state)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed"]["table"] if cfg.tie_embeddings else \
        params["lm_head"]["table"]
    logits = unembed({"table": table}, x, logit_scale=cfg.logit_scale,
                     compute_dtype=cdt)[:, 0]
    new_cache = cache._replace(kv=kv, pos=pos + 1)
    return logits.astype(jnp.float32), new_cache


def verify_step(params, cfg: ModelConfig, cache: Cache,
                tokens: jnp.ndarray, length: jnp.ndarray,
                *, impl: str = "auto") -> Tuple[jnp.ndarray, Cache]:
    """Speculative verify-K decode: score S = K + 1 tokens per slot in
    one step.  tokens: (B, S) int32 — row 0 the last committed token,
    rows 1..K the drafted continuation; length: (B,) valid rows per slot
    (0 marks an inert slot, whose K/V all scatter to the trash frame).
    Returns (logits (B, S, V) f32, cache).

    Logits row ``s`` predicts the token at position ``pos + s + 1``;
    for any draft prefix that matches greedy decode, the rows are
    bit-equal to the sequential :func:`decode_step` logits they replace
    (same layer structure via ``_decode_families``, same attention
    expressions via ``paged_verify_block``).  ``cache.pos`` is NOT
    advanced — acceptance length is decided host-side after the argmax
    comparison, and the engine writes the rewound ``pos`` back.

    Paged KV only, dense/moe families only, no SWA — the engine gates
    speculation accordingly.
    """
    cdt = _cdtype(cfg)
    if not isinstance(cache, PagedCache):
        raise ValueError("verify_step requires a PagedCache")
    if cfg.family not in ("dense", "moe"):
        raise ValueError(
            f"speculative verify not supported for family {cfg.family!r}")
    if cfg.attention == "swa":
        raise ValueError("speculative verify has no SWA ring semantics")
    pos = cache.pos
    x = embed(params["embed"], tokens, cdt)
    pt = cache.kv["page_table"]

    def attn(p, h, kl, vl):
        return paged_verify_block(p, cfg, h, (kl, vl), pt, pos, length,
                                  compute_dtype=cdt, impl=impl)

    x, kn, vn, _ = _decode_families(
        params, cfg, x, cache, cache.kv["k_pages"], cache.kv["v_pages"],
        attn, cdt)
    kv = dict(cache.kv, k_pages=kn, v_pages=vn)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed"]["table"] if cfg.tie_embeddings else \
        params["lm_head"]["table"]
    logits = unembed({"table": table}, x, logit_scale=cfg.logit_scale,
                     compute_dtype=cdt)
    return logits.astype(jnp.float32), cache._replace(kv=kv)


def _decode_families(params, cfg: ModelConfig, x, cache, ks, vs, attn,
                     cdt):
    """One-token decode through the family layer stacks, parameterized
    over the attention callback and the KV arrays — the dense per-slot
    cache and the paged pool frames share every line of layer structure,
    which is what keeps the two layouts bit-exact by construction.

    ``attn(p, h, kl, vl) -> (out, (kn, vn))`` runs one attention block
    on the pre-normed hidden ``h``; ``ks``/``vs`` are the stacked-over-
    layers KV arrays (axis 0 scanned per layer/group).  Returns
    ``(x, k_new, v_new, new_ssm_state_or_None)``.
    """
    fam = cfg.family
    if fam in ("dense", "moe"):
        is_moe = bool(cfg.num_experts)
        if is_moe and cfg.moe_every > 1:
            x, kn, vn = _decode_grouped_moe(params, cfg, x, ks, vs, attn,
                                            cdt)
        else:
            def body(carry, xs):
                x = carry
                lp, kl, vl = xs
                a, (kn, vn) = attn(lp["attn"],
                                   rms_norm(lp["attn_norm"], x, cfg.norm_eps),
                                   kl, vl)
                x = x + a
                h = rms_norm(lp["mlp_norm"], x, cfg.norm_eps)
                if is_moe:
                    m, _ = moe_block(lp["mlp"], cfg, h, compute_dtype=cdt)
                else:
                    m = swiglu(lp["mlp"], h, cdt)
                return x + m, (kn, vn)
            x, (kn, vn) = jax.lax.scan(body, x, (params["layers"], ks, vs))
        return x, kn, vn, None
    if fam == "hybrid":
        return _decode_hybrid(params, cfg, x, cache, ks, vs, attn, cdt)
    if fam == "encdec":
        x, kn, vn = _decode_encdec(params, cfg, x, cache, ks, vs, attn, cdt)
        return x, kn, vn, None
    raise ValueError(f"_decode_families: bad family {fam}")


def _decode_grouped_moe(params, cfg, x, ks, vs, attn, cdt):
    """Decode path for moe_every>1 (llama4): scan groups, inner dense scan."""
    n_groups = cfg.num_layers // cfg.moe_every
    d_per = cfg.moe_every - 1
    # cache layout: layer l -> group g = l // moe_every, slot = l % moe_every
    kshape = ks.shape
    k = ks.reshape((n_groups, cfg.moe_every) + kshape[1:])
    v = vs.reshape((n_groups, cfg.moe_every) + kshape[1:])

    def group_body(x, xs):
        gp, kg, vg = xs
        def dense_body(x, ys):
            lp, kl, vl = ys
            a, (kn, vn) = attn(lp["attn"],
                               rms_norm(lp["attn_norm"], x, cfg.norm_eps),
                               kl, vl)
            x = x + a
            m = swiglu(lp["mlp"], rms_norm(lp["mlp_norm"], x, cfg.norm_eps),
                       cdt)
            return x + m, (kn, vn)
        x, (kd, vd) = jax.lax.scan(dense_body, x,
                                   (gp["dense"], kg[:d_per], vg[:d_per]))
        lp = gp["moe"]
        a, (km, vm) = attn(lp["attn"],
                           rms_norm(lp["attn_norm"], x, cfg.norm_eps),
                           kg[d_per], vg[d_per])
        x = x + a
        m, _ = moe_block(lp["mlp"], cfg,
                         rms_norm(lp["mlp_norm"], x, cfg.norm_eps),
                         compute_dtype=cdt)
        x = x + m
        kout = jnp.concatenate([kd, km[None]], axis=0)
        vout = jnp.concatenate([vd, vm[None]], axis=0)
        return x, (kout, vout)

    x, (kn, vn) = jax.lax.scan(group_body, x, (params["groups"], k, v))
    return x, kn.reshape(kshape), vn.reshape(kshape)


def _decode_hybrid(params, cfg, x, cache, ks, vs, attn, cdt):
    every = cfg.shared_attn_every or cfg.num_layers
    n_groups = cfg.num_layers // every
    tail = cfg.num_layers - n_groups * every
    sg = jax.tree_util.tree_map(lambda a: a[:n_groups * every].reshape(
        (n_groups, every) + a.shape[1:]), cache.ssm)
    shared = params["shared_attn"]

    def mamba_body(x, ys):
        lp, st = ys
        y, st2 = ssm_mod.mamba2_step(lp, cfg, x, ssm_mod.MambaState(*st),
                                     compute_dtype=cdt)
        return y, tuple(st2)

    def group_body(carry, xs):
        x = carry
        gp, st_g, kl, vl = xs
        x, st_new = jax.lax.scan(mamba_body, x, (gp, tuple(st_g)))
        a, (kn, vn) = attn(shared["attn"],
                           rms_norm(shared["attn_norm"], x, cfg.norm_eps),
                           kl, vl)
        x = x + a
        x = x + swiglu(shared["mlp"], rms_norm(shared["mlp_norm"], x,
                                               cfg.norm_eps), cdt)
        return x, (st_new, kn, vn)

    x, (st_new, kn, vn) = jax.lax.scan(
        group_body, x, (params["mamba_groups"], tuple(sg), ks, vs))
    st_new = ssm_mod.MambaState(*st_new)
    st_flat = jax.tree_util.tree_map(
        lambda a: a.reshape((n_groups * every,) + a.shape[2:]), st_new)
    if tail:
        st_tail = jax.tree_util.tree_map(lambda a: a[n_groups * every:],
                                         cache.ssm)
        x, st_tail_new = jax.lax.scan(mamba_body, x,
                                      (params["mamba_tail"], tuple(st_tail)))
        st_flat = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], axis=0),
            st_flat, ssm_mod.MambaState(*st_tail_new))
    return x, kn, vn, st_flat


def _decode_encdec(params, cfg, x, cache, ks, vs, attn, cdt, *,
                   cross=None, cross_valid=None):
    """Enc-dec decoder stack shared by one-token decode and the chunked
    paged-prefill path: ``x`` may be (B, 1, d) or a (C, T, d) prompt
    chunk.  ``cross`` overrides the cache's cross-KV trees (the chunk
    path gathers per-slot rows) and ``cross_valid`` masks encoder
    positions past each row's true source length — decode passes
    neither, so its traced graph is unchanged."""
    cross = cache.cross if cross is None else cross

    def body(carry, xs):
        x = carry
        lp, kl, vl, ck, cv = xs
        a, (kn, vn) = attn(lp["self_attn"],
                           rms_norm(lp["self_norm"], x, cfg.norm_eps),
                           kl, vl)
        x = x + a
        # cross attention against precomputed cross KV (no rope, not causal)
        from repro.models.layers import dense
        B, S = x.shape[:2]
        hd = cfg.head_dim
        xq = rms_norm(lp["cross_norm"], x, cfg.norm_eps)
        q = dense(lp["cross_attn"]["q"], xq, cdt).reshape(B, S, cfg.num_heads, hd)
        c = chunked_attention(q, ck, cv, causal=False,
                              kv_valid_len=cross_valid)
        c = dense(lp["cross_attn"]["o"], c.reshape(B, S, cfg.num_heads * hd), cdt)
        x = x + c
        x = x + swiglu(lp["mlp"], rms_norm(lp["mlp_norm"], x, cfg.norm_eps), cdt)
        return x, (kn, vn)

    x, (kn, vn) = jax.lax.scan(
        body, x, (params["decoder"], ks, vs, cross["k"], cross["v"]))
    return x, kn, vn


def prefill(params, cfg: ModelConfig, batch, *, max_len: Optional[int] = None,
            last_pos: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, Cache]:
    """Encode the prompt, build the cache, return last-token logits.

    For attention families this materialises the KV cache from the full
    forward; for SSM/hybrid families it runs the chunked form with
    ``return_state`` and keeps only the state (O(1) memory in S).

    ``last_pos`` (a per-row ``(B,)`` index, default ``S - 1``) selects
    which position's logits are returned — the serving engine passes the
    prompt's true last token so the first sampled token never depends on
    the padded bucket tail (and so bucketed dense prefill and chunked
    paged prefill agree on it).
    """
    cdt = _cdtype(cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_len = max_len or S
    cache = init_cache(cfg, B, max_len)
    fam = cfg.family
    x = embed(params["embed"], tokens, cdt)
    positions = (jnp.broadcast_to(jnp.arange(S), (3, B, S))
                 if cfg.mrope_sections else
                 jnp.broadcast_to(jnp.arange(S), (B, S)))

    if fam in ("dense", "moe"):
        kv = cache.kv
        slots = int(kv["k"].shape[2])
        if cfg.num_experts and cfg.moe_every > 1:
            x, kn, vn = _prefill_grouped_moe(params, cfg, x, positions, slots, cdt)
        else:
            is_moe = bool(cfg.num_experts)
            def body(x, lp):
                h = rms_norm(lp["attn_norm"], x, cfg.norm_eps)
                a, (k, v) = attention_block(lp["attn"], cfg, h, positions,
                                            compute_dtype=cdt)
                x = x + a
                h2 = rms_norm(lp["mlp_norm"], x, cfg.norm_eps)
                m = (moe_block(lp["mlp"], cfg, h2, compute_dtype=cdt)[0]
                     if is_moe else swiglu(lp["mlp"], h2, cdt))
                return x + m, _cache_fit(k, v, slots)
            x, (kn, vn) = jax.lax.scan(body, x, params["layers"])
        cache = cache._replace(kv=dict(kv, k=kn.astype(kv["k"].dtype),
                                       v=vn.astype(kv["v"].dtype)))
    elif fam == "ssm":
        x, states = _prefill_rwkv(params, cfg, x, cdt)
        cache = cache._replace(ssm=states)
    elif fam == "hybrid":
        x, states, kv = _prefill_hybrid(params, cfg, x, positions, cache, cdt)
        cache = cache._replace(ssm=states, kv=kv)
    elif fam == "encdec":
        enc_out = _encode(params, cfg, batch["src_embeds"], remat="none")
        x, kv, cross = _prefill_encdec(params, cfg, x, positions, enc_out, cdt)
        cache = cache._replace(kv=dict(cache.kv, **kv), cross=cross)
    if last_pos is None:
        x_last = x[:, -1:]
    else:
        idx = jnp.clip(jnp.asarray(last_pos, jnp.int32), 0, S - 1)
        x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
    x = rms_norm(params["final_norm"], x_last, cfg.norm_eps)
    table = params["embed"]["table"] if cfg.tie_embeddings else \
        params["lm_head"]["table"]
    logits = unembed({"table": table}, x, logit_scale=cfg.logit_scale,
                     compute_dtype=cdt)[:, 0]
    cache = cache._replace(pos=jnp.full((B,), S, jnp.int32))
    return logits.astype(jnp.float32), cache


def _cache_fit(k, v, slots):
    """Keep the last ``slots`` positions, rolled so absolute position ``p``
    lands in ring slot ``p % slots`` (decode overwrites the oldest entry)."""
    S = k.shape[1]
    if S <= slots:
        return k, v
    shift = S % slots
    return (jnp.roll(k[:, -slots:], shift, axis=1),
            jnp.roll(v[:, -slots:], shift, axis=1))


def _prefill_grouped_moe(params, cfg, x, positions, slots, cdt):
    def group_body(x, gp):
        def dense_body(x, lp):
            h = rms_norm(lp["attn_norm"], x, cfg.norm_eps)
            a, (k, v) = attention_block(lp["attn"], cfg, h, positions,
                                        compute_dtype=cdt)
            x = x + a
            m = swiglu(lp["mlp"], rms_norm(lp["mlp_norm"], x, cfg.norm_eps), cdt)
            return x + m, _cache_fit(k, v, slots)
        x, (kd, vd) = jax.lax.scan(dense_body, x, gp["dense"])
        lp = gp["moe"]
        h = rms_norm(lp["attn_norm"], x, cfg.norm_eps)
        a, (k, v) = attention_block(lp["attn"], cfg, h, positions,
                                    compute_dtype=cdt)
        x = x + a
        m, _ = moe_block(lp["mlp"], cfg,
                         rms_norm(lp["mlp_norm"], x, cfg.norm_eps),
                         compute_dtype=cdt)
        x = x + m
        kf, vf = _cache_fit(k, v, slots)
        return x, (jnp.concatenate([kd, kf[None]], 0),
                   jnp.concatenate([vd, vf[None]], 0))
    x, (kg, vg) = jax.lax.scan(group_body, x, params["groups"])
    L = cfg.num_layers
    kn = kg.reshape((L,) + kg.shape[2:])
    vn = vg.reshape((L,) + vg.shape[2:])
    return x, kn, vn


def _prefill_rwkv(params, cfg, x, cdt):
    def body(x, lp):
        xn = x
        from repro.models.layers import layer_norm
        h = layer_norm(lp["ln1"], xn, cfg.norm_eps)
        tm, S = ssm_mod.rwkv6_time_mix(lp, cfg, h, return_state=True,
                                       compute_dtype=cdt)
        x = x + tm
        h2 = layer_norm(lp["ln2"], x, cfg.norm_eps)
        x = x + ssm_mod.rwkv6_channel_mix(lp, cfg, h2, compute_dtype=cdt)
        st = ssm_mod.RWKVState(S=S, tm_prev=h[:, -1].astype(jnp.float32),
                               cm_prev=h2[:, -1].astype(jnp.float32))
        return x, tuple(st)
    x, states = jax.lax.scan(body, x, params["layers"])
    return x, ssm_mod.RWKVState(*states)


def _prefill_hybrid(params, cfg, x, positions, cache, cdt):
    every = cfg.shared_attn_every or cfg.num_layers
    n_groups = cfg.num_layers // every
    tail = cfg.num_layers - n_groups * every
    shared = params["shared_attn"]
    slots = int(cache.kv["k"].shape[2])

    def mamba_state_body(x, lp):
        from repro.models.layers import rms_norm as rn
        xn = rn(lp["norm"], x, cfg.norm_eps)
        z, xbc, dt_raw = ssm_mod._mamba2_project(lp, cfg, xn, cdt)
        xbc_conv, conv_ctx = ssm_mod._causal_conv(xbc, lp["conv_w"], lp["conv_b"])
        xh, dt, Bs, Cs = ssm_mod._mamba2_ssm_inputs(lp, cfg, xbc_conv, dt_raw)
        A = jnp.exp(lp["A_log"].astype(jnp.float32))
        y, S = ssm_mod.ssd_chunked(xh, dt, A, Bs, Cs, lp["D"],
                                   return_state=True)
        y = y.reshape(x.shape[0], x.shape[1], cfg.d_inner)
        y = rn(lp["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
        from repro.models.layers import dense
        out = x + dense(lp["out_proj"], y.astype(cdt), cdt)
        st = ssm_mod.MambaState(S=S, conv=xbc[:, -(cfg.ssm_conv - 1):]
                                .astype(jnp.float32))
        return out, tuple(st)

    def group_body(x, xs):
        gp = xs
        x, st = jax.lax.scan(mamba_state_body, x, gp)
        h = rms_norm(shared["attn_norm"], x, cfg.norm_eps)
        a, (k, v) = attention_block(shared["attn"], cfg, h, positions,
                                    compute_dtype=cdt)
        x = x + a
        x = x + swiglu(shared["mlp"], rms_norm(shared["mlp_norm"], x,
                                               cfg.norm_eps), cdt)
        kf, vf = _cache_fit(k, v, slots)
        return x, (st, kf, vf)

    x, (st_g, kn, vn) = jax.lax.scan(group_body, x, params["mamba_groups"])
    st_flat = jax.tree_util.tree_map(
        lambda a: a.reshape((n_groups * every,) + a.shape[2:]),
        ssm_mod.MambaState(*st_g))
    if tail:
        x, st_t = jax.lax.scan(mamba_state_body, x, params["mamba_tail"])
        st_flat = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], 0), st_flat,
            ssm_mod.MambaState(*st_t))
    kv = dict(cache.kv, k=kn.astype(cache.kv["k"].dtype),
              v=vn.astype(cache.kv["v"].dtype))
    return x, st_flat, kv


# ===========================================================================
# chunked paged prefill: prompt chunks computed directly on the pool layout
# ===========================================================================


def encode_cross(params, cfg: ModelConfig, src_embeds) -> Dict[str, Any]:
    """Run the encoder once and project the per-layer cross-attention KV.

    The chunked-prefill admission path for enc-dec: the encoder (and the
    cross K/V projections) run once when a request is admitted, their
    rows are installed into the batched cache's ``cross`` tree, and every
    subsequent prompt chunk / decode token reads them from there.  The
    projections are exactly the ones dense prefill's cross
    ``attention_block`` computes, so chunked and dense prefill agree.
    """
    cdt = _cdtype(cfg)
    enc_out = _encode(params, cfg, src_embeds, remat="none")
    B, Skv, _ = enc_out.shape
    hd = cfg.head_dim

    def body(carry, lp):
        from repro.models.layers import dense
        k = dense(lp["cross_attn"]["k"], enc_out, cdt).reshape(
            B, Skv, cfg.num_kv_heads, hd)
        v = dense(lp["cross_attn"]["v"], enc_out, cdt).reshape(
            B, Skv, cfg.num_kv_heads, hd)
        return carry, (k, v)

    _, (ck, cv) = jax.lax.scan(body, 0, params["decoder"])
    return {"k": ck, "v": cv, "enc_out": enc_out}


def _chunk_hybrid(params, cfg: ModelConfig, x, carry, ks, vs, attn, cdt):
    """Chunked-prefill layer stack for the hybrid family: the group
    structure of :func:`_decode_hybrid` with a *chunked* Mamba2 body that
    consumes and emits explicit per-layer state (``carry``: a
    ``MambaState`` with leaves stacked ``(num_layers, C, ...)``), so a
    prompt can be prefilled across several engine steps with the SSM
    state carried host-side between chunks."""
    from repro.models.layers import dense as dense_proj
    every = cfg.shared_attn_every or cfg.num_layers
    n_groups = cfg.num_layers // every
    tail = cfg.num_layers - n_groups * every
    B, T, _ = x.shape
    sg = jax.tree_util.tree_map(lambda a: a[:n_groups * every].reshape(
        (n_groups, every) + a.shape[1:]), carry)
    shared = params["shared_attn"]

    def mamba_body(x, ys):
        lp, st = ys
        st = ssm_mod.MambaState(*st)
        xn = rms_norm(lp["norm"], x, cfg.norm_eps)
        z, xbc, dt_raw = ssm_mod._mamba2_project(lp, cfg, xn, cdt)
        xbc_conv, new_conv = ssm_mod._causal_conv(
            xbc, lp["conv_w"], lp["conv_b"], conv_state=st.conv)
        xh, dt, Bs, Cs = ssm_mod._mamba2_ssm_inputs(lp, cfg, xbc_conv, dt_raw)
        A = jnp.exp(lp["A_log"].astype(jnp.float32))
        y, S = ssm_mod.ssd_chunked(xh, dt, A, Bs, Cs, lp["D"],
                                   initial_state=st.S, return_state=True)
        y = y.reshape(B, T, cfg.d_inner)
        y = rms_norm(lp["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
        out = x + dense_proj(lp["out_proj"], y.astype(cdt), cdt)
        st2 = ssm_mod.MambaState(S=S, conv=new_conv.astype(st.conv.dtype))
        return out, tuple(st2)

    def group_body(carry_x, xs):
        x = carry_x
        gp, st_g, kl, vl = xs
        x, st_new = jax.lax.scan(mamba_body, x, (gp, tuple(st_g)))
        a, (kn, vn) = attn(shared["attn"],
                           rms_norm(shared["attn_norm"], x, cfg.norm_eps),
                           kl, vl)
        x = x + a
        x = x + swiglu(shared["mlp"], rms_norm(shared["mlp_norm"], x,
                                               cfg.norm_eps), cdt)
        return x, (st_new, kn, vn)

    x, (st_new, kn, vn) = jax.lax.scan(
        group_body, x, (params["mamba_groups"], tuple(sg), ks, vs))
    st_new = ssm_mod.MambaState(*st_new)
    st_flat = jax.tree_util.tree_map(
        lambda a: a.reshape((n_groups * every,) + a.shape[2:]), st_new)
    if tail:
        st_tail = jax.tree_util.tree_map(lambda a: a[n_groups * every:],
                                         carry)
        x, st_tail_new = jax.lax.scan(mamba_body, x,
                                      (params["mamba_tail"], tuple(st_tail)))
        st_flat = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], axis=0),
            st_flat, ssm_mod.MambaState(*st_tail_new))
    return x, kn, vn, st_flat


def prefill_chunk(params, cfg: ModelConfig, cache: PagedCache,
                  chunk: Dict[str, Any], *, impl: str = "auto"
                  ) -> Tuple[jnp.ndarray, PagedCache, Any]:
    """Run one prompt chunk for up to C admitting slots on the pool layout.

    The compute half of chunked paged prefill (the serving pattern the
    follow-up AMU paper, 2404.11044, builds its massive-parallelism case
    on): instead of densely prefilling a whole prompt in one bubble, the
    engine feeds prompts through in chunks that flash-attend against the
    sequence's pool-resident prefix while scattering their own K/V into
    the mapped frames (:func:`~repro.models.attention.
    paged_prefill_block`) — so prefill and decode share one fused step
    and dense KV never exists, not even transiently.

    ``chunk`` keys (C = chunk rows, T = chunk token capacity):

    * ``tokens`` (C, T) int32 — prompt chunk token ids, zero-padded,
    * ``offset`` / ``length`` (C,) int32 — each row's absolute start
      position and valid token count (``length == 0`` rows are inert:
      their K/V writes land in the trash frame),
    * ``page_rows`` (C, pages_per_seq) int32 — pool frame ids covering
      ``[0, offset + length)`` for each row (trash id beyond),
    * ``slots`` (C,) int32 — the decode slot each row occupies (used to
      gather enc-dec cross-KV rows),
    * ``src_len`` (C,) int32 — enc-dec only: true encoder length,
    * ``ssm`` — hybrid only: ``MambaState`` carry with leaves stacked
      ``(num_layers, C, ...)``.

    Returns ``(logits, cache, carry)``: logits at each row's last valid
    token (the first sampled token when the row just finished its
    prompt), the cache with the pool frames updated in place, and the
    state carry to thread into the row's next chunk (hybrid; else None).

    Layer structure is shared with the decode path (the same
    ``_decode_families`` bodies run with a multi-token ``x`` and the
    paged-prefill attention callback), which is what keeps chunked
    prefill + paged decode bit-compatible with a dense run.
    """
    cdt = _cdtype(cfg)
    fam = cfg.family
    if fam == "ssm":
        raise ValueError("family 'ssm' has no KV to page")
    toks = chunk["tokens"]
    C, T = toks.shape
    offset = jnp.asarray(chunk["offset"], jnp.int32)
    length = jnp.asarray(chunk["length"], jnp.int32)
    page_rows = jnp.asarray(chunk["page_rows"], jnp.int32)
    x = embed(params["embed"], toks, cdt)
    pos2 = offset[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    positions = (jnp.broadcast_to(pos2, (3, C, T)) if cfg.mrope_sections
                 else pos2)
    kv = cache.kv
    kp, vp = kv["k_pages"], kv["v_pages"]

    def attn(p, h, kl, vl):
        return paged_prefill_block(p, cfg, h, (kl, vl), page_rows, offset,
                                   length, positions, compute_dtype=cdt,
                                   impl=impl)

    carry_out = None
    if fam in ("dense", "moe"):
        x, kn, vn, _ = _decode_families(params, cfg, x, cache, kp, vp,
                                        attn, cdt)
    elif fam == "hybrid":
        x, kn, vn, carry_out = _chunk_hybrid(params, cfg, x, chunk["ssm"],
                                             kp, vp, attn, cdt)
    elif fam == "encdec":
        slots_ix = jnp.asarray(chunk["slots"], jnp.int32)
        cross = {"k": cache.cross["k"][:, slots_ix],
                 "v": cache.cross["v"][:, slots_ix]}
        x, kn, vn = _decode_encdec(params, cfg, x, cache, kp, vp, attn, cdt,
                                   cross=cross,
                                   cross_valid=jnp.asarray(chunk["src_len"],
                                                           jnp.int32))
    else:
        raise ValueError(f"prefill_chunk: bad family {fam}")

    idx = jnp.clip(length - 1, 0, T - 1)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
    x_last = rms_norm(params["final_norm"], x_last, cfg.norm_eps)
    table = params["embed"]["table"] if cfg.tie_embeddings else \
        params["lm_head"]["table"]
    logits = unembed({"table": table}, x_last, logit_scale=cfg.logit_scale,
                     compute_dtype=cdt)[:, 0]
    new_cache = cache._replace(kv=dict(kv, k_pages=kn, v_pages=vn))
    return logits.astype(jnp.float32), new_cache, carry_out


def _prefill_encdec(params, cfg, x, positions, enc_out, cdt):
    def body(x, lp):
        h = rms_norm(lp["self_norm"], x, cfg.norm_eps)
        a, (k, v) = attention_block(lp["self_attn"], cfg, h, positions,
                                    compute_dtype=cdt)
        x = x + a
        hc = rms_norm(lp["cross_norm"], x, cfg.norm_eps)
        c, (ck, cv) = attention_block(lp["cross_attn"], cfg, hc, positions,
                                      kv=enc_out, compute_dtype=cdt)
        x = x + c
        x = x + swiglu(lp["mlp"], rms_norm(lp["mlp_norm"], x, cfg.norm_eps), cdt)
        return x, (k, v, ck, cv)
    x, (kn, vn, ckn, cvn) = jax.lax.scan(body, x, params["decoder"])
    kv = {"k": kn, "v": vn}
    cross = {"k": ckn, "v": cvn, "enc_out": enc_out}
    return x, kv, cross

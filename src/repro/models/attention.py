"""Attention blocks: GQA / SWA / cross attention + KV caches.

Two execution paths exist for the core softmax-attention compute:

  * :func:`repro.kernels.ops.flash_attention` — the Pallas AMU kernel
    (TPU target, interpret-validated),
  * the chunked online-softmax implementation here (`_chunked_attention`)
    — pure jnp, O(S·C) peak memory, used for XLA lowering in the dry-run
    and as the CPU execution path.  Both agree with ``kernels/ref.py``.

The chunk loop is a ``lax.scan`` over KV blocks: exactly the AMU stream
pattern (fetch KV chunk → accumulate online softmax → next), so what the
Pallas kernel does with explicit DMA the XLA path does with scan.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import dense, dense_init, rms_norm, rms_norm_init, rope, mrope

Params = Dict[str, jnp.ndarray]

__all__ = [
    "attn_init", "attention_block", "decode_attention_block",
    "paged_decode_attention_block", "paged_prefill_block",
    "paged_verify_block", "one_token_attention", "multi_token_attention",
    "init_kv_cache", "init_paged_kv_cache",
    "chunked_attention", "NEG_INF",
]

NEG_INF = -1e30


def _gather_qkv_for_rope(q, k, v):
    """Work around a jax-0.4.37 SPMD miscompile: rope applied to a
    model-sharded projection comes out scaled by exactly the data-axis
    size on some mesh shapes (observed at (2, 4); see the ROADMAP
    record).  Decode/chunk projections are at most a few tokens per
    slot, so gathering them to replicated before rope costs noise next
    to the step's weight traffic.  No-op without an active mesh —
    single-device graphs (and the dense-vs-paged bit-exactness they
    anchor) are untouched."""
    from repro.dist import act_sharding as acts
    return (acts.constrain(q, P()), acts.constrain(k, P()),
            acts.constrain(v, P()))


def _pin_qkv_for_rope(q, k, v, seq_len: int):
    """The full-sequence (prefill / train) variant of the same SPMD
    workaround.  Replicating a whole 32k-token projection per layer —
    what the decode path does — would be a real cost here, so instead
    q/k/v are *pinned to an explicit layout* through rope: the layout
    :func:`chunked_attention`'s plan would pick anyway (head-sharded
    over the model axis, or sequence-sharded under a Megatron-SP
    residual), falling back to heads-over-model when no plan is active
    (GSPMD pads a non-dividing head count).  The explicit annotation is
    what stops the partitioner from mis-placing the rope subgraph; no
    data moves that attention would not have moved anyway.  No-op
    without a mesh or without a model axis."""
    from repro.dist import act_sharding as acts
    if acts.model_axis_size() <= 1:
        return q, k, v
    pol = acts.current()
    dp = acts.dp_spec_prefix()
    plan = acts.attn_plan(q.shape[2], k.shape[2], seq_len)
    if plan is not None and plan[0] == "seq":
        spec = P(dp, plan[1], None, None)
    else:
        spec = P(dp, None, pol.model_axis, None)
    return (acts.constrain(q, spec), acts.constrain(k, spec),
            acts.constrain(v, spec))


# -- parameter init -------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    p = {
        "q": dense_init(kq, d, cfg.num_heads * hd, dtype=dtype),
        "k": dense_init(kk, d, cfg.num_kv_heads * hd, dtype=dtype),
        "v": dense_init(kv, d, cfg.num_kv_heads * hd, dtype=dtype),
        "o": dense_init(ko, cfg.num_heads * hd, d, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rms_norm_init(hd, dtype)
        p["k_norm"] = rms_norm_init(hd, dtype)
    return p


# -- chunked online-softmax attention ----------------------------------------------

def chunked_attention(
    q: jnp.ndarray,            # (B, Sq, H, D)
    k: jnp.ndarray,            # (B, Skv, Hkv, D)
    v: jnp.ndarray,            # (B, Skv, Hkv, D)
    *,
    causal: bool = True,
    window: int = 0,           # SWA: attend to [i-window+1, i]
    q_offset=0,                # absolute position of q[0]: int, or (B,) array
    chunk: int = 1024,
    kv_valid_len: Optional[jnp.ndarray] = None,   # mask KV beyond this
) -> jnp.ndarray:
    """Numerically-stable blockwise attention, peak memory O(Sq·chunk).

    ``q_offset`` and ``kv_valid_len`` accept per-row ``(B,)`` arrays in
    addition to scalars — the chunked paged-prefill path mixes prompt
    chunks of different sequences (each at its own absolute offset) in
    one batch.  The scalar path traces exactly the same graph as before
    the per-row variant existed, so dense prefill stays bit-identical.

    Two execution modes, selected by :mod:`repro.dist.act_sharding`:

    * baseline (paper-faithful run): GQA-grouped einsums with f32 operand
      upcast; activation placement left to GSPMD;
    * optimized (``--opt``): operands stay in their native dtype with f32
      accumulation, and the layout is constrained explicitly — head-
      sharded when H divides the model axis (K/V repeated to H locally),
      else query-sequence-sharded — so no collective ever appears inside
      the KV-chunk loop.
    """
    from repro.dist import act_sharding as acts

    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    pol = acts.current()
    plan = acts.attn_plan(H, Hkv, Sq)
    dp = acts.dp_spec_prefix()

    if plan is not None and plan[0] == "heads":
        ax = plan[1]
        if g > 1:
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        spec = P(dp, None, ax, None)
        q = acts.constrain(q, spec)
        k = acts.constrain(k, spec)
        v = acts.constrain(v, spec)
        out = _chunked_core(q, k, v, grouped=False, causal=causal,
                            window=window, q_offset=q_offset, chunk=chunk,
                            kv_valid_len=kv_valid_len,
                            native_dtype=pol.native_dtype)
        return acts.constrain(out, spec)

    if plan is not None and plan[0] == "seq":
        ax = plan[1]
        q = acts.constrain(q, P(dp, ax, None, None))
        k = acts.constrain(k, P(dp, None, None, None))
        v = acts.constrain(v, P(dp, None, None, None))
        out = _chunked_core(q, k, v, grouped=True, causal=causal,
                            window=window, q_offset=q_offset, chunk=chunk,
                            kv_valid_len=kv_valid_len,
                            native_dtype=pol.native_dtype)
        return acts.constrain(out, P(dp, ax, None, None))

    return _chunked_core(q, k, v, grouped=True, causal=causal,
                         window=window, q_offset=q_offset, chunk=chunk,
                         kv_valid_len=kv_valid_len,
                         native_dtype=pol.native_dtype)


def _chunked_core(q, k, v, *, grouped: bool, causal, window, q_offset,
                  chunk, kv_valid_len, native_dtype: bool):
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    scale = 1.0 / math.sqrt(D)
    orig_dtype = q.dtype
    opd = orig_dtype if native_dtype else jnp.float32   # einsum operand dtype

    chunk = min(chunk, Skv)
    n_chunks = -(-Skv // chunk)
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    # reshape kv to (n_chunks, B, chunk, Hkv, D) for scan
    kc = k.reshape(B, n_chunks, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)

    if grouped:
        qs = q.astype(opd).reshape(B, Sq, Hkv, g, D)
        s_eq, pv_eq = "bqhgd,bkhd->bqhgk", "bqhgk,bkhd->bqhgd"
        acc_shape, red_shape = (B, Sq, Hkv, g, D), (B, Sq, Hkv, g)
    else:
        qs = q.astype(opd)
        s_eq, pv_eq = "bqhd,bkhd->bqhk", "bqhk,bkhd->bqhd"
        acc_shape, red_shape = (B, Sq, H, D), (B, Sq, H)
    # per-row offsets / valid lengths get a (B, Sq, chunk) mask; the
    # scalar path keeps its original (Sq, chunk) mask (and graph)
    per_row = (getattr(q_offset, "ndim", 0) > 0
               or getattr(kv_valid_len, "ndim", 0) > 0)
    if per_row:
        q_pos = (jnp.asarray(q_offset).reshape(-1, 1)
                 + jnp.arange(Sq))                       # (B or 1, Sq)
    else:
        q_pos = q_offset + jnp.arange(Sq)                # (Sq,)

    def body(carry, xs):
        acc, m, l = carry
        ci, kci, vci = xs
        kv_pos = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum(s_eq, qs, kci.astype(opd),
                       preferred_element_type=jnp.float32) * scale
        if per_row:
            mask = jnp.ones((q_pos.shape[0], Sq, chunk), bool)
            if causal:
                mask &= q_pos[..., None] >= kv_pos[None, None, :]
            if window:
                mask &= kv_pos[None, None, :] > q_pos[..., None] - window
            if kv_valid_len is not None:
                vl = jnp.asarray(kv_valid_len).reshape(-1, 1, 1)
                mask = mask & (kv_pos[None, None, :] < vl)
            mask = mask & (kv_pos < Skv)[None, None, :]  # padding chunk tail
            bmask = (mask[:, :, None, None, :] if grouped
                     else mask[:, :, None, :])
        else:
            mask = jnp.ones((Sq, chunk), bool)
            if causal:
                mask &= q_pos[:, None] >= kv_pos[None, :]
            if window:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
            if kv_valid_len is not None:
                mask = mask & (kv_pos[None, :] < kv_valid_len)
            mask = mask & (kv_pos < Skv)[None, :]        # padding chunk tail
            bmask = (mask[None, :, None, None, :] if grouped
                     else mask[None, :, None, :])
        s = jnp.where(bmask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum(pv_eq, p.astype(opd), vci.astype(opd),
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l), None

    acc0 = jnp.zeros(acc_shape, jnp.float32)
    m0 = jnp.full(red_shape, NEG_INF, jnp.float32)
    l0 = jnp.zeros(red_shape, jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, D).astype(orig_dtype)


# -- full attention block (prefill / train) -------------------------------------------

def _project_qkv(p: Params, cfg: ModelConfig, x: jnp.ndarray, compute_dtype):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = dense(p["q"], x, compute_dtype).reshape(B, S, cfg.num_heads, hd)
    k = dense(p["k"], x, compute_dtype).reshape(B, S, cfg.num_kv_heads, hd)
    v = dense(p["v"], x, compute_dtype).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)
    return q, k, v


def _position_encode(cfg: ModelConfig, q, k, positions):
    if cfg.attention == "none":
        return q, k
    if cfg.mrope_sections:
        q = mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k


def attention_block(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,                       # (B, S, d)
    positions: jnp.ndarray,               # (B, S) or (3, B, S) for mrope
    *,
    causal: bool = True,
    kv: Optional[jnp.ndarray] = None,     # cross-attention source (B, Skv, d)
    compute_dtype=jnp.bfloat16,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Full-sequence attention; returns (out, (k, v)) so prefill can cache."""
    B, S, _ = x.shape
    hd = cfg.head_dim
    if kv is None:
        q, k, v = _project_qkv(p, cfg, x, compute_dtype)
        q, k, v = _pin_qkv_for_rope(q, k, v, S)
        q, k = _position_encode(cfg, q, k, positions)
    else:  # cross attention: k/v from encoder output, no rope on cross path
        q = dense(p["q"], x, compute_dtype).reshape(B, S, cfg.num_heads, hd)
        Skv = kv.shape[1]
        k = dense(p["k"], kv, compute_dtype).reshape(B, Skv, cfg.num_kv_heads, hd)
        v = dense(p["v"], kv, compute_dtype).reshape(B, Skv, cfg.num_kv_heads, hd)
    out = chunked_attention(
        q, k, v,
        causal=causal and kv is None,
        window=cfg.window if cfg.attention == "swa" else 0,
    )
    out = out.reshape(B, S, cfg.num_heads * hd)
    return dense(p["o"], out, compute_dtype), (k, v)


# -- decode path -----------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  n_layers: Optional[int] = None,
                  dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    """Stacked-over-layers KV cache.  SWA archs use a ring buffer of
    ``window`` slots (decode cost independent of context length)."""
    L = n_layers if n_layers is not None else cfg.num_layers
    slots = min(max_len, cfg.window) if cfg.attention == "swa" else max_len
    shape = (L, batch, slots, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((), jnp.int32),     # absolute position of next token
        "slots": jnp.asarray(slots, jnp.int32),
    }


def decode_attention_block(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,                      # (B, 1, d)
    layer_cache: Tuple[jnp.ndarray, jnp.ndarray],   # k,v (B, slots, Hkv, D)
    pos: jnp.ndarray,                    # (B,) int32: per-sequence position
    *,
    compute_dtype=jnp.bfloat16,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """One-token attention against the cache; returns (out, new (k,v)).

    ``pos`` is per sequence so continuous batching can mix requests at
    different depths in one decode step."""
    B = x.shape[0]
    hd = cfg.head_dim
    kc, vc = layer_cache
    slots = kc.shape[1]
    q, k_new, v_new = _project_qkv(p, cfg, x, compute_dtype)
    q, k_new, v_new = _gather_qkv_for_rope(q, k_new, v_new)
    pos = jnp.broadcast_to(pos, (B,))
    posv = pos[:, None]                              # (B, 1)
    if cfg.mrope_sections:
        pos3 = jnp.broadcast_to(posv, (3, B, 1))
        q, k_new = _position_encode(cfg, q, k_new, pos3)
    else:
        q, k_new = _position_encode(cfg, q, k_new, posv)
    slot = (pos % slots if cfg.attention == "swa"
            else jnp.minimum(pos, slots - 1))        # (B,)
    kc = kc.at[jnp.arange(B), slot].set(k_new[:, 0].astype(kc.dtype))
    vc = vc.at[jnp.arange(B), slot].set(v_new[:, 0].astype(vc.dtype))
    valid = jnp.minimum(pos + 1, slots)              # (B,)
    out = _one_token_attention(cfg, q, kc, vc, valid)
    out = out.astype(compute_dtype)
    return dense(p["o"], out, compute_dtype), (kc, vc)


def one_token_attention(q, kc, vc, valid, num_kv_heads: int):
    """One-query-token attention over a dense (B, Skv, Hkv, D) cache.

    THE XLA reference for decode attention: shared by the dense decode
    block and (through ``kernels.ops.paged_decode_attention``'s gather)
    the paged block, so the two layouts stay bit-exact — identical
    expressions, identical shapes.  ``q``: (B, H, D); ``valid``: (B,)
    masks KV positions at/past it.  Returns f32 (B, 1, H * D).
    """
    B, H, hd = q.shape
    slots = kc.shape[1]
    qf = (q.astype(jnp.float32) * (1.0 / math.sqrt(hd)))
    qf = qf.reshape(B, num_kv_heads, H // num_kv_heads, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, kc.astype(jnp.float32))
    kv_idx = jnp.arange(slots)
    s = jnp.where((kv_idx[None, :] < valid[:, None])[:, None, None, :],
                  s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", w, vc.astype(jnp.float32))
    return out.reshape(B, 1, H * hd)


def _one_token_attention(cfg: ModelConfig, q, kc, vc, valid):
    """cfg-typed wrapper: ``q`` is the block's (B, 1, H, D) projection."""
    B = q.shape[0]
    return one_token_attention(q.reshape(B, cfg.num_heads, cfg.head_dim),
                               kc, vc, valid, cfg.num_kv_heads)


def multi_token_attention(q, kc, vc, valid, num_kv_heads: int):
    """S-query-row attention over a dense (B, Skv, Hkv, D) cache.

    :func:`one_token_attention` generalised to ``S`` query rows per
    sequence — the XLA reference for speculative verify-K decode.  The
    expression chain is kept IDENTICAL to the one-token path (scale
    before the score einsum, mask, softmax, then the value einsum) with
    one extra batch axis, because token-exactness of speculative decode
    rests on row ``s`` here being bit-equal to what a sequential
    one-token step at position ``pos + s`` would compute.  Online-
    softmax variants (``_chunked_core``) are NOT bit-compatible — they
    normalise after the value product.

    ``q``: (B, S, H, D); ``valid``: (B, S) masks KV positions at/past it
    independently per row (row ``s`` of a verify step may see ``s`` more
    tokens than row 0).  Returns f32 (B, S, H * D).
    """
    B, S, H, hd = q.shape
    slots = kc.shape[1]
    qf = (q.astype(jnp.float32) * (1.0 / math.sqrt(hd)))
    qf = qf.reshape(B, S, num_kv_heads, H // num_kv_heads, hd)
    s = jnp.einsum("bshgd,bkhd->bshgk", qf, kc.astype(jnp.float32))
    kv_idx = jnp.arange(slots)
    s = jnp.where((kv_idx[None, None, :]
                   < valid[:, :, None])[:, :, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bshgk,bkhd->bshgd", w, vc.astype(jnp.float32))
    return out.reshape(B, S, H * hd)


# -- paged decode path (repro.paging pool layout) ---------------------------------


def init_paged_kv_cache(cfg: ModelConfig, n_frames: int, page_size: int,
                        batch: int, max_len: int,
                        n_layers: Optional[int] = None,
                        dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    """KV cache in the ``repro.paging`` device-pool layout.

    Instead of one dense per-slot buffer, k/v live in ``n_frames``
    physical page frames of ``page_size`` token positions, stacked over
    layers — one frame holds a page's K or V *for every layer*, matching
    the engine's transfer unit.  ``page_table`` maps each decode slot's
    logical pages to frames; rows are initialised to ``n_frames - 1``,
    which callers should reserve as the trash frame (writes from empty
    slots land there, reads are masked by per-sequence lengths).

    The per-sequence token capacity must be an exact multiple of
    ``page_size`` so the gathered view of a sequence is shape-identical
    to the dense cache (bit-exactness depends on it).
    """
    L = n_layers if n_layers is not None else cfg.num_layers
    slots = min(max_len, cfg.window) if cfg.attention == "swa" else max_len
    if slots % page_size:
        raise ValueError(
            f"page_size {page_size} must divide the per-sequence token "
            f"capacity {slots} for the paged decode layout")
    pages_per_seq = slots // page_size
    shape = (L, n_frames, page_size, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k_pages": jnp.zeros(shape, dtype),
        "v_pages": jnp.zeros(shape, dtype),
        "page_table": jnp.full((batch, pages_per_seq), n_frames - 1,
                               jnp.int32),
    }


def paged_decode_attention_block(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,                      # (B, 1, d)
    layer_pages: Tuple[jnp.ndarray, jnp.ndarray],  # k,v (N, page, Hkv, D)
    page_table: jnp.ndarray,             # (B, pages_per_seq) int32 frame ids
    pos: jnp.ndarray,                    # (B,) int32: per-sequence position
    *,
    compute_dtype=jnp.bfloat16,
    impl: str = "auto",
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """One-token attention computing directly on the paged KV layout.

    The paged counterpart of :func:`decode_attention_block`: the new
    token's K/V is scattered straight into its page-table-mapped pool
    frame (no dense per-slot cache exists at all), and attention reads
    the pool through the page table — the Pallas scalar-prefetch gather
    kernel on TPU, a ``jnp.take`` gather under XLA.  The XLA path's
    gathered view is sliced to the exact dense-cache shape and fed
    through the same expressions as the dense block, so outputs are
    bit-exact with an uninterrupted dense decode.
    """
    from repro.kernels import ops

    B = x.shape[0]
    hd = cfg.head_dim
    kp, vp = layer_pages
    page = kp.shape[1]
    slots = page_table.shape[1] * page           # token capacity per sequence
    q, k_new, v_new = _project_qkv(p, cfg, x, compute_dtype)
    q, k_new, v_new = _gather_qkv_for_rope(q, k_new, v_new)
    pos = jnp.broadcast_to(pos, (B,))
    posv = pos[:, None]                              # (B, 1)
    if cfg.mrope_sections:
        pos3 = jnp.broadcast_to(posv, (3, B, 1))
        q, k_new = _position_encode(cfg, q, k_new, pos3)
    else:
        q, k_new = _position_encode(cfg, q, k_new, posv)
    slot = (pos % slots if cfg.attention == "swa"
            else jnp.minimum(pos, slots - 1))        # (B,)
    frame = page_table[jnp.arange(B), slot // page]  # (B,) physical frames
    row = slot % page
    kp = kp.at[frame, row].set(k_new[:, 0].astype(kp.dtype))
    vp = vp.at[frame, row].set(v_new[:, 0].astype(vp.dtype))
    valid = jnp.minimum(pos + 1, slots)              # (B,)

    # one dispatcher for every backend: ops' XLA fallback gathers the
    # dense view and runs one_token_attention — the same expressions as
    # the dense block, so paged-vs-dense stays bit-exact
    out = ops.paged_decode_attention(
        q[:, 0], kp, vp, page_table, valid, impl=impl)
    out = out.reshape(B, 1, cfg.num_heads * hd).astype(compute_dtype)
    return dense(p["o"], out, compute_dtype), (kp, vp)


def paged_verify_block(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,                      # (B, S, d)
    layer_pages: Tuple[jnp.ndarray, jnp.ndarray],  # k,v (N, page, Hkv, D)
    page_table: jnp.ndarray,             # (B, pages_per_seq) int32 frame ids
    pos: jnp.ndarray,                    # (B,) int32: position of x[:, 0]
    length: jnp.ndarray,                 # (B,) valid rows in x (0 = inert)
    *,
    compute_dtype=jnp.bfloat16,
    impl: str = "auto",
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Verify-K attention for self-speculative decode on the paged pool.

    :func:`paged_decode_attention_block` generalised to ``S = K + 1``
    query rows per slot: row 0 carries the last committed token, rows
    1..K the drafted continuation.  All valid rows' K/V scatter into the
    page-table-mapped frames exactly as ``S`` sequential decode steps
    would (row ``s`` lands at position ``pos + s``); rows at/past
    ``length`` scatter to the trash frame, so a slot whose draft was
    capped (or an empty slot, ``length == 0``) never dirties real
    frames.  Attention then reads the pool with a per-row valid length
    ``min(pos + s + 1, slots)`` — row ``s`` sees its own K/V and every
    draft row before it, the causal view a sequential decode would have.

    Bit-exactness contract: for any row ``s < length`` whose prefix
    d_1..d_s matches greedy decode, the returned logits row is
    bit-equal to the logits of the s-th sequential
    :func:`paged_decode_attention_block` step — the XLA path defers to
    :func:`multi_token_attention`, the one-token reference's exact
    expressions.  Callers must ensure ``pos + length <= slots``; this
    block has no SWA ring semantics (speculation is gated off for SWA).
    """
    from repro.kernels import ops

    B, S, _ = x.shape
    hd = cfg.head_dim
    kp, vp = layer_pages
    page = kp.shape[1]
    pages_per_seq = page_table.shape[1]
    slots = pages_per_seq * page                 # token capacity per sequence
    trash = kp.shape[0] - 1
    q, k_new, v_new = _project_qkv(p, cfg, x, compute_dtype)
    q, k_new, v_new = _gather_qkv_for_rope(q, k_new, v_new)
    pos = jnp.broadcast_to(pos, (B,))
    abs_pos = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]  # (B, S)
    if cfg.mrope_sections:
        pos3 = jnp.broadcast_to(abs_pos, (3, B, S))
        q, k_new = _position_encode(cfg, q, k_new, pos3)
    else:
        q, k_new = _position_encode(cfg, q, k_new, abs_pos)

    # scatter like paged_prefill_block: draft row s of slot b lands at
    # absolute position pos[b] + s -> (frame, row-in-page); rows past
    # `length` go to the trash frame (the rejected-tail scatter target)
    in_draft = jnp.arange(S, dtype=jnp.int32)[None, :] < length[:, None]
    ok = in_draft & (abs_pos < slots)
    page_idx = jnp.clip(abs_pos // page, 0, pages_per_seq - 1)
    frame = jnp.where(ok, jnp.take_along_axis(page_table, page_idx, axis=1),
                      trash)                     # (B, S)
    row = abs_pos % page
    kp = kp.at[frame, row].set(k_new.astype(kp.dtype))
    vp = vp.at[frame, row].set(v_new.astype(vp.dtype))
    valid = jnp.minimum(abs_pos + 1, slots)      # (B, S) per-row causal view

    out = ops.paged_verify_attention(
        q, kp, vp, page_table, valid, impl=impl)
    out = out.reshape(B, S, cfg.num_heads * hd).astype(compute_dtype)
    return dense(p["o"], out, compute_dtype), (kp, vp)


# -- chunked paged prefill (prompt chunks computed on the pool layout) -----------


def paged_prefill_block(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,                      # (C, T, d) pre-normed chunk hidden
    layer_pages: Tuple[jnp.ndarray, jnp.ndarray],  # k,v (N, page, Hkv, D)
    page_rows: jnp.ndarray,              # (C, pages_per_seq) int32 frame ids
    offset: jnp.ndarray,                 # (C,) absolute position of x[:, 0]
    length: jnp.ndarray,                 # (C,) valid tokens in this chunk
    positions: jnp.ndarray,              # (C, T) or (3, C, T) absolute pos
    *,
    compute_dtype=jnp.bfloat16,
    impl: str = "auto",
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """One prompt-chunk attention computing directly on the paged layout.

    The prefill counterpart of :func:`paged_decode_attention_block` and
    the kernel-level heart of chunked paged prefill (the follow-up AMU
    paper's massive-MLP serving pattern, 2404.11044 §4): each row of
    ``x`` is one admitting sequence's next prompt chunk, flash-attended
    against that sequence's pool-resident KV prefix *while its own K/V
    is scattered straight into the mapped pool frames* — no dense
    per-sequence KV buffer ever exists, not even during prefill.

    Chunk rows are independent sequences at independent depths:
    ``offset`` gives each row's absolute start position (RoPE and the
    causal mask both honour it) and ``length`` its valid token count —
    tail padding beyond ``length`` writes to the trash frame
    (``n_frames - 1``, same convention as empty decode slots) and its
    outputs are discarded by the caller.  The XLA path gathers the
    page-table view and runs the same ``chunked_attention`` expressions
    as dense prefill, so a chunked prefill's tokens match an
    uninterrupted dense prefill's.
    """
    from repro.kernels import ops

    C, T, _ = x.shape
    hd = cfg.head_dim
    kp, vp = layer_pages
    page = kp.shape[1]
    pages_per_seq = page_rows.shape[1]
    slots = pages_per_seq * page                 # token capacity per sequence
    trash = kp.shape[0] - 1
    q, k_new, v_new = _project_qkv(p, cfg, x, compute_dtype)
    q, k_new, v_new = _gather_qkv_for_rope(q, k_new, v_new)
    q, k_new = _position_encode(cfg, q, k_new, positions)

    # scatter the chunk's K/V into its mapped pool frames: token t of row
    # c lands at absolute position offset[c] + t -> (frame, row-in-page)
    abs_pos = offset[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    in_chunk = jnp.arange(T, dtype=jnp.int32)[None, :] < length[:, None]
    ok = in_chunk & (abs_pos < slots)
    page_idx = jnp.clip(abs_pos // page, 0, pages_per_seq - 1)
    frame = jnp.where(ok, jnp.take_along_axis(page_rows, page_idx, axis=1),
                      trash)                     # (C, T)
    row = abs_pos % page
    kp = kp.at[frame, row].set(k_new.astype(kp.dtype))
    vp = vp.at[frame, row].set(v_new.astype(vp.dtype))

    out = ops.paged_prefill_attention(
        q, kp, vp, page_rows, offset, length,
        window=cfg.window if cfg.attention == "swa" else 0, impl=impl)
    out = out.reshape(C, T, cfg.num_heads * hd).astype(compute_dtype)
    return dense(p["o"], out, compute_dtype), (kp, vp)

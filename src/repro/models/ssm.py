"""Linear-recurrence blocks: RWKV6 ("Finch") and Mamba2 (SSD).

Both blocks stream a fixed-size recurrent state over the sequence — the
AMU stream pattern at model level (state stays in SPM/VMEM, token chunks
stream through).  Each has three forms:

  * ``*_sequential`` — O(T) scan over single tokens: the oracle,
  * ``*_chunked``    — chunked parallel form used for train/prefill
    (intra-chunk pairwise decay einsum + inter-chunk state scan);
    numerically safe because pairwise exponents ``W_t - W_s (s<=t)`` are
    always <= 0,
  * ``*_step``       — one-token decode carrying (state, shift/conv state).

The Pallas kernels in ``repro/kernels/{rwkv6,mamba2}.py`` implement the
chunked forms with explicit VMEM tiling; these jnp versions are their
oracles and the XLA lowering path.
"""

from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (dense, dense_init, layer_norm,
                                 layer_norm_init, rms_norm)

Params = Dict[str, jnp.ndarray]

__all__ = [
    "rwkv6_init", "rwkv6_time_mix", "rwkv6_channel_mix", "rwkv6_block",
    "rwkv6_step", "rwkv6_state_init",
    "mamba2_init", "mamba2_block", "mamba2_step", "mamba2_state_init",
    "wkv6_chunked", "wkv6_sequential", "ssd_chunked", "ssd_sequential",
]


# ===========================================================================
# RWKV6
# ===========================================================================

def rwkv6_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    H, K = cfg.num_heads, cfg.head_dim
    lora = 64
    keys = jax.random.split(key, 12)
    s = 1.0 / math.sqrt(d)
    return {
        "ln1": layer_norm_init(d, dtype),
        "ln2": layer_norm_init(d, dtype),
        # token-shift lerp coefficients
        "mu": {n: jnp.full((d,), 0.5, dtype) for n in ("r", "k", "v", "g", "w")},
        "Wr": dense_init(keys[0], d, H * K, dtype=dtype),
        "Wk": dense_init(keys[1], d, H * K, dtype=dtype),
        "Wv": dense_init(keys[2], d, H * K, dtype=dtype),
        "Wg": dense_init(keys[3], d, H * K, dtype=dtype),
        "Wo": dense_init(keys[4], H * K, d, dtype=dtype),
        # data-dependent decay: w_t = -exp(w0 + tanh(x A) B)
        "w0": jnp.full((H * K,), -2.0, dtype),
        "wA": jax.random.normal(keys[5], (d, lora), dtype) * s * 0.1,
        "wB": jax.random.normal(keys[6], (lora, H * K), dtype) * 0.01,
        "u": jax.random.normal(keys[7], (H, K), dtype) * 0.1,   # bonus
        "gn": {"scale": jnp.ones((H, K), dtype), "bias": jnp.zeros((H, K), dtype)},
        # channel mix
        "cm_mu": {n: jnp.full((d,), 0.5, dtype) for n in ("k", "r")},
        "cWk": dense_init(keys[8], d, ff, dtype=dtype),
        "cWv": dense_init(keys[9], ff, d, dtype=dtype),
        "cWr": dense_init(keys[10], d, d, dtype=dtype),
    }


def _token_shift(x: jnp.ndarray, x_prev: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """x_{t-1} with x_prev (B, d) filling t=0 (zeros if None)."""
    B, T, d = x.shape
    first = (jnp.zeros((B, 1, d), x.dtype) if x_prev is None
             else x_prev[:, None].astype(x.dtype))
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _lerp(x, xx, mu):
    return x + (xx - x) * mu.astype(x.dtype)


def wkv6_sequential(r, k, v, w, u):
    """Oracle WKV6: per-head recurrence.

    r,k,w: (B,T,H,K); v: (B,T,H,V); u: (H,K); w = log-decay (<=0).
    Returns o: (B,T,H,V).  o_t = S_{t-1}^T r_t + (r_t . (u*k_t)) v_t;
    S_t = diag(e^{w_t}) S_{t-1} + k_t v_t^T.
    """
    B, T, H, K = r.shape
    V = v.shape[-1]
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(S, xs):
        rt, kt, vt, wt = xs                       # (B,H,K)/(B,H,V)
        o = jnp.einsum("bhkv,bhk->bhv", S, rt)
        o = o + jnp.einsum("bhk,bhk->bh", rt, uf * kt)[..., None] * vt
        S = jnp.exp(wt)[..., None] * S + kt[..., None] * vt[..., None, :]
        return S, o

    S0 = jnp.zeros((B, H, K, V), jnp.float32)
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    _, o = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(o, 0, 1).astype(r.dtype)


#: factorized mode clamps in-chunk cumulative log-decay at -_WKV_CLAMP:
#: contributions decayed below e^-20 are numerically zero anyway, and the
#: clamp keeps exp(-W) <= e^20 (safe in f32 AND bf16).
_WKV_CLAMP = 20.0


def wkv6_chunked(r, k, v, w, u, *, chunk: int = 64,
                 initial_state: Optional[jnp.ndarray] = None,
                 return_state: bool = False,
                 mode: str = "pairwise",
                 operand_dtype=None):
    """Chunked WKV6 (GLA-style): intra-chunk decay + state scan.

    ``mode``:
      * ``"pairwise"``  — materialises the exact (c, c, K) pairwise decay
        tensor (paper-faithful baseline; always stable since exponents
        are <= 0),
      * ``"factorized"``— att = (r * e^{W_prev}) @ (k * e^{-W})^T with the
        exponent clamped at ``_WKV_CLAMP``: two MXU matmuls, no (c,c,K)
        tensor — ~K x less intra-chunk HBM traffic.  Error bounded by
        dropping contributions decayed below e^-20.
    """
    B, T, H, K = r.shape
    V = v.shape[-1]
    c = min(chunk, T)
    if T % c:
        pad = c - T % c
        r, k, v, w = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
                      for a in (r, k, v, w))
    else:
        pad = 0
    Tp = T + pad
    n = Tp // c
    opd = operand_dtype or jnp.float32      # matmul operand dtype
    rf, kf, vf = (a.astype(opd).reshape(B, n, c, H, -1)
                  .transpose(1, 0, 2, 3, 4) for a in (r, k, v))
    # the decay path stays f32: cumsum+exp precision sets state fidelity
    wf = w.astype(jnp.float32).reshape(B, n, c, H, -1).transpose(1, 0, 2, 3, 4)
    uf = u.astype(jnp.float32)

    def per_chunk(S, xs):
        rc, kc, vc, wc = xs                       # (B,c,H,K|V); wc is f32
        Wc = jnp.cumsum(wc, axis=1)               # inclusive cumulative log decay
        # inter-chunk: o_t += (r_t * e^{W_{t-1}}) . S_in  (decay through t-1)
        # S_{t-1} = e^{W_{t-1}} applied to S_in + intra terms; W_{t-1} = Wc - wc
        decay_q = jnp.exp(Wc - wc).astype(opd)    # e^{W_{t-1}} <= 1
        o_inter = jnp.einsum("bthk,bhkv->bthv", rc * decay_q, S,
                             preferred_element_type=jnp.float32)
        mask = (jnp.arange(c)[:, None] > jnp.arange(c)[None, :])
        if mode == "factorized":
            # att[t,s] = sum_k (r e^{Wprev})_t (k e^{-W})_s — two matmuls,
            # exponent clamped so e^{-W} stays finite (even in bf16)
            rp = rc * jnp.exp(jnp.maximum(Wc - wc, -_WKV_CLAMP)).astype(opd)
            kp = kc * jnp.exp(-jnp.maximum(Wc, -_WKV_CLAMP)).astype(opd)
            att = jnp.einsum("bthk,bshk->btsh", rp, kp,
                             preferred_element_type=jnp.float32)
            att = att * mask[None, :, :, None]
        else:
            # intra-chunk pairwise: s < t strictly; decay e^{W_{t-1} - W_s}
            pair = (Wc - wc)[:, :, None] - Wc[:, None, :, :]   # (B,t,s,H,K)
            pairdec = (jnp.exp(jnp.minimum(pair, 0.0))
                       * mask[None, :, :, None, None]).astype(opd)
            att = jnp.einsum("bthk,btshk,bshk->btsh", rc, pairdec, kc,
                             preferred_element_type=jnp.float32)
        o_intra = jnp.einsum("btsh,bshv->bthv", att.astype(opd), vc,
                             preferred_element_type=jnp.float32)
        # bonus diagonal s = t
        o_diag = jnp.einsum("bthk,bthk->bth", rc, uf.astype(opd) * kc,
                            preferred_element_type=jnp.float32)[..., None] \
            * vc.astype(jnp.float32)
        # state update: S_out = e^{W_last} S + sum_s e^{W_last - W_s} k_s v_s^T
        Wl = Wc[:, -1][:, None]                   # (B,1,H,K)
        kdec = kc * jnp.exp(Wl - Wc).astype(opd)
        S = jnp.exp(Wl[:, 0])[..., None] * S + jnp.einsum(
            "bshk,bshv->bhkv", kdec, vc, preferred_element_type=jnp.float32)
        return S, o_inter + o_intra + o_diag

    S0 = (jnp.zeros((B, H, K, V), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    S, o = jax.lax.scan(per_chunk, S0, (rf, kf, vf, wf))
    o = o.transpose(1, 0, 2, 3, 4).reshape(B, Tp, H, V)[:, :T]
    o = o.astype(r.dtype)
    return (o, S) if return_state else o


def _rwkv6_rkvgw(p: Params, cfg: ModelConfig, x, xx, compute_dtype):
    B, T, d = x.shape
    H, K = cfg.num_heads, cfg.head_dim
    r = dense(p["Wr"], _lerp(x, xx, p["mu"]["r"]), compute_dtype).reshape(B, T, H, K)
    k = dense(p["Wk"], _lerp(x, xx, p["mu"]["k"]), compute_dtype).reshape(B, T, H, K)
    v = dense(p["Wv"], _lerp(x, xx, p["mu"]["v"]), compute_dtype).reshape(B, T, H, K)
    g = dense(p["Wg"], _lerp(x, xx, p["mu"]["g"]), compute_dtype)
    xw = _lerp(x, xx, p["mu"]["w"]).astype(jnp.float32)
    wlog = -jnp.exp(p["w0"].astype(jnp.float32)
                    + jnp.tanh(xw @ p["wA"].astype(jnp.float32))
                    @ p["wB"].astype(jnp.float32))
    w = wlog.reshape(B, T, H, K)
    return r, k, v, g, w


def _group_norm(gn: Params, o: jnp.ndarray, eps: float) -> jnp.ndarray:
    # o: (B,T,H,K) — per-head layer norm
    dtype = o.dtype
    of = o.astype(jnp.float32)
    mu = of.mean(-1, keepdims=True)
    var = of.var(-1, keepdims=True)
    y = (of - mu) * jax.lax.rsqrt(var + eps)
    return (y * gn["scale"] + gn["bias"]).astype(dtype)


def rwkv6_time_mix(p, cfg, x, *, x_prev=None, state=None, chunk=64,
                   return_state=False, compute_dtype=jnp.bfloat16):
    from repro.dist import act_sharding as acts
    B, T, d = x.shape
    H, K = cfg.num_heads, cfg.head_dim
    xx = _token_shift(x, x_prev)
    r, k, v, g, w = _rwkv6_rkvgw(p, cfg, x, xx, compute_dtype)
    pol = acts.current()
    mode = "factorized" if pol.ssm_factorized else "pairwise"
    if pol.ssm_head_shard:
        sizes = acts._mesh_axis_sizes() or {}
        m = sizes.get(pol.model_axis, 1)
        if m > 1 and H % m == 0:
            dp = acts.dp_spec_prefix()
            spec = jax.sharding.PartitionSpec(dp, None, pol.model_axis, None)
            r, k, v, w = (acts.constrain(a, spec) for a in (r, k, v, w))
    opd = compute_dtype if pol.native_dtype else None
    if return_state:
        o, S = wkv6_chunked(r, k, v, w, p["u"], chunk=chunk,
                            initial_state=state, return_state=True, mode=mode,
                            operand_dtype=opd)
    else:
        o = wkv6_chunked(r, k, v, w, p["u"], chunk=chunk, initial_state=state,
                         mode=mode, operand_dtype=opd)
        S = None
    o = _group_norm(p["gn"], o, cfg.norm_eps).reshape(B, T, H * K)
    out = dense(p["Wo"], o.astype(compute_dtype) * jax.nn.silu(g), compute_dtype)
    return (out, S) if return_state else out


def rwkv6_channel_mix(p, cfg, x, *, x_prev=None, compute_dtype=jnp.bfloat16):
    from repro.dist import act_sharding as acts
    xx = _token_shift(x, x_prev)
    kx = _lerp(x, xx, p["cm_mu"]["k"])
    rx = _lerp(x, xx, p["cm_mu"]["r"])
    kk = jnp.square(jax.nn.relu(dense(p["cWk"], kx, compute_dtype)))
    pol = acts.current()
    if pol.ssm_head_shard and x.shape[1] > 1:
        # keep the ff-wide intermediate column-sharded through backward
        # (full-sequence path only: on a 1-token decode the constraint
        # just adds a reshard)
        kk = acts.constrain(kk, jax.sharding.PartitionSpec(
            acts.dp_spec_prefix(), None, pol.model_axis))
    return jax.nn.sigmoid(dense(p["cWr"], rx, compute_dtype)) * \
        dense(p["cWv"], kk, compute_dtype)


def rwkv6_block(p, cfg, x, *, compute_dtype=jnp.bfloat16):
    """Full-sequence RWKV6 layer (train/prefill)."""
    from repro.dist import act_sharding as acts
    rspec = acts.residual_spec(x.shape[1])
    if rspec is not None:
        x = acts.constrain(x, rspec)
    x = x + rwkv6_time_mix(p, cfg, layer_norm(p["ln1"], x, cfg.norm_eps),
                           compute_dtype=compute_dtype)
    x = x + rwkv6_channel_mix(p, cfg, layer_norm(p["ln2"], x, cfg.norm_eps),
                              compute_dtype=compute_dtype)
    if rspec is not None:
        x = acts.constrain(x, rspec)
    return x


class RWKVState(NamedTuple):
    S: jnp.ndarray          # (B, H, K, V) wkv state
    tm_prev: jnp.ndarray    # (B, d) last input to time mix
    cm_prev: jnp.ndarray    # (B, d) last input to channel mix


def rwkv6_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> RWKVState:
    H, K = cfg.num_heads, cfg.head_dim
    return RWKVState(
        S=jnp.zeros((batch, H, K, K), jnp.float32),
        tm_prev=jnp.zeros((batch, cfg.d_model), dtype),
        cm_prev=jnp.zeros((batch, cfg.d_model), dtype),
    )


def rwkv6_step(p, cfg, x, state: RWKVState, *, compute_dtype=jnp.bfloat16):
    """One-token decode.  x: (B, 1, d)."""
    B = x.shape[0]
    H, K = cfg.num_heads, cfg.head_dim
    xn = layer_norm(p["ln1"], x, cfg.norm_eps)
    xx = state.tm_prev[:, None].astype(xn.dtype)
    r, k, v, g, w = _rwkv6_rkvgw(p, cfg, xn, xx, compute_dtype)
    rt, kt, vt, wt = (a[:, 0].astype(jnp.float32) for a in (r, k, v, w))
    S = state.S
    o = jnp.einsum("bhkv,bhk->bhv", S, rt)
    o = o + jnp.einsum("bhk,bhk->bh", rt, p["u"].astype(jnp.float32) * kt)[..., None] * vt
    S = jnp.exp(wt)[..., None] * S + kt[..., None] * vt[..., None, :]
    o = _group_norm(p["gn"], o[:, None], cfg.norm_eps).reshape(B, 1, H * K)
    x = x + dense(p["Wo"], o.astype(compute_dtype) * jax.nn.silu(g), compute_dtype)
    xn2 = layer_norm(p["ln2"], x, cfg.norm_eps)
    x = x + rwkv6_channel_mix(p, cfg, xn2, x_prev=state.cm_prev,
                              compute_dtype=compute_dtype)
    new_state = RWKVState(S=S, tm_prev=xn[:, 0].astype(state.tm_prev.dtype),
                          cm_prev=xn2[:, 0].astype(state.cm_prev.dtype))
    return x, new_state


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================

def mamba2_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    di = cfg.d_inner
    N = cfg.ssm_state
    P = cfg.head_dim
    H = di // P
    conv_dim = di + 2 * N
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "norm": {"scale": jnp.ones((d,), dtype)},
        "in_proj": dense_init(k1, d, 2 * di + 2 * N + H, dtype=dtype),
        "conv_w": jax.random.normal(k2, (cfg.ssm_conv, conv_dim), dtype) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(dtype)),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "gate_norm": {"scale": jnp.ones((di,), dtype)},
        "out_proj": dense_init(k3, di, d, dtype=dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 conv_state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv.  x: (B,T,C); w: (W,C).  Returns (y, new_state)."""
    W = w.shape[0]
    if conv_state is None:
        ctx = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        ctx = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    y = sum(ctx[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(W))
    y = jax.nn.silu(y + b.astype(x.dtype))
    new_state = ctx[:, -(W - 1):] if W > 1 else None
    return y, new_state


def ssd_sequential(x, dt, A, B, C, D):
    """Oracle SSD.  x:(B,T,H,P) dt:(B,T,H) A:(H,) B,C:(B,T,N).

    S_t = e^{-A dt_t} S_{t-1} + dt_t B_t x_t^T; y_t = C_t^T S_t + D x_t.
    """
    Bb, T, H, P = x.shape
    N = B.shape[-1]
    xf, dtf, Bf, Cf = (a.astype(jnp.float32) for a in (x, dt, B, C))
    Af, Df = A.astype(jnp.float32), D.astype(jnp.float32)

    def step(S, xs):
        xt, dtt, Bt, Ct = xs
        a = jnp.exp(-Af * dtt)                        # (B,H)
        S = a[..., None, None] * S + jnp.einsum(
            "bn,bhp,bh->bhnp", Bt, xt, dtt)
        y = jnp.einsum("bn,bhnp->bhp", Ct, S) + Df[:, None] * xt
        return S, y

    S0 = jnp.zeros((Bb, H, N, P), jnp.float32)
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (xf, dtf, Bf, Cf))
    S, y = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(y, 0, 1).astype(x.dtype), S


def ssd_chunked(x, dt, A, B, C, D, *, chunk: int = 128,
                initial_state: Optional[jnp.ndarray] = None,
                return_state: bool = False):
    """Chunked SSD: scalar per-head decay -> cheap pairwise (T_c x T_c) masks."""
    Bb, T, H, P = x.shape
    N = B.shape[-1]
    c = min(chunk, T)
    pad = (-T) % c
    if pad:
        x, dt, B, C = (jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
                       for a in (x, dt, B, C))
    Tp = T + pad
    n = Tp // c
    xf = x.astype(jnp.float32).reshape(Bb, n, c, H, P).transpose(1, 0, 2, 3, 4)
    dtf = dt.astype(jnp.float32).reshape(Bb, n, c, H).transpose(1, 0, 2, 3)
    Bf = B.astype(jnp.float32).reshape(Bb, n, c, N).transpose(1, 0, 2, 3)
    Cf = C.astype(jnp.float32).reshape(Bb, n, c, N).transpose(1, 0, 2, 3)
    Af, Df = A.astype(jnp.float32), D.astype(jnp.float32)

    def per_chunk(S, xs):
        xc, dtc, Bc, Cc = xs                      # (B,c,H,P)/(B,c,H)/(B,c,N)
        dA = -Af * dtc                            # log decay (B,c,H)
        L = jnp.cumsum(dA, axis=1)                # inclusive
        o_inter = jnp.einsum("btn,bhnp->bthp", Cc, S) * jnp.exp(L)[..., None]
        # pairwise s <= t: decay exp(L_t - L_s) * dt_s (B,t,s,H)
        pair = L[:, :, None] - L[:, None, :, :]
        mask = jnp.arange(c)[:, None] >= jnp.arange(c)[None, :]
        G = jnp.exp(jnp.minimum(pair, 0.0)) * mask[None, :, :, None]
        CB = jnp.einsum("btn,bsn->bts", Cc, Bc)
        M = CB[..., None] * G * dtc[:, None]      # (B,t,s,H)
        o_intra = jnp.einsum("btsh,bshp->bthp", M, xc)
        # state update
        Ll = L[:, -1]                             # (B,H)
        kdec = jnp.exp(Ll[:, None] - L) * dtc     # (B,c,H)
        S = jnp.exp(Ll)[..., None, None] * S + jnp.einsum(
            "bsn,bshp,bsh->bhnp", Bc, xc, kdec)
        return S, o_inter + o_intra + Df[:, None] * xc

    S0 = (jnp.zeros((Bb, H, N, P), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    S, y = jax.lax.scan(per_chunk, S0, (xf, dtf, Bf, Cf))
    y = y.transpose(1, 0, 2, 3, 4).reshape(Bb, Tp, H, P)[:, :T].astype(x.dtype)
    return (y, S) if return_state else y


def _mamba2_project(p, cfg, xn, compute_dtype):
    di, N = cfg.d_inner, cfg.ssm_state
    H = di // cfg.head_dim
    zxbcdt = dense(p["in_proj"], xn, compute_dtype)
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    return z, xbc, dt_raw


def _mamba2_ssm_inputs(p, cfg, xbc_conv, dt_raw):
    di, N = cfg.d_inner, cfg.ssm_state
    P = cfg.head_dim
    H = di // P
    xs, Bs, Cs = jnp.split(xbc_conv, [di, di + N], axis=-1)
    Bsh, Tsh = xs.shape[0], xs.shape[1]
    xh = xs.reshape(Bsh, Tsh, H, P)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    return xh, dt, Bs, Cs


def mamba2_block(p, cfg, x, *, chunk: int = 128, compute_dtype=jnp.bfloat16):
    """Full-sequence Mamba2 layer with residual."""

    B, T, d = x.shape
    di = cfg.d_inner
    xn = rms_norm(p["norm"], x, cfg.norm_eps)
    z, xbc, dt_raw = _mamba2_project(p, cfg, xn, compute_dtype)
    xbc_conv, _ = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xh, dt, Bs, Cs = _mamba2_ssm_inputs(p, cfg, xbc_conv, dt_raw)
    A = jnp.exp(p["A_log"].astype(jnp.float32))
    y = ssd_chunked(xh, dt, A, Bs, Cs, p["D"], chunk=chunk)
    y = y.reshape(B, T, di)
    y = rms_norm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return x + dense(p["out_proj"], y.astype(compute_dtype), compute_dtype)


class MambaState(NamedTuple):
    S: jnp.ndarray            # (B, H, N, P)
    conv: jnp.ndarray         # (B, W-1, conv_dim)


def mamba2_state_init(cfg: ModelConfig, batch: int) -> MambaState:
    di, N, P = cfg.d_inner, cfg.ssm_state, cfg.head_dim
    H = di // P
    return MambaState(
        S=jnp.zeros((batch, H, N, P), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * N), jnp.float32),
    )


def mamba2_step(p, cfg, x, state: MambaState, *, compute_dtype=jnp.bfloat16):
    """One-token decode.  x: (B,1,d)."""

    B = x.shape[0]
    di, N, P = cfg.d_inner, cfg.ssm_state, cfg.head_dim
    H = di // P
    xn = rms_norm(p["norm"], x, cfg.norm_eps)
    z, xbc, dt_raw = _mamba2_project(p, cfg, xn, compute_dtype)
    xbc_conv, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                      conv_state=state.conv)
    xh, dt, Bs, Cs = _mamba2_ssm_inputs(p, cfg, xbc_conv, dt_raw)
    A = jnp.exp(p["A_log"].astype(jnp.float32))
    xt, dtt, Bt, Ct = xh[:, 0].astype(jnp.float32), dt[:, 0], \
        Bs[:, 0].astype(jnp.float32), Cs[:, 0].astype(jnp.float32)
    a = jnp.exp(-A * dtt)
    S = a[..., None, None] * state.S + jnp.einsum("bn,bhp,bh->bhnp", Bt, xt, dtt)
    y = jnp.einsum("bn,bhnp->bhp", Ct, S) + p["D"].astype(jnp.float32)[:, None] * xt
    y = y.reshape(B, 1, di)
    y = rms_norm(p["gate_norm"], y.astype(compute_dtype) * jax.nn.silu(z),
                 cfg.norm_eps)
    out = x + dense(p["out_proj"], y.astype(compute_dtype), compute_dtype)
    return out, MambaState(S=S, conv=new_conv.astype(state.conv.dtype))

"""Pure-JAX model zoo: layers, attention, MoE, SSM blocks, composable models."""

from repro.models.model import (
    init_params, train_loss, prefill, prefill_chunk, encode_cross,
    decode_step, init_cache, init_paged_cache, PagedCache,
    chunked_cross_entropy, count_params, forward, Cache,
)

__all__ = [
    "init_params", "train_loss", "prefill", "prefill_chunk", "encode_cross",
    "decode_step", "init_cache", "init_paged_cache", "PagedCache",
    "chunked_cross_entropy", "count_params", "forward", "Cache",
]

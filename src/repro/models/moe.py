"""Mixture-of-Experts block with sort-based, capacity-bounded dispatch.

Design constraints (in order):
  1. expert-parallel shardable under GSPMD: experts (and their dispatch
     buffers) shard over the ``model`` axis, tokens over ``data``;
  2. no (T, E, C) one-hot dispatch tensors (they explode at 1M tokens);
  3. dispatch is *per sequence* (vmapped over batch) so the sort never
     crosses the data-sharded batch axis — the only collective GSPMD must
     insert is the final combine all-reduce over ``model``.

This is the AMU gather pattern (repro.core.patterns.GatherPattern) at
model scale: expert dispatch is an indexed gather whose granularity is
the expert capacity slot, and the Pallas `moe_gather` kernel implements
the same slot layout at tile level.

Dropping semantics: per (sequence, expert) capacity
``C = ceil(S·k/E · capacity_factor)``; pairs beyond C are dropped (their
gate mass is simply not added — standard Switch behaviour).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

Params = Dict[str, jnp.ndarray]

__all__ = ["moe_init", "moe_block", "expert_capacity"]


def expert_capacity(cfg: ModelConfig, seq_len: int) -> int:
    pairs = seq_len * cfg.experts_per_token
    return max(1, math.ceil(pairs / cfg.num_experts * cfg.capacity_factor))


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(kr, d, E, dtype=dtype, scale=scale),
        "gate": jax.random.normal(kg, (E, d, ff), dtype) * scale,
        "up": jax.random.normal(ku, (E, d, ff), dtype) * scale,
        "down": jax.random.normal(kd, (E, ff, d), dtype) / math.sqrt(ff),
    }
    if cfg.shared_expert:
        from repro.models.layers import swiglu_init
        p["shared"] = swiglu_init(ks, d, ff, dtype)
    return p


def _dispatch_indices(sorted_e: jnp.ndarray, E: int, C: int):
    """Per-row slot assignment for pairs sorted by expert id.

    sorted_e: (P,) int32 ascending expert ids.  Returns (slot, keep):
    slot in [0, E*C) for kept pairs; dropped pairs get slot E*C.
    """
    P = sorted_e.shape[0]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")  # (E,)
    rank = jnp.arange(P) - starts[sorted_e]
    keep = rank < C
    slot = jnp.where(keep, sorted_e * C + rank, E * C)
    return slot, keep


def moe_block(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,                  # (B, S, d)
    *,
    capacity: Optional[int] = None,
    compute_dtype=jnp.bfloat16,
    renorm_gates: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    C = capacity or expert_capacity(cfg, S)
    P = S * k

    xc = x.astype(compute_dtype)
    # -- routing (fp32 for stability) ---------------------------------------
    logits = (x.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (B, S, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)            # (B, S, k)
    if renorm_gates:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

    # -- aux load-balancing loss (Switch): E * sum_e f_e * P_e ----------------
    pair_onehot_frac = jnp.zeros((B, E), jnp.float32)
    flat_ids = expert_ids.reshape(B, P)
    pair_onehot_frac = jax.vmap(
        lambda ids: jnp.zeros((E,), jnp.float32).at[ids].add(1.0))(flat_ids)
    f_e = pair_onehot_frac / P                                  # (B, E)
    p_e = probs.mean(axis=1)                                    # (B, E)
    aux = cfg.router_aux_coef * E * jnp.mean(jnp.sum(f_e * p_e, axis=-1))

    # -- sort-based dispatch, vmapped over batch rows -------------------------
    pair_tok = jnp.repeat(jnp.arange(S), k)                     # (P,)
    flat_gates = gate_vals.reshape(B, P)

    def dispatch_row(xr, ids, gates):
        # xr: (S, d); ids/gates: (P,)
        order = jnp.argsort(ids)
        se, st, sg = ids[order], pair_tok[order], gates[order]
        slot, keep = _dispatch_indices(se, E, C)
        gathered = xr[st] * keep[:, None].astype(xr.dtype)       # (P, d)
        buf = jnp.zeros((E * C + 1, d), xr.dtype).at[slot].set(gathered)
        return buf[:-1].reshape(E, C, d), (slot, keep, st, sg)

    buf, (slot, keep, st, sg) = jax.vmap(dispatch_row)(
        xc, flat_ids, flat_gates)                                # buf (B,E,C,d)

    # -- expert FFN (einsum over stacked expert weights; E shards over model) --
    g = jnp.einsum("becd,edf->becf", buf, p["gate"].astype(compute_dtype))
    u = jnp.einsum("becd,edf->becf", buf, p["up"].astype(compute_dtype))
    h = jax.nn.silu(g) * u
    eo = jnp.einsum("becf,efd->becd", h, p["down"].astype(compute_dtype))

    # -- combine: gather back by slot, weight by gate, scatter-add to tokens ---
    def combine_row(eor, slot, keep, st, sg):
        flat = eor.reshape(E * C, d)
        y = flat[jnp.minimum(slot, E * C - 1)]                   # (P, d)
        y = y * (sg * keep)[:, None].astype(y.dtype)
        return jnp.zeros((S, d), y.dtype).at[st].add(y)

    out = jax.vmap(combine_row)(eo, slot, keep, st, sg)          # (B, S, d)

    if cfg.shared_expert:
        from repro.models.layers import swiglu
        out = out + swiglu(p["shared"], xc, compute_dtype)
    return out.astype(x.dtype), aux

"""Primitive layers (pure JAX, pytree params) shared by every architecture.

Conventions:
  * params are nested dicts of jnp arrays; init fns return the dict,
    apply fns take (params, x, ...) and are shape-polymorphic,
  * params are stored in ``param_dtype`` (fp32) and cast to
    ``compute_dtype`` (bf16) at use — the MaxText mixed-precision scheme,
  * every init takes an explicit PRNG key (no global state).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init", "dense",
    "rms_norm_init", "rms_norm", "layer_norm_init", "layer_norm",
    "embed_init", "embed", "unembed",
    "rope", "mrope", "rope_freqs",
    "swiglu_init", "swiglu",
]

Params = Dict[str, jnp.ndarray]


def _native_norms() -> bool:
    """Norm elementwise math in native dtype (perf policy; stats stay f32)."""
    from repro.dist import act_sharding as acts
    return acts.current().native_dtype


# -- linear -------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, scale: Optional[float] = None) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jnp.ndarray, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    w = p["w"].astype(compute_dtype)
    y = x.astype(compute_dtype) @ w
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


# -- norms ---------------------------------------------------------------------

def rms_norm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    if _native_norms() and dtype != jnp.float32:
        # statistics in f32, (B,S,d)-sized elementwise math in the native
        # dtype: halves the norm's HBM traffic and keeps its backward out
        # of f32 (the single largest memory term in the baseline roofline)
        return x * inv.astype(dtype) * p["scale"].astype(dtype)
    y = xf * inv
    return (y * p["scale"].astype(jnp.float32)).astype(dtype)


def layer_norm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    if _native_norms() and dtype != jnp.float32:
        return ((x - mu.astype(dtype)) * inv.astype(dtype)
                * p["scale"].astype(dtype) + p["bias"].astype(dtype))
    y = (xf - mu) * inv
    return (y * p["scale"] + p["bias"]).astype(dtype)


# -- embeddings ------------------------------------------------------------------

def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(p: Params, tokens: jnp.ndarray, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    return jnp.take(p["table"].astype(compute_dtype), tokens, axis=0)


def unembed(p: Params, x: jnp.ndarray, *, logit_scale: float = 1.0,
            compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    """Project to vocab logits.  ``p`` is the embed table (tied) or lm_head."""
    table = p["table"].astype(compute_dtype)
    logits = x.astype(compute_dtype) @ table.T
    if logit_scale != 1.0:
        logits = logits * logit_scale
    return logits


# -- rotary position embeddings ----------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim // 2,), fp32."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def _apply_rot(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """Rotate pairs (x[..., ::2], x[..., 1::2]) — GPT-NeoX convention on halves."""
    d = x.shape[-1] // 2
    x1, x2 = x[..., :d], x[..., d:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10_000.0) -> jnp.ndarray:
    """Standard RoPE.

    x: (..., S, H, D); positions: broadcastable to (..., S), int32.
    """
    freqs = rope_freqs(x.shape[-1], theta)                     # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    return _apply_rot(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def mrope(x: jnp.ndarray, positions: jnp.ndarray, sections: Sequence[int],
          theta: float = 10_000.0) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL): head_dim/2 freqs split into (t, h, w)
    sections, each driven by its own position component.

    x: (B, S, H, D); positions: (3, B, S) int32 (t/h/w ids — equal for text).
    """
    d_half = x.shape[-1] // 2
    if sum(sections) != d_half:
        raise ValueError(f"mrope sections {sections} must sum to {d_half}")
    freqs = rope_freqs(x.shape[-1], theta)                     # (D/2,)
    # build per-frequency position selector: section i uses positions[i]
    sec_ids = jnp.repeat(jnp.arange(len(sections)), jnp.array(sections),
                         total_repeat_length=d_half)           # (D/2,)
    # gather per-section positions: (B, S, D/2)
    pos = positions.astype(jnp.float32)[sec_ids]               # (D/2, B, S)
    pos = jnp.moveaxis(pos, 0, -1)                             # (B, S, D/2)
    angles = pos * freqs                                       # (B, S, D/2)
    cos = jnp.cos(angles)[..., None, :]                        # (B, S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    return _apply_rot(x.astype(jnp.float32), cos, sin).astype(x.dtype)


# -- gated MLP -----------------------------------------------------------------------

def swiglu_init(key, d: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d, d_ff, dtype=dtype),
        "up": dense_init(k2, d, d_ff, dtype=dtype),
        "down": dense_init(k3, d_ff, d, dtype=dtype),
    }


def swiglu(p: Params, x: jnp.ndarray, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    g = dense(p["gate"], x, compute_dtype)
    u = dense(p["up"], x, compute_dtype)
    return dense(p["down"], jax.nn.silu(g) * u, compute_dtype)

"""Content-addressed cross-request prefix sharing over the page pool.

Serving fleets see the same prompt prefixes over and over — a system
prompt shared by thousands of users, a few-shot template, a long
retrieval document.  Recomputing the prefix's KV per request burns the
exact prefill FLOPs chunked admission was built to hide.  This module
makes prompt pages *content-addressed*: a full page of prompt tokens is
identified by a rolling hash of the token-id prefix it terminates, so
any later request whose prompt starts with the same tokens can map its
page-table rows straight onto the already-computed KV — device-resident
(refcounted frame share, zero traffic) or far-tier (one LATENCY-QoS
page fetch instead of a prefill chunk).

Design notes:

  * **Full pages only.**  A page hash covers tokens
    ``[0, (i+1) * page_size)``; only exactly-full pages are interned, so
    a sharer never writes a shared frame (its own tail starts on the
    next page boundary) and the KV inside is position-exact for every
    sharer (RoPE is absolute, prefixes share positions).
  * **The cache is a page-table sequence.**  Interned pages live under
    the pseudo-sequence :data:`PREFIX_SEQ` in the engine's own
    :class:`~repro.paging.PageTable` — one logical page per entry — so
    the pager's LRU eviction, clean-park fast path and far-tier
    bookkeeping all apply to cache-owned frames with no special cases:
    under pool pressure a cache frame parks to the far tier for free
    (its far home is written at intern time) and a later hit fetches it
    back with a LATENCY aload.
  * **COW discipline.**  Interning sets the frame's copy-on-write bit;
    the refcount + :meth:`~repro.paging.PageTable.remap_private` give
    writers an escape hatch.  On the supported families (global-
    attention dense/moe, append-only KV) no writer ever reaches a
    shared page — the engine still guards the decode tail defensively.
  * **Far hits fetch private copies.**  A hit on a parked entry installs
    the entry's host payload as the *requester's* far alias (no copy —
    same host array) and lets the ordinary resume machinery fetch it,
    so "prefix hit while the page is still ARRIVING" is just the
    existing resume-while-ARRIVING path.

This is the serving-level version of the paper's aggregation argument:
the far tier plus massive outstanding aloads turns recomputation into
cheap, overlappable transfers (2404.11044 §4's memory-pool economics).
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, Hashable, List, Optional

import numpy as np

from repro.paging.page_table import (NOT_MAPPED, PagePool, PageState,
                                     PageTable, PagingError)
from repro.paging.pager import Pager

__all__ = ["PrefixCache", "PREFIX_SEQ", "page_hashes"]

#: Pseudo-sequence owning cache entries in the engine's page table.
PREFIX_SEQ = "~prefix"


def page_hashes(prompt: np.ndarray, page_size: int) -> List[bytes]:
    """Rolling hash per *full* page of ``prompt`` token ids.

    ``h[i]`` digests the entire prefix ``prompt[: (i+1) * page_size]``
    (chained, not per-page), so equal hashes imply equal full prefixes —
    a hit on page ``i`` is only meaningful after hits on ``0..i-1``.

    >>> a = page_hashes(np.arange(8, dtype=np.int32), 4)
    >>> b = page_hashes(np.arange(9, dtype=np.int32), 4)
    >>> a == b[:2] and len(b) == 2
    True
    """
    prompt = np.ascontiguousarray(prompt, dtype=np.int32)
    out: List[bytes] = []
    h = hashlib.blake2b(digest_size=16)
    for i in range(len(prompt) // page_size):
        h.update(prompt[i * page_size:(i + 1) * page_size].tobytes())
        out.append(h.copy().digest())
    return out


@dataclass
class _Entry:
    logical: int          # index in the PREFIX_SEQ page-table row
    hits: int = 0
    last_hit: int = 0


class PrefixCache:
    """Content-addressed store of computed prompt pages.

    Wraps the engine's pool/table/pager; entries are logical pages of
    the :data:`PREFIX_SEQ` pseudo-sequence.  ``match`` finds the
    longest usable shared prefix of a prompt; ``intern`` donates a
    just-prefilled request's full prompt pages.  Example::

        cache = PrefixCache(pool, table, pager, page_size=16)
        hits = cache.match(prompt)       # [(logical, phys-or-None), ...]
        ...                              # engine maps / fetches them
        cache.intern(prompt, rid, read_frame)   # after prefill finishes
    """

    def __init__(self, pool: PagePool, table: PageTable, pager: Pager,
                 page_size: int, max_pages: Optional[int] = None):
        self.pool = pool
        self.table = table
        self.pager = pager
        self.page_size = page_size
        self.max_pages = max_pages
        self._by_hash: Dict[bytes, _Entry] = {}
        self._clock = 0
        table.register(PREFIX_SEQ)
        # per-request hit/saved-token tallies live in the engine's stats
        self.stats = {"interned": 0, "evicted_entries": 0}

    # -- lookup --------------------------------------------------------------
    def match(self, prompt: np.ndarray) -> List[int]:
        """Longest usable run of cached pages for ``prompt``.

        Returns the cache-entry *logical* indices for leading full pages
        ``0..k-1``, capped so at least the prompt's final token is left
        to compute (the chunk path must produce logits at ``plen - 1``
        to sample the first token, so a full-prompt hit recomputes its
        last page).
        """
        plen = len(prompt)
        max_pages = max(0, (plen - 1) // self.page_size)
        out: List[int] = []
        self._clock += 1
        for h in page_hashes(prompt, self.page_size)[:max_pages]:
            ent = self._by_hash.get(h)
            if ent is None:
                break
            ent.hits += 1
            ent.last_hit = self._clock
            out.append(ent.logical)
        return out

    def entry_state(self, logical: int) -> PageState:
        return self.table.entry(PREFIX_SEQ, logical).state

    def entry_phys(self, logical: int) -> int:
        return self.table.entry(PREFIX_SEQ, logical).phys

    def far_key(self, logical: int):
        return (PREFIX_SEQ, logical)

    # -- intern --------------------------------------------------------------
    def intern(self, prompt: np.ndarray, seq: Hashable, read_frame) -> int:
        """Donate a prefilled sequence's full prompt pages to the cache.

        For each full page of ``prompt`` not already cached: share the
        donor's frame into the :data:`PREFIX_SEQ` row (refcount up, COW
        bit on) and write the page's host payload to the far tier, so
        every future sharer can clean-park it and a cache eviction is
        free.  The donor keeps decoding on the same frame — it never
        writes it again (its tail lives on later pages).  Returns the
        number of pages newly interned.
        """
        new = 0
        hashes = page_hashes(prompt, self.page_size)
        for i, h in enumerate(hashes):
            ent = self._by_hash.get(h)
            if ent is not None:
                # entry exists but may have been evicted to the far tier:
                # re-promote it onto this sharer's freshly-fetched frame
                # (self-healing — the next hit is a device hit again)
                self._repromote(ent, seq, i)
                continue
            try:
                pte = self.table.entry(seq, i)
            except PagingError:
                break
            if pte.state is not PageState.RESIDENT or pte.phys == NOT_MAPPED:
                continue            # page already parked: nothing to share
            if self.max_pages is not None and \
                    len(self._by_hash) >= self.max_pages:
                self._evict_entry()
            logical = self.table.append_shared(PREFIX_SEQ, pte.phys)
            self.pool.mark_cow(pte.phys)
            self.pool.mark_dirty(pte.phys, False)
            self.pool.frames[pte.phys].tokens = self.page_size
            # far home written now: any sharer (and the cache itself)
            # can park this page clean, for free, forever after — the
            # donor included, via an alias under its own key
            payload = read_frame(pte.phys)
            self.pager.store_far(PREFIX_SEQ, logical, payload,
                                 tokens=self.page_size)
            self.pager.store_far(seq, i, payload, tokens=self.page_size)
            self._by_hash[h] = _Entry(logical=logical, last_hit=self._clock)
            self.stats["interned"] += 1
            new += 1
        return new

    def _repromote(self, ent: _Entry, seq: Hashable, logical: int) -> None:
        """Point a far-only cache entry back at a device frame a sharer
        just fetched/recomputed, so future hits are device hits."""
        pte_c = self.table.entry(PREFIX_SEQ, ent.logical)
        if pte_c.state is not PageState.PARKED:
            return
        try:
            pte_s = self.table.entry(seq, logical)
        except PagingError:
            return
        if pte_s.state is not PageState.RESIDENT or pte_s.phys == NOT_MAPPED:
            return
        self.pool.share(pte_s.phys, PREFIX_SEQ, ent.logical)
        self.pool.mark_cow(pte_s.phys)
        self.pool.mark_dirty(pte_s.phys, False)
        self.pool.frames[pte_s.phys].tokens = self.page_size
        pte_c.state = PageState.RESIDENT
        pte_c.phys = pte_s.phys

    # -- capacity ------------------------------------------------------------
    def _evict_entry(self) -> None:
        """Tombstone the least-recently-hit entry whose frame is not in
        use by any live sequence (refs == 1 means only the cache maps
        it).  Far copy and hash are dropped; the logical slot stays as
        an UNMAPPED tombstone (logical indices are positional)."""
        victims = sorted(self._by_hash.items(),
                         key=lambda kv: (kv[1].last_hit, kv[1].logical))
        for h, ent in victims:
            pte = self.table.entry(PREFIX_SEQ, ent.logical)
            if pte.state is PageState.RESIDENT:
                if self.pool.frames[pte.phys].refs > 1:
                    continue            # a live sequence still maps it
                self.table.unpin_page(PREFIX_SEQ, ent.logical)
                self.table.mark_parked(PREFIX_SEQ, ent.logical)
            pte.state = PageState.UNMAPPED
            pte.phys = NOT_MAPPED
            self.pager.tier.discard(self.far_key(ent.logical))
            del self._by_hash[h]
            self.stats["evicted_entries"] += 1
            return
        raise PagingError("prefix cache full and every entry is in use")

"""AMU-backed demand/prefetch pager over the device page pool.

The pager is the traffic engine between the pool (near tier) and the
host far tier — a :class:`repro.core.offload.FarMemoryTier`, the single
storage backend every cold page (preempted, evicted or finished) lives
in — expressed entirely as the paper's instruction set against
:class:`repro.core.amu.AMU`:

  * **prefetch** — LATENCY-QoS ``aload`` of the next-needed pages,
    issued while the current decode step computes, so the far-memory
    latency hides behind useful work (the paper's MACR: a small
    granularity + high priority for latency-critical random access),
  * **writeback / eviction** — BULK-QoS ``astore`` of cold or evicted
    pages under an LRU-with-pinning policy (pinned frames back active
    decode slots and are never victims),
  * **poll** — ``getfin``: non-blocking completion drain that flips the
    page table's residency bits and never stalls the event loop.

On top of the AMU's global outstanding-slot queue the pager adds
*per-QoS outstanding windows*: each class gets its own bounded window
so BULK writeback can never occupy every hardware queue entry ahead of
a latency-critical fetch — the QoS field of the paper's Memory Access
Configuration Register enforced at the issue stage.
"""

from __future__ import annotations

import collections
from typing import Any, Callable, Deque, Dict, Hashable, List, Optional, Tuple

from repro.core.amu import (AMU, AMUError, AccessConfig, FAILURE_CODE, QoS,
                            RequestState, SimBackend)
from repro.core.offload import FarMemoryTier
from repro.obs import MetricsRegistry, NULL_TRACER
from repro.paging.page_table import (NOT_MAPPED, PagePool, PageState,
                                     PageTable, PagingError)

__all__ = ["Pager", "QoSWindows"]

#: per-QoS take/release counter keys (precomputed: no per-op f-strings)
_TAKE_KEY = {q: f"window_take_{q.name.lower()}" for q in QoS}
_RELEASE_KEY = {q: f"window_release_{q.name.lower()}" for q in QoS}
_OCCUPANCY_TRACK = {q: f"window/{q.name}" for q in QoS}

_PENDING = -2        # rid sentinel: request queued behind its QoS window


class QoSWindows:
    """Per-QoS outstanding-request windows layered over one AMU queue.

    The QoS field of the paper's Memory Access Configuration Register
    (§2.2) enforced at the issue stage: each class gets its own bounded
    window, so BULK writeback can never occupy every hardware queue
    entry ahead of a latency-critical fetch.  Example::

        w = QoSWindows({QoS.LATENCY: 16, QoS.BULK: 4})
        if w.has_room(QoS.BULK):
            w.take(QoS.BULK)      # ... issue the astore ...
        w.release(QoS.BULK)       # on getfin completion
    """

    def __init__(self, windows: Dict[QoS, int]):
        for q, w in windows.items():
            if w < 1:
                raise PagingError(f"QoS window for {q.name} must be >= 1")
        self.limit = dict(windows)
        self.in_flight: Dict[QoS, int] = {q: 0 for q in windows}
        # every take/release is counted (the acquire/release balance
        # invariant reads these) and sampled onto one occupancy counter
        # track per class when tracing is on
        self.stats = MetricsRegistry().counters("pager")
        self.tracer = NULL_TRACER

    def bind_obs(self, stats, tracer) -> None:
        """Point take/release accounting at a shared registry view +
        tracer (existing counts carry over)."""
        if stats is not self.stats:
            for k, v in self.stats.items():
                stats[k] += v
            self.stats = stats
        self.tracer = tracer

    def has_room(self, qos: QoS) -> bool:
        return self.in_flight[qos] < self.limit[qos]

    def take(self, qos: QoS) -> None:
        if not self.has_room(qos):
            raise PagingError(f"QoS window {qos.name} full")
        self.in_flight[qos] += 1
        self.stats[_TAKE_KEY[qos]] += 1
        if self.tracer.enabled:
            self.tracer.counter("pager", _OCCUPANCY_TRACK[qos],
                                self.in_flight[qos])

    def release(self, qos: QoS) -> None:
        if self.in_flight[qos] <= 0:
            raise PagingError(f"QoS window {qos.name} release underflow")
        self.in_flight[qos] -= 1
        self.stats[_RELEASE_KEY[qos]] += 1
        if self.tracer.enabled:
            self.tracer.counter("pager", _OCCUPANCY_TRACK[qos],
                                self.in_flight[qos])

    def check_invariants(self) -> None:
        """Take/release counters must balance against live occupancy."""
        for qos, limit in self.limit.items():
            occ = self.in_flight[qos]
            if not 0 <= occ <= limit:
                raise PagingError(
                    f"QoS window {qos.name} occupancy {occ} outside "
                    f"[0, {limit}]")
            takes = self.stats[_TAKE_KEY[qos]]
            releases = self.stats[_RELEASE_KEY[qos]]
            if takes - releases != occ:
                raise PagingError(
                    f"QoS window {qos.name} unbalanced: {takes} takes - "
                    f"{releases} releases != {occ} in flight")


class Pager:
    """Demand/prefetch pager: moves pages between pool frames and the
    far tier through LATENCY aloads and BULK astores (§2.2 ISA, §2.3
    QoS split).  Example — park two pages, bring them back overlapped::

        pager.writeback(rid, 0, payload0)     # BULK astore (dirty)
        pager.park_clean(rid, 1)              # far copy current: free
        pager.prefetch_seq(rid, tail_first=True)   # LATENCY aloads
        for seq, logical in pager.poll():          # getfin drain
            ...                                    # residency bits set
    """

    def __init__(
        self,
        pool: PagePool,
        table: PageTable,
        amu: Optional[AMU] = None,
        *,
        page_nbytes: int = 1 << 16,
        latency_window: int = 16,
        standard_window: int = 8,
        bulk_window: int = 4,
        granularity: Optional[int] = None,
        read_frame: Optional[Callable[[int], Any]] = None,
        tier: Optional[FarMemoryTier] = None,
        tracer=None,
        metrics=None,
    ):
        self.pool = pool
        self.table = table
        # Optional hook: read a frame's content out of the device pool.
        # When the engine keeps page payloads in device arrays rather
        # than per-frame host copies, ``Frame.data`` is None and this is
        # how eviction obtains the writeback payload.
        self.read_frame = read_frame
        self.amu = amu or AMU(max_outstanding=latency_window
                              + standard_window + bulk_window)
        self.page_nbytes = int(page_nbytes)
        g = granularity or self.page_nbytes
        self.fetch_config = AccessConfig(granularity_bytes=g, qos=QoS.LATENCY)
        self.evict_config = AccessConfig(granularity_bytes=g, qos=QoS.BULK)
        self.windows = QoSWindows({QoS.LATENCY: latency_window,
                                   QoS.STANDARD: standard_window,
                                   QoS.BULK: bulk_window})
        # THE far tier: home copies of every cold page (and, for the
        # serving engine, finished-sequence KV + aux residues) live in
        # one FarMemoryTier sharing this pager's AMU.  The pager issues
        # its own windowed aloads/astores against the tier's storage;
        # completions consumed by either party on the shared queue are
        # forwarded to the other (see poll / _finish / _reap_failed).
        self.tier = tier if tier is not None else FarMemoryTier(self.amu)
        # in-flight request -> (kind, seq, logical, qos): the QoS class
        # travels *with* the request instead of being re-derived from
        # the kind string, so per-request overrides (the scheduler's
        # tier -> QoS mapping) release the right window on completion
        self._inflight: Dict[int, Tuple[str, Hashable, int, QoS]] = {}
        self._page_rid: Dict[Tuple[Hashable, int], int] = {}
        self._pending: Dict[QoS, Deque[Tuple[str, Hashable, int,
                                             Callable[[], int], float]]] = {
            QoS.LATENCY: collections.deque(),
            QoS.STANDARD: collections.deque(),
            QoS.BULK: collections.deque(),
        }
        # telemetry: stats is a Counter-compatible view onto a shared
        # MetricsRegistry (repro.obs) — every existing stats["key"] call
        # site works unchanged, and one metrics export sees everything
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.stats = self.metrics.counters("pager")
        self.tracer = NULL_TRACER
        self._noframe_t: Dict[Tuple[Hashable, int], float] = {}
        self._blocked_note: Dict[Tuple[Hashable, int], float] = {}
        self.bind_obs(self.metrics, tracer)

    def bind_obs(self, metrics=None, tracer=None) -> None:
        """Bind this pager (and its AMU, windows, page table) to a shared
        registry + tracer — the engine calls this so factory-built pagers
        land on the engine's clock/registry.  Existing counts migrate."""
        if metrics is not None and metrics is not self.metrics:
            fresh = metrics.counters("pager")
            for k, v in self.stats.items():
                fresh[k] += v
            self.metrics = metrics
            self.stats = fresh
        if tracer is not None:
            self.tracer = tracer
            self.amu.tracer = tracer
            self.table.tracer = tracer
        if self.amu.metrics is None or metrics is not None:
            self.amu.metrics = self.metrics
        self.windows.bind_obs(self.stats, self.tracer)

    def _now(self) -> float:
        return self.amu._clock()

    def check_invariants(self) -> None:
        """Window acquire/release accounting must balance: counter
        deltas equal live occupancy, and occupancy equals the number of
        requests this pager is actually tracking in flight."""
        self.windows.check_invariants()
        occ = sum(self.windows.in_flight.values())
        if occ != len(self._inflight):
            raise PagingError(
                f"window occupancy {occ} != {len(self._inflight)} "
                "tracked in-flight requests")

    # -- write path: park / writeback ---------------------------------------
    def writeback(self, seq: Hashable, logical: int, data: Any,
                  tokens: int = -1, qos: Optional[QoS] = None) -> None:
        """Park one RESIDENT page: the far tier becomes its home (an
        astore models the transfer — BULK by default, overridable per
        call for e.g. an interactive-tier preemption whose pages should
        not queue behind batch-tier parks), and this mapping's device
        frame is released.  ``tokens`` tags how many positions of the
        page were valid when stored, so a later park can tell a current
        far copy from a stale one (clean-eviction fast path)."""
        qos = QoS.BULK if qos is None else QoS(qos)
        self.table.mark_parked(seq, logical)
        self.tier.put((seq, logical), data, nbytes=self.page_nbytes,
                      tokens=tokens)
        self.stats["writeback"] += 1
        if self.tracer.enabled:
            self.tracer.instant("pager", "actions", "writeback",
                                {"seq": seq, "logical": logical,
                                 "qos": qos.name})
        self._issue(qos, "astore", seq, logical,
                    lambda: self.amu.astore(data, nbytes=self.page_nbytes,
                                            config=self.evict_config,
                                            qos=qos))

    def park_clean(self, seq: Hashable, logical: int) -> None:
        """Park a page whose far-tier home copy is already current —
        no astore traffic (the clean-eviction fast path)."""
        if (seq, logical) not in self.tier:
            raise PagingError(
                f"page ({seq!r}, {logical}) has no far-tier copy; "
                "use writeback for dirty pages")
        self.table.mark_parked(seq, logical)
        self.stats["clean_evict"] += 1
        if self.tracer.enabled:
            self.tracer.instant("pager", "actions", "clean_evict",
                                {"seq": seq, "logical": logical})

    def evict(self, seq: Hashable, logical: int,
              qos: Optional[QoS] = None) -> None:
        """Evict one resident page: writeback (BULK unless overridden)
        when its frame is dirty, frame free only when clean."""
        pte = self.table.entry(seq, logical)
        if pte.state is not PageState.RESIDENT:
            raise PagingError(
                f"evict of non-resident page ({seq!r}, {logical})")
        frame = self.pool.frames[pte.phys]
        if frame.dirty or (seq, logical) not in self.tier:
            data = frame.data
            if data is None and self.read_frame is not None:
                data = self.read_frame(pte.phys)
            # carry the frame's valid-token tag into the far entry so a
            # later park of the same content still hits the clean fast
            # path (an untagged writeback would poison it forever)
            self.writeback(seq, logical, data, tokens=frame.tokens, qos=qos)
        else:
            self.park_clean(seq, logical)
        self.stats["evictions"] += 1

    def evict_lru(self, n: int) -> int:
        """Evict up to ``n`` unpinned RESIDENT frames, least-recently-used
        first (ARRIVING frames have a fetch in flight and are skipped;
        so are frames mapped by more than one sequence — evicting one
        sharer's mapping cannot free the frame).  Returns how many were
        actually evicted."""
        done = 0
        for phys in self.pool.lru_victims(self.pool.n_pages):
            if done >= n:
                break
            f = self.pool.frames[phys]
            if f.refs > 1 or not f.users:
                continue
            seq, logical = next(iter(f.users))
            if self.table.entry(seq, logical).state \
                    is not PageState.RESIDENT:
                continue
            self.evict(seq, logical)
            done += 1
        return done

    def balance(self, low_free: int) -> int:
        """The capacity-pressure loop: evict LRU frames until at least
        ``low_free`` frames are free (§2.3.2 free-watermark policy made
        proactive — cold RESIDENT pages flow to the far tier *before*
        growth/admission hits an empty free heap, so the astores overlap
        decode instead of serialising in front of it).  Returns how many
        frames were evicted."""
        deficit = low_free - self.pool.n_free
        if deficit <= 0:
            return 0
        done = self.evict_lru(deficit)
        if done:
            self.stats["watermark_evictions"] += done
            if self.tracer.enabled:
                self.tracer.instant("pager", "actions", "watermark_evict",
                                    {"n": done, "free": self.pool.n_free})
        return done

    # -- read path: prefetch / demand fetch ---------------------------------
    def prefetch(self, seq: Hashable, logical: int,
                 qos: Optional[QoS] = None) -> bool:
        """Begin an aload of one PARKED page (non-blocking; LATENCY by
        default — the scheduler demotes batch-tier resumes to STANDARD
        so they cannot crowd interactive fetches out of the window).
        Returns False when the page is already resident or in flight."""
        qos = QoS.LATENCY if qos is None else QoS(qos)
        pte = self.table.entry(seq, logical)
        if pte.state in (PageState.RESIDENT, PageState.ARRIVING):
            return False
        if self.pool.n_free == 0:
            self.stats["prefetch_no_frame"] += 1
            if self.tracer.enabled:
                # first time this page is frame-blocked: remember when,
                # so the eventual fetch span carries the blocked time
                self._noframe_t.setdefault((seq, logical), self._now())
                self.tracer.instant("pager", "actions", "prefetch_no_frame",
                                    {"seq": seq, "logical": logical})
            return False
        self.table.mark_arriving(seq, logical)
        src = self.tier.home((seq, logical))
        self.stats["prefetch"] += 1
        if self.tracer.enabled:
            t_blocked = self._noframe_t.pop((seq, logical), None)
            if t_blocked is not None:
                self._blocked_note[(seq, logical)] = \
                    (self._now() - t_blocked) * 1e6
            self.tracer.instant("pager", "actions", "prefetch",
                                {"seq": seq, "logical": logical,
                                 "qos": qos.name})
        self._issue(qos, "aload", seq, logical,
                    lambda: self.amu.aload(src, nbytes=self.page_nbytes,
                                           config=self.fetch_config,
                                           qos=qos))
        return True

    def prefetch_seq(self, seq: Hashable, *, tail_first: bool = True,
                     qos: Optional[QoS] = None) -> int:
        """Prefetch every parked page of ``seq``; with ``tail_first`` the
        hot tail (most recent positions) is issued — and so arrives —
        first, which is the order a rescheduled decode touches them."""
        parked = self.table.logical_pages(seq, PageState.PARKED)
        if tail_first:
            parked = parked[::-1]
        n = 0
        for logical in parked:
            n += bool(self.prefetch(seq, logical, qos=qos))
        return n

    def poll(self) -> List[Tuple[Hashable, int]]:
        """getfin until the completion queue is empty; returns the pages
        whose aloads landed this call (residency bits now set).

        A *failed* request (``getfin`` raising :class:`AMUError`) must
        not leak its QoS window slot: the failure is reaped — window
        released, an aload's ARRIVING page reverted to PARKED so a
        retry can re-issue it — and polling continues.  Without this a
        single fault would permanently shrink the window until the
        class wedged entirely."""
        arrived: List[Tuple[Hashable, int]] = []
        while True:
            try:
                rid = self.amu.getfin()
            except AMUError:
                self._reap_failed()
                continue
            if rid == FAILURE_CODE:
                break
            got = self._finish(rid)
            if got is not None:
                arrived.append(got)
        self._pump()
        return arrived

    def _reap_failed(self) -> None:
        """Clean up every tracked request the AMU marked FAILED (and let
        the shared far tier reap its own failed fetches — one completion
        queue, two consumers)."""
        for rid in list(self._inflight):
            if self.amu.request(rid).state is RequestState.FAILED:
                self._fail_one(rid)
        if self.tier.amu is self.amu:
            self.tier._reap_failed()
        self._pump()

    def _fail_one(self, rid: int) -> None:
        """Undo one failed request's bookkeeping: release its QoS window
        slot and, for an aload, free the reserved frame and mark the
        page PARKED again (the far copy is still intact, so a later
        prefetch simply retries)."""
        kind, seq, logical, qos = self._inflight.pop(rid)
        self.windows.release(qos)
        self.stats[f"{kind}_failed"] += 1
        if self.tracer.enabled:
            self.tracer.instant("pager", "actions", "fault",
                                {"seq": seq, "logical": logical,
                                 "kind": kind, "qos": qos.name})
        if kind != "aload":
            return
        self._page_rid.pop((seq, logical), None)
        try:
            pte = self.table.entry(seq, logical)
        except PagingError:
            return                        # sequence dropped mid-flight
        if pte.state is PageState.ARRIVING:
            phys, pte.phys = pte.phys, NOT_MAPPED
            pte.state = PageState.PARKED
            self.pool.free(phys)

    def wait_page(self, seq: Hashable, logical: int) -> None:
        """Blocking: ensure one page is RESIDENT (demand fetch)."""
        pte = self.table.entry(seq, logical)
        if pte.state is PageState.RESIDENT:
            return
        if pte.state is PageState.PARKED:
            if self.pool.n_free == 0 and not self.evict_lru(1):
                raise PagingError(
                    f"demand fetch of ({seq!r}, {logical}): pool "
                    "exhausted and nothing evictable")
            if not self.prefetch(seq, logical):
                raise PagingError(
                    f"demand fetch of ({seq!r}, {logical}) failed to issue")
            self.stats["demand_fetch"] += 1
            if self.tracer.enabled:
                self.tracer.instant("pager", "actions", "demand_fetch",
                                    {"seq": seq, "logical": logical})
        rid = self._page_rid.get((seq, logical), _PENDING)
        if rid == _PENDING:
            self._force_issue(seq, logical)
            rid = self._page_rid[(seq, logical)]
        req = self.amu.wait(rid)
        if req.error is not None:
            if rid in self._inflight:
                self._fail_one(rid)
            self._pump()
            raise PagingError(
                f"demand fetch of ({seq!r}, {logical}) failed"
            ) from req.error
        self._finish(rid)

    def wait_arriving(self, seq: Hashable) -> None:
        """Blocking: land every ARRIVING page of ``seq`` (no new frames
        are taken — safe under pool pressure)."""
        for logical in self.table.logical_pages(seq, PageState.ARRIVING):
            self.wait_page(seq, logical)

    def wait_seq(self, seq: Hashable) -> None:
        """Blocking: ensure every page of ``seq`` is RESIDENT.  Parked
        pages are all issued before the first wait so their transfers
        overlap each other (never one-fetch-at-a-time)."""
        self.prefetch_seq(seq, tail_first=False)
        for logical in range(self.table.n_pages(seq)):
            self.wait_page(seq, logical)

    def fetch_keys(self, keys: List[Hashable], *,
                   discard_after: bool = False) -> Dict[Hashable, Any]:
        """Overlapped fault-safe fetch of raw far-tier entries (the
        tier-payload analogue of :meth:`prefetch_seq` + :meth:`wait_seq`
        for pages): every key's aload is issued before the first wait so
        the transfers overlap, then each is verified landed.

        The one fault discipline both reuse paths share — the engine's
        ``fetch_finished`` reassembly and the cross-engine handoff
        admission: a mid-transfer :class:`~repro.core.amu.AMUError`
        propagates with every home copy *intact* (``FarMemoryTier.get``
        clears only the pending transfer), so the caller retries by
        calling again; with ``discard_after`` the entries are dropped
        only once **all** payloads verifiably landed — never before."""
        tier = self.tier
        for key in keys:
            tier.prefetch(key)              # issue everything first
        out: Dict[Hashable, Any] = {}
        for key in keys:
            out[key] = tier.get(key)        # raises on fault; nothing
        if discard_after:                   # discarded yet
            for key in keys:
                tier.discard(key)
        return out

    # -- far-tier access (delegates to the shared FarMemoryTier) -------------
    def far_copy(self, seq: Hashable, logical: int) -> Any:
        return self.tier.home((seq, logical))

    def has_far(self, seq: Hashable, logical: int) -> bool:
        return (seq, logical) in self.tier

    def far_tokens(self, seq: Hashable, logical: int) -> int:
        """Valid-token tag of the far copy (-1: none or untagged)."""
        return self.tier.tokens_of((seq, logical))

    def store_far(self, seq: Hashable, logical: int, data: Any,
                  tokens: int = -1) -> None:
        self.tier.put((seq, logical), data, nbytes=self.page_nbytes,
                      tokens=tokens)

    def drop_far(self, seq: Hashable) -> None:
        self.tier.discard_seq(seq)
        for key in [k for k in self._page_rid if k[0] == seq]:
            del self._page_rid[key]

    def advance(self, dt: float) -> List[Tuple[Hashable, int]]:
        """Advance a simulated backend's clock by ``dt`` and poll.  On a
        real backend this is just a poll (time advances by itself)."""
        if isinstance(self.amu.backend, SimBackend):
            self.amu.backend.advance(dt)
        arrived = self.poll()
        if self.tracer.enabled:
            self.tracer.counter("pager", "free_frames", self.pool.n_free)
        return arrived

    # -- issue machinery -----------------------------------------------------
    def _issue(self, qos: QoS, kind: str, seq: Hashable, logical: int,
               submit: Callable[[], int]) -> None:
        if self.windows.has_room(qos):
            self.windows.take(qos)
            rid = submit()
            self._track(rid, kind, seq, logical, qos)
        else:
            self.stats["window_queued"] += 1
            if kind == "aload":
                self._page_rid[(seq, logical)] = _PENDING
            self._pending[qos].append((kind, seq, logical, submit,
                                       self._now()))
            if self.tracer.enabled:
                self.tracer.instant("pager", "actions", "window_queued",
                                    {"seq": seq, "logical": logical,
                                     "kind": kind, "qos": qos.name})

    def _track(self, rid: int, kind: str, seq: Hashable, logical: int,
               qos: QoS, queued_t: Optional[float] = None) -> None:
        self._inflight[rid] = (kind, seq, logical, qos)
        if kind == "aload":
            self._page_rid[(seq, logical)] = rid
        if self.tracer.enabled:
            note = {"seq": str(seq), "logical": logical}
            if queued_t is not None:
                note["window_wait_us"] = (self._now() - queued_t) * 1e6
            blocked = self._blocked_note.pop((seq, logical), None)
            if blocked is not None:
                note["frame_blocked_us"] = blocked
            self.amu.annotate(rid, **note)

    def _pump(self) -> None:
        # latency class drains first, bulk last (§2.2 QoS-ordered issue)
        for qos in (QoS.LATENCY, QoS.STANDARD, QoS.BULK):
            dq = self._pending[qos]
            while dq and self.windows.has_room(qos):
                kind, seq, logical, submit, t_q = dq.popleft()
                self.windows.take(qos)
                rid = submit()
                self._track(rid, kind, seq, logical, qos, queued_t=t_q)

    def _force_issue(self, seq: Hashable, logical: int) -> None:
        for qos, dq in self._pending.items():
            for i, (kind, s, l, submit, t_q) in enumerate(dq):
                if (s, l) == (seq, logical):
                    del dq[i]
                    while not self.windows.has_room(qos):
                        self._drain_one(qos)
                    self.windows.take(qos)
                    rid = submit()
                    self._track(rid, kind, seq, logical, qos, queued_t=t_q)
                    return
        raise PagingError(f"page ({seq!r}, {logical}) not pending")

    def _drain_one(self, qos: QoS) -> None:
        """Make room in a full window by finishing one of its requests.
        A drained request that *failed* is reaped like any other fault —
        window released, ARRIVING page reverted — never treated as a
        successful arrival."""
        for rid, (kind, _, _, q) in list(self._inflight.items()):
            if q is qos:
                req = self.amu.wait(rid)
                if req.error is not None:
                    self._fail_one(rid)
                else:
                    self._finish(rid)
                return
        raise PagingError(f"QoS window {qos.name} full with nothing in flight")

    def _finish(self, rid: int) -> Optional[Tuple[Hashable, int]]:
        """Bookkeeping for one consumed completion id."""
        entry = self._inflight.pop(rid, None)
        if entry is None:
            # foreign request on the shared AMU: forward it to the far
            # tier so its fetch bookkeeping sees the completion too
            if self.tier.amu is self.amu:
                self.tier.complete_rid(rid, self.amu.request(rid).payload)
            return None
        kind, seq, logical, qos = entry
        self.windows.release(qos)
        self._pump()
        if kind != "aload":
            return None
        self._page_rid.pop((seq, logical), None)
        # The sequence may have been dropped while its fetch was in flight.
        try:
            pte = self.table.entry(seq, logical)
        except PagingError:
            return None
        if pte.state is PageState.ARRIVING:
            frame = self.pool.frames[pte.phys]
            frame.data = self.tier.home((seq, logical))
            frame.dirty = False
            frame.tokens = self.tier.tokens_of((seq, logical))
            self.table.mark_resident(seq, logical)
            self.pool.touch(pte.phys)
            self.stats["arrived"] += 1
            if self.tracer.enabled:
                self.tracer.instant("pager", "actions", "arrived",
                                    {"seq": seq, "logical": logical})
            return (seq, logical)
        return None

"""Event-driven serving scheduler (the paper's §2.3.2 model, generalized).

The paper's event-driven programming model drives computation from
memory-completion events: issue many asynchronous accesses, then let
``getfin`` completions — not program order — decide what runs next.
Here the same loop shape schedules *sequences* instead of cache lines:

  * ``TICK`` — one decode step of the serving engine (the compute event
    the paper overlaps transfers against),
  * ``PAGE_ARRIVED`` — a pager ``getfin`` completion flipped a page's
    residency bit; a waiting sequence may now be runnable,
  * ``ADMIT`` / ``PREEMPT`` — capacity decisions made from *free-page
    watermarks* over the device pool, replacing the seed engine's
    free-slot counting: a request is admitted when the pool can hold
    its working set above the low watermark, and a victim is preempted
    when free pages fall below it,
  * ``COMPLETE`` — a sequence finished and released its pages.

The loop itself is deliberately tiny and deterministic: a FIFO event
queue drained to empty each iteration, with handlers registered per
event kind.  Both the serving engine (`repro.serve.engine`) and the
``paged_kv_sweep`` benchmark drive their scheduling through it.
"""

from __future__ import annotations

import collections
import enum
import heapq
import itertools
from dataclasses import dataclass
from typing import Any, Callable, Deque, Dict, List, Tuple

from repro.obs import MetricsRegistry
from repro.paging.page_table import PagePool, PagingError

__all__ = ["EventKind", "Event", "EventLoop", "WatermarkPolicy",
           "DeadlineQueue"]


class EventKind(enum.Enum):
    TICK = "tick"                    # one decode step elapsed
    PAGE_ARRIVED = "page_arrived"    # getfin landed a page (seq, logical)
    ADMIT = "admit"                  # admission decision for a request
    PREEMPT = "preempt"              # a victim must shed pages
    COMPLETE = "complete"            # a sequence finished
    DEADLINE = "deadline"            # a request's SLO deadline passed
    HANDOFF = "handoff"              # prefill graduated a request to the
                                     # shared far tier (disaggregation)


@dataclass
class Event:
    kind: EventKind
    payload: Any = None


@dataclass
class WatermarkPolicy:
    """Free-page watermark admission/preemption rules.

    low
        Frames that must remain free *after* an admission for it to be
        allowed — headroom so active sequences can still grow a page
        without an immediate preemption storm.
    critical
        When free frames fall to/below this, the scheduler should start
        preempting (shedding cold pages) even between admissions.

    The free-SPM-slot counting of the paper's event-driven scheduler
    (§2.3.2) generalized to a two-threshold policy.  Example::

        policy = WatermarkPolicy(low=2, critical=0)
        policy.can_admit(pool, pages_needed=4)   # free - 4 >= 2 ?
        policy.deficit(pool, 4)                  # frames to shed first
    """

    low: int = 1
    critical: int = 0

    def can_admit(self, pool: PagePool, pages_needed: int) -> bool:
        return pool.n_free - pages_needed >= self.low

    def should_preempt(self, pool: PagePool) -> bool:
        return pool.n_free <= self.critical

    def deficit(self, pool: PagePool, pages_needed: int) -> int:
        """Frames that must be freed before ``pages_needed`` fits."""
        return max(0, pages_needed + self.low - pool.n_free)


class DeadlineQueue:
    """Min-heap of (time, payload) deadlines on the engine's virtual
    clock.  Each tick the SLO scheduler pops everything due and posts a
    ``DEADLINE`` event per entry — the timer half of the event-driven
    model (§2.3.2), where passing time (a blown TTFT deadline) is as
    much a scheduling event as an arriving page.

    Example::

        dq = DeadlineQueue()
        dq.schedule(0.050, rid)            # TTFT deadline at t=50ms
        for t, rid in dq.pop_due(clock()):
            loop.post(EventKind.DEADLINE, (t, rid))
    """

    def __init__(self) -> None:
        self._heap: List[Tuple[float, int, Any]] = []
        self._seq = itertools.count()      # FIFO among equal deadlines

    def schedule(self, t: float, payload: Any = None) -> None:
        heapq.heappush(self._heap, (float(t), next(self._seq), payload))

    def pop_due(self, now: float) -> List[Tuple[float, Any]]:
        """All (deadline, payload) entries with deadline <= ``now``."""
        due: List[Tuple[float, Any]] = []
        while self._heap and self._heap[0][0] <= now:
            t, _, payload = heapq.heappop(self._heap)
            due.append((t, payload))
        return due

    def peek(self) -> float:
        """Earliest scheduled deadline (inf when empty)."""
        return self._heap[0][0] if self._heap else float("inf")

    def __len__(self) -> int:
        return len(self._heap)


class EventLoop:
    """FIFO event queue with per-kind handlers, drained to quiescence —
    the paper's §2.3.2 event-driven model as a scheduler skeleton.

    Example (the engine's wiring)::

        loop = EventLoop()
        loop.on(EventKind.PAGE_ARRIVED, lambda ev: land(ev.payload))
        loop.post(EventKind.PAGE_ARRIVED, (rid, logical))
        loop.tick()        # one decode step: post TICK + drain all
    """

    def __init__(self, metrics: "MetricsRegistry" = None) -> None:
        self._q: Deque[Event] = collections.deque()
        self._handlers: Dict[EventKind, List[Callable[[Event], None]]] = \
            collections.defaultdict(list)
        self.ticks = 0
        # Counter-compatible view onto a shared MetricsRegistry, keyed
        # by EventKind (history[EventKind.PREEMPT] etc. work unchanged)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.history = self.metrics.counters("events")

    def on(self, kind: EventKind, handler: Callable[[Event], None]) -> None:
        self._handlers[kind].append(handler)

    def post(self, kind: EventKind, payload: Any = None) -> None:
        self._q.append(Event(kind, payload))

    def tick(self) -> None:
        """Post one TICK and drain — the per-decode-step heartbeat."""
        self.ticks += 1
        self.post(EventKind.TICK, self.ticks)
        self.drain()

    def drain(self, max_events: int = 100_000) -> int:
        """Dispatch queued events (and any they post) until quiescent."""
        n = 0
        while self._q:
            if n >= max_events:
                raise PagingError("event loop livelock: "
                                  f"{max_events} events without quiescing")
            ev = self._q.popleft()
            self.history[ev.kind] += 1
            for h in self._handlers.get(ev.kind, ()):
                h(ev)
            n += 1
        return n

    @property
    def pending(self) -> int:
        return len(self._q)

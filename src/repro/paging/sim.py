"""Deterministic serving-policy simulation over the paging subsystem.

Compares, on the SimBackend's virtual clock, the two KV-transfer
policies the paper contrasts:

  * **blocking whole-sequence fetch** — the seed engine's pattern: one
    coarse AMU request for a sequence's entire KV, waited on before any
    of its tokens decode (transfer and compute strictly serialized),
  * **AMU prefetching pager** — page-granularity LATENCY-QoS aloads of
    the *next* sequence's KV issued while the current one decodes, LRU
    eviction of clean pages for free, BULK writeback of the dirty tail.

Everything runs through the real :class:`~repro.paging.Pager` /
:class:`~repro.paging.PagePool` / :class:`~repro.paging.EventLoop`
against a simulated-latency AMU, so the numbers are deterministic and
the benchmark doubles as an integration test of the subsystem.
"""

from __future__ import annotations

from typing import Dict

from repro.core.amu import AMU, AccessConfig, QoS, SimBackend
from repro.paging.events import EventKind, EventLoop
from repro.paging.page_table import PagePool, PageState, PageTable
from repro.paging.pager import Pager

__all__ = ["simulate_paged_serving", "simulate_mixed_batching",
           "simulate_prefix_reuse", "simulate_slo_schedule",
           "simulate_disagg", "simulate_spec_decode"]


def simulate_paged_serving(
    oversubscription: float,
    *,
    n_seqs: int = 8,
    pages_per_seq: int = 8,
    page_bytes: int = 256 << 10,
    new_tokens: int = 32,
    tick_s: float = 5e-6,
    base_latency: float = 10e-6,
    bandwidth: float = 10e9,
    latency_window: int = 8,
    densify_bandwidth: float = 20e9,
    tracer=None,
    metrics=None,
) -> Dict[str, float]:
    """Serve ``n_seqs`` decode bursts whose KV starts in the far tier,
    with the device pool sized to ``total_pages / oversubscription``.
    Returns virtual-clock timings for both policies plus the pager's
    page hit rate (fraction of pages already resident when a burst
    starts — prefetch that landed in time).

    Also models the *densification tax* the engine paid before decode
    computed on the paged layout directly: every sequence activation
    used to join its pages into a contiguous slot buffer and insert it
    into the batched cache (one full-sequence copy at
    ``densify_bandwidth``), and parking extracted it back out.  The
    ``paged_densify_*`` keys are the paged policy *with* that copy-in/
    copy-out; ``speedup`` (paged, no densification — what the engine
    does now) vs ``speedup_densify`` quantifies what eliminating the
    round-trip buys at the serving level."""
    total_pages = n_seqs * pages_per_seq
    pool_pages = max(pages_per_seq, int(round(total_pages / oversubscription)))
    seq_bytes = pages_per_seq * page_bytes
    total_tokens = n_seqs * new_tokens

    # -- policy 1: blocking whole-sequence fetch ---------------------------
    be = SimBackend(base_latency=base_latency, bandwidth=bandwidth)
    amu = AMU(backend=be, max_outstanding=4)
    cfg = AccessConfig(granularity_bytes=seq_bytes, qos=QoS.STANDARD)
    t0 = be.now
    for _ in range(n_seqs):
        amu.wait(amu.aload(nbytes=seq_bytes, config=cfg))
        be.advance(new_tokens * tick_s)
    blocking_time = be.now - t0

    # -- policy 2: AMU prefetching pager -----------------------------------
    pool = PagePool(pool_pages, page_size=1)
    table = PageTable(pool)
    sim_be = SimBackend(base_latency=base_latency, bandwidth=bandwidth)
    pamu = AMU(backend=sim_be, max_outstanding=latency_window + 4)
    if tracer is not None:
        tracer.clock = lambda: sim_be.now    # spans on the sim clock
    pager = Pager(pool, table, pamu, page_nbytes=page_bytes,
                  latency_window=latency_window, bulk_window=4,
                  tracer=tracer, metrics=metrics)
    loop = EventLoop(metrics=metrics)
    loop.on(EventKind.PAGE_ARRIVED,
            lambda ev: pool.touch(table.entry(*ev.payload).phys))
    for s in range(n_seqs):
        table.register_parked(s, pages_per_seq)
        for l in range(pages_per_seq):
            pager.store_far(s, l, None)

    hits = 0
    t0 = pamu.backend.now
    for s in range(n_seqs):
        hits += len(table.logical_pages(s, PageState.RESIDENT))
        pager.wait_seq(s)                       # demand-fetch the misses
        pinned = []
        for l in range(pages_per_seq):
            phys = table.entry(s, l).phys
            pool.pin(phys)
            pool.touch(phys)
            pinned.append(phys)
        nxt = s + 1
        for _ in range(new_tokens):             # decode burst
            if nxt < n_seqs:
                short = len(table.logical_pages(nxt, PageState.PARKED))
                if short and pool.n_free < short:
                    pager.evict_lru(short - pool.n_free)
                pager.prefetch_seq(nxt, tail_first=True)
            for arrived in pager.advance(tick_s):
                loop.post(EventKind.PAGE_ARRIVED, arrived)
            loop.tick()
        for phys in pinned:
            pool.unpin(phys)
        pool.mark_dirty(pinned[-1])             # decode wrote the tail page
    paged_time = pamu.backend.now - t0

    # densification tax of the pre-paged-decode engine: one whole-sequence
    # join on every activation and one extract on every deactivation
    # (2 x seq_bytes of device copies per sequence served).
    densify_time = n_seqs * 2 * seq_bytes / densify_bandwidth
    paged_densify_time = paged_time + densify_time

    return {
        "oversubscription": oversubscription,
        "pool_pages": pool_pages,
        "blocking_time": blocking_time,
        "paged_time": paged_time,
        "speedup": blocking_time / paged_time,
        "hit_rate": hits / total_pages,
        "blocking_us_per_token": blocking_time / total_tokens * 1e6,
        "paged_us_per_token": paged_time / total_tokens * 1e6,
        "paged_densify_us_per_token": paged_densify_time / total_tokens * 1e6,
        "speedup_densify": blocking_time / paged_densify_time,
        "densify_eliminated_frac": densify_time / paged_densify_time,
        "bulk_writebacks": pager.stats["writeback"],
        "clean_evictions": pager.stats["clean_evict"],
        "demand_fetches": pager.stats["demand_fetch"],
    }


def simulate_mixed_batching(
    oversubscription: float,
    *,
    max_batch: int = 4,
    prompt_tokens: int = 128,
    new_tokens: int = 32,
    page_size: int = 16,
    chunk_tokens: int = 8,
    chunk_slots: int = 2,
    low_watermark: int = 1,
    t_decode_step: float = 20e-6,
    t_prefill_token: float = 1.5e-6,
) -> Dict[str, float]:
    """Serial dense prefill vs chunked continuous batching, deterministic.

    ``oversubscription`` here is *request* oversubscription — offered
    load versus slot capacity: ``oversubscription * max_batch * 4``
    requests arrive at t=0 against ``max_batch`` decode slots (the
    page-pool oversubscription axis is ``paged_kv_sweep``'s job; this
    bench isolates the admission bubble, so the pool holds every slot's
    working set with watermark headroom).  Two admission policies over
    one virtual clock:

    * **serial dense prefill** — the pre-chunking engine: admitting a
      request stalls *every* running slot for the whole prompt's
      prefill (``prompt_tokens * t_prefill_token``), then decode
      resumes: transfer^W prefill and decode strictly serialized, the
      admission-bubble analogue of the paper's blocking far-memory
      access (§1),
    * **chunked mixed steps** — the chunk-queue engine: each step runs
      one decode token for every running slot *fused* with up to
      ``chunk_slots`` prompt chunks.  Decode steps are memory-bound on
      weight traffic while a prompt chunk is compute-dense, so the
      fused step costs ``max(t_decode_step, chunk_work)`` — the chunk
      FLOPs hide under the decode step's weight streaming exactly as
      the AMU hides far-memory latency under compute (the overlap
      thesis at serving granularity; 2404.11044 makes the same case
      for massive request-level parallelism).

    Returns mean/p95 time-to-first-token and decode tokens/s for both
    policies; ``ttft_speedup > 1`` means chunking improved mean TTFT.
    """
    n_seqs = max(1, int(round(oversubscription * max_batch * 4)))
    pages_per_seq = -(-(prompt_tokens + new_tokens) // page_size)
    pool_pages = max_batch * pages_per_seq + low_watermark

    def admission_pages(decoded: int) -> int:
        return -(-prompt_tokens // page_size) if decoded == 0 else \
            -(-(prompt_tokens + decoded + 1) // page_size)

    def run(chunked: bool) -> Dict[str, float]:
        now = 0.0
        free_pages = pool_pages
        queue = list(range(n_seqs))
        running: Dict[int, int] = {}        # seq -> decoded tokens
        prefilling: Dict[int, int] = {}     # seq -> prefilled tokens
        held: Dict[int, int] = {}           # seq -> pages held
        ttft = [0.0] * n_seqs
        done = 0
        decode_steps = 0
        decode_time = 0.0
        while done < n_seqs:
            # admit while slots + pages-above-watermark allow
            while queue and (len(running) + len(prefilling)) < max_batch:
                need = -(-prompt_tokens // page_size)
                if free_pages - need < low_watermark:
                    break
                seq = queue.pop(0)
                free_pages -= need
                held[seq] = need
                if chunked:
                    prefilling[seq] = 0
                else:
                    now += prompt_tokens * t_prefill_token  # global stall
                    ttft[seq] = now
                    running[seq] = 1        # first token from prefill
            if not running and not prefilling:
                break
            # one engine step
            chunk_work = 0
            if chunked:
                for seq in sorted(prefilling)[:chunk_slots]:
                    take = min(chunk_tokens,
                               prompt_tokens - prefilling[seq])
                    prefilling[seq] += take
                    chunk_work += take
                step = max(t_decode_step if running else 0.0,
                           chunk_work * t_prefill_token)
                step = step or t_decode_step
            else:
                step = t_decode_step
            now += step
            if running:
                decode_steps += 1
                decode_time += step
            for seq in sorted(prefilling):
                if prefilling[seq] >= prompt_tokens:
                    del prefilling[seq]
                    ttft[seq] = now
                    running[seq] = 1
            for seq in sorted(running):
                # grow a page at each boundary (skip when pool is dry:
                # the modeled engine preempts; we charge no extra time)
                need = admission_pages(running[seq]) - held[seq]
                if need > 0 and free_pages >= need:
                    free_pages -= need
                    held[seq] += need
                running[seq] += 1
                if running[seq] >= new_tokens:
                    free_pages += held.pop(seq)
                    del running[seq]
                    done += 1
        total_new = n_seqs * new_tokens
        ttft_sorted = sorted(ttft)
        return {
            "ttft_mean": sum(ttft) / n_seqs,
            "ttft_p95": ttft_sorted[min(n_seqs - 1,
                                        int(0.95 * n_seqs))],
            "wall": now,
            "decode_tok_per_s": total_new / now,
            "decode_steps": decode_steps,
            # mean decode-step cost = inter-token latency: chunk work
            # stretches a mixed step to max(t_decode_step, chunk FLOPs)
            "tpot_mean": decode_time / max(1, decode_steps),
        }

    dense = run(chunked=False)
    mixed = run(chunked=True)
    return {
        "oversubscription": oversubscription,
        "pool_pages": pool_pages,
        "ttft_dense_us": dense["ttft_mean"] * 1e6,
        "ttft_mixed_us": mixed["ttft_mean"] * 1e6,
        "ttft_p95_dense_us": dense["ttft_p95"] * 1e6,
        "ttft_p95_mixed_us": mixed["ttft_p95"] * 1e6,
        "ttft_speedup": dense["ttft_mean"] / mixed["ttft_mean"],
        "tok_per_s_dense": dense["decode_tok_per_s"],
        "tok_per_s_mixed": mixed["decode_tok_per_s"],
        "throughput_speedup": (mixed["decode_tok_per_s"]
                               / dense["decode_tok_per_s"]),
        "tpot_dense_us": dense["tpot_mean"] * 1e6,
        "tpot_mixed_us": mixed["tpot_mean"] * 1e6,
    }


def simulate_spec_decode(
    oversubscription: float,
    *,
    traffic: str = "repetitive",
    max_batch: int = 4,
    prompt_tokens: int = 64,
    new_tokens: int = 48,
    speculate_k: int = 4,
    ngram: int = 3,
    period: int = 8,
    vocab: int = 512,
    seed: int = 0,
    t_decode_step: float = 20e-6,
    t_prefill_token: float = 1.5e-6,
    c_verify: float = 0.15,
) -> Dict[str, float]:
    """Self-speculative verify-K decode vs single-step, deterministic.

    A burst of ``oversubscription * max_batch * 4`` requests is served
    on ``max_batch`` decode slots over a virtual clock; each request's
    *true* token stream is synthetic and known up front, so greedy
    acceptance is exact prefix matching against it — the same algebra
    the engine's verify step runs against argmax logits.  Two traffic
    shapes:

    * ``"repetitive"`` — each stream cycles a per-request random
      ``period``-gram, the prompt-lookup proposer's best case (code,
      templated text); trailing n-grams recur, so drafts are nearly
      always the true continuation,
    * ``"adversarial"`` — i.i.d. uniform random tokens; with a large
      vocabulary the trailing n-gram essentially never recurs, so the
      proposer rarely fires and almost nothing it drafts survives.

    Drafting uses the REAL :class:`~repro.serve.speculate.NgramProposer`
    over each request's prompt + generated history, not a model of it.
    A speculative step's cost scales with the widest draft actually
    batched that step — the verify matmul's extra query rows —
    ``t_decode_step * (1 + c_verify * K_step)``, and each slot advances
    ``1 + accepted``; the single-step baseline pays ``t_decode_step``
    per token.  Pages are not the constraint here (that is
    ``paged_kv_sweep``); admission is slot-bound with serial prefill on
    both sides, so the ratio isolates verify-K compression.

    Returns tokens/s for both policies, the throughput speedup, and
    mean accepted-K per drafting slot (the acceptance telemetry the
    engine reports from its ``spec_*`` tracks).
    """
    import random as _random

    from repro.serve.speculate import NgramProposer

    if traffic not in ("repetitive", "adversarial"):
        raise ValueError(f"unknown traffic shape {traffic!r}")
    n_seqs = max(1, int(round(oversubscription * max_batch * 4)))
    total_len = prompt_tokens + new_tokens
    streams = []
    for s in range(n_seqs):
        rng = _random.Random((seed, traffic, s))
        if traffic == "repetitive":
            pattern = [rng.randrange(vocab) for _ in range(period)]
            streams.append([pattern[i % period] for i in range(total_len)])
        else:
            streams.append([rng.randrange(vocab) for _ in range(total_len)])

    def run(speculative: bool) -> Dict[str, float]:
        now = 0.0
        queue = list(range(n_seqs))
        running: Dict[int, int] = {}        # seq -> tokens generated
        proposer = NgramProposer(n=ngram, k=speculate_k)
        drafted = accepted = spec_steps = n_drafts = 0
        done = 0
        while done < n_seqs:
            while queue and len(running) < max_batch:
                seq = queue.pop(0)
                now += prompt_tokens * t_prefill_token  # serial prefill
                running[seq] = 0
            k_step = 0
            advances: Dict[int, int] = {}
            for seq in sorted(running):
                gen = running[seq]
                budget = new_tokens - gen - 1
                adv = 1
                if speculative and budget > 0:
                    hist = streams[seq][:prompt_tokens + gen]
                    draft = proposer.propose(seq, hist)[:budget]
                    if draft:
                        true_tail = streams[seq][prompt_tokens + gen:]
                        acc = 0
                        while acc < len(draft) \
                                and draft[acc] == true_tail[acc]:
                            acc += 1
                        drafted += len(draft)
                        accepted += acc
                        n_drafts += 1
                        k_step = max(k_step, len(draft))
                        adv = 1 + acc
                advances[seq] = adv
            now += t_decode_step * (1.0 + c_verify * k_step)
            if k_step:
                spec_steps += 1
            for seq, adv in advances.items():
                running[seq] += adv
                if running[seq] >= new_tokens:
                    del running[seq]
                    proposer.drop(seq)
                    done += 1
        return {
            "wall": now,
            "tok_per_s": n_seqs * new_tokens / now,
            "drafted": drafted,
            "accepted": accepted,
            "spec_steps": spec_steps,
            "n_drafts": n_drafts,
        }

    plain = run(speculative=False)
    spec = run(speculative=True)
    return {
        "oversubscription": oversubscription,
        "n_seqs": float(n_seqs),
        "tok_per_s_plain": plain["tok_per_s"],
        "tok_per_s_spec": spec["tok_per_s"],
        "throughput_speedup": spec["tok_per_s"] / plain["tok_per_s"],
        "drafted": float(spec["drafted"]),
        "accepted": float(spec["accepted"]),
        "mean_accepted_k": (spec["accepted"] / spec["n_drafts"]
                            if spec["n_drafts"] else 0.0),
        "acceptance_rate": (spec["accepted"] / spec["drafted"]
                            if spec["drafted"] else 0.0),
    }


def simulate_disagg(
    oversubscription: float,
    *,
    max_batch: int = 4,
    prompt_tokens: int = 128,
    new_tokens: int = 32,
    page_size: int = 16,
    chunk_tokens: int = 8,
    chunk_slots: int = 2,
    low_watermark: int = 1,
    t_decode_step: float = 20e-6,
    t_prefill_token: float = 1.5e-6,
    page_bytes: int = 256 << 10,
    base_latency: float = 10e-6,
    bandwidth: float = 10e9,
) -> Dict[str, float]:
    """Two-pool disaggregated prefill/decode vs fused mixed batching, at
    matched device counts, deterministic.

    Both sides get **two devices** and the same offered load
    (``oversubscription * max_batch * 4`` requests *per device*, all at
    t=0):

    * **fused** — two independent ``make_mixed_step`` engines, each
      taking half the traffic: every step fuses one decode token per
      running slot with up to ``chunk_slots`` prompt chunks, so chunk
      FLOPs stretch decode steps (``max(t_decode_step, chunk_work)``)
      and decode slots throttle prefill throughput — the interference
      disaggregation removes,
    * **disaggregated** — one PREFILL device + one DECODE device over a
      shared far tier.  The prefill device runs prompts back-to-back at
      full compute density (no decode interference) and emits each
      request's **first token itself** (the engine's PREFILL role
      finishes at first token), then graduates: a BULK astore parks the
      prompt's KV pages + aux residue in the shared tier
      (``base_latency + pages * page_bytes / bandwidth``, overlapped
      with the next prompt's compute).  The decode device admits each
      handoff through a LATENCY fetch of those pages — overlapped with
      its running decode batch, exactly like the engine's resume
      machinery — and decodes the remaining tokens at an *unstretched*
      ``t_decode_step`` (no chunk work in its steps).

    The trade this exposes is the one production disaggregation is
    deployed for: the fused engines win raw TTFT and aggregate
    throughput at these scales (chunking already hides prefill FLOPs
    under decode weight streaming, and two fused devices prefill two
    prompt streams in parallel), while the disaggregated split wins
    **inter-token latency** — the decode device's steps are never
    stretched by chunk work, so TPOT is flat ``t_decode_step`` instead
    of ``max(t_decode_step, chunk_work)`` whenever prompts are in
    flight.  Returns mean TTFT, mean TPOT and aggregate decode
    tokens/s for both sides; ``ttft_ratio`` / ``tpot_ratio`` /
    ``goodput_ratio`` are oriented so > 1 always means disaggregation
    won that axis.
    """
    per_dev = max(1, int(round(oversubscription * max_batch * 4)))
    n_seqs = 2 * per_dev
    pages = -(-prompt_tokens // page_size)
    xfer = base_latency + pages * page_bytes / bandwidth

    # -- fused baseline: one engine's mixed-batching loop, half traffic
    # (the second device is identical and independent)
    fused = simulate_mixed_batching(
        oversubscription, max_batch=max_batch,
        prompt_tokens=prompt_tokens, new_tokens=new_tokens,
        page_size=page_size, chunk_tokens=chunk_tokens,
        chunk_slots=chunk_slots, low_watermark=low_watermark,
        t_decode_step=t_decode_step, t_prefill_token=t_prefill_token)
    fused_ttft = fused["ttft_mixed_us"] * 1e-6
    fused_tpot = fused["tpot_mixed_us"] * 1e-6
    fused_tok_s = 2 * fused["tok_per_s_mixed"]        # two devices

    # -- disaggregated: prefill device serialises every prompt ---------
    # (dense full-compute prefill + the first-token step; graduation's
    # BULK park overlaps the next prompt)
    now = 0.0
    ttft = []
    ready = []                   # handoff visible to the decode side at
    for _ in range(n_seqs):      # first-token time + BULK park
        now += prompt_tokens * t_prefill_token + t_decode_step
        ttft.append(now)
        ready.append(now + xfer)

    # -- decode device: admit handoffs through a LATENCY fetch that
    # overlaps the running batch, then pure decode steps
    t = 0.0
    remaining = {i: new_tokens - 1 for i in range(n_seqs)}
    running: Dict[int, int] = {}
    nxt = 0
    decoded = 0
    while remaining or running:
        while nxt < n_seqs and len(running) < max_batch:
            # fetch overlaps decode: the admission lands at whichever is
            # later of "pages arrived" and "a step boundary passed"
            at = ready[nxt] + xfer
            if at > t and running:
                break            # keep decoding; admit once it lands
            t = max(t, at)
            running[nxt] = remaining.pop(nxt)
            nxt += 1
        if not running:
            if nxt < n_seqs:
                t = max(t, ready[nxt] + xfer)
                continue
            break
        t += t_decode_step       # one unstretched decode step, all slots
        decoded += len(running)
        for seq in sorted(running):
            running[seq] -= 1
            if running[seq] <= 0:
                del running[seq]
    disagg_ttft = sum(ttft) / n_seqs
    # aggregate completion: first tokens on the prefill device, the rest
    # on the decode device; the decode device finishes last
    wall = max(t, ttft[-1])
    disagg_tok_s = n_seqs * new_tokens / wall

    return {
        "oversubscription": oversubscription,
        "n_seqs": n_seqs,
        "handoff_xfer_us": xfer * 1e6,
        "ttft_fused_us": fused_ttft * 1e6,
        "ttft_disagg_us": disagg_ttft * 1e6,
        "ttft_ratio": fused_ttft / disagg_ttft,
        # the decode device's steps are never stretched by chunk work
        "tpot_fused_us": fused_tpot * 1e6,
        "tpot_disagg_us": t_decode_step * 1e6,
        "tpot_ratio": fused_tpot / t_decode_step,
        "tok_per_s_fused": fused_tok_s,
        "tok_per_s_disagg": disagg_tok_s,
        "goodput_ratio": disagg_tok_s / fused_tok_s,
    }


def simulate_prefix_reuse(
    shared_frac: float,
    *,
    oversubscription: float = 2.0,
    max_batch: int = 4,
    prefix_tokens: int = 240,
    tail_tokens: int = 16,
    new_tokens: int = 32,
    page_size: int = 16,
    chunk_tokens: int = 16,
    chunk_slots: int = 2,
    low_watermark: int = 1,
    t_decode_step: float = 20e-6,
    t_prefill_token: float = 2.5e-6,
    t_page_fetch: float = 15e-6,
) -> Dict[str, float]:
    """Cross-request prefix sharing vs recompute-everything, deterministic.

    Models system-prompt traffic at ``oversubscription`` x request load:
    ``shared_frac`` of the burst's requests carry an identical
    ``prefix_tokens``-long prefix ahead of a unique tail (defaults: a
    240-token system prompt over a 16-token user turn — the
    thousands-of-users-one-template regime prefix caching targets,
    with prompt chunks compute-dense next to the memory-bound decode
    step).  Both engines
    are the chunk-queue engine of :func:`simulate_mixed_batching`; the
    *sharing* engine additionally runs the
    :mod:`repro.paging.prefix_cache` policy:

    * the first shared request to finish its prefix chunks *interns*
      the full prefix pages,
    * every later shared request maps those pages instead of computing
      them — only the boundary page (the hash covers full pages and the
      first token must still produce logits) and the unique tail pay
      prefill FLOPs,
    * under pool pressure the interned pages are evicted to the far
      tier (clean, for free — the intern writes the far home); a hit
      then pays one overlapped LATENCY page-fetch round
      (``t_page_fetch``, all pages under one window) before its first
      chunk instead of the chunks themselves.

    This is the serving-level aggregation claim of the follow-up AMU
    paper (2404.11044): far memory plus massive outstanding aloads
    turns recomputation into cheap overlappable transfers.  Returns
    mean/p95 TTFT for both engines, the TTFT speedup, and the fraction
    of prefill FLOPs the sharing engine skipped.
    """
    n_seqs = max(1, int(round(oversubscription * max_batch * 4)))
    n_shared = int(round(shared_frac * n_seqs))
    prompt_tokens = prefix_tokens + tail_tokens
    pages_per_seq = -(-(prompt_tokens + new_tokens) // page_size)
    pool_pages = max_batch * pages_per_seq + low_watermark
    # full pages only, and the last prompt token always recomputes
    hit_tokens = min(((prefix_tokens - 1) // page_size) * page_size,
                     ((prompt_tokens - 1) // page_size) * page_size)
    hit_pages = hit_tokens // page_size

    def run(sharing: bool) -> Dict[str, float]:
        now = 0.0
        free_pages = pool_pages
        queue = list(range(n_seqs))          # seq < n_shared: shared prefix
        running: Dict[int, int] = {}         # seq -> decoded tokens
        prefilling: Dict[int, int] = {}      # seq -> prefilled tokens
        ready_at: Dict[int, float] = {}      # far-hit fetch completion time
        held: Dict[int, int] = {}
        ttft = [0.0] * n_seqs
        done = 0
        interned = False
        prefill_tokens_done = 0
        far_hit_admissions = 0
        while done < n_seqs:
            while queue and (len(running) + len(prefilling)) < max_batch:
                need = -(-prompt_tokens // page_size)
                if free_pages - need < low_watermark:
                    break
                seq = queue.pop(0)
                shared = seq < n_shared
                start = 0
                if sharing and shared and interned and hit_pages:
                    start = hit_tokens
                    # interned pages resident only while the pool has
                    # slack; at real oversubscription they live in the
                    # far tier and the hit pays one overlapped fetch
                    if free_pages - need < hit_pages + low_watermark:
                        ready_at[seq] = now + t_page_fetch
                        far_hit_admissions += 1
                free_pages -= need
                held[seq] = need
                prefilling[seq] = start
            if not running and not prefilling:
                break
            chunk_work = 0
            for seq in sorted(prefilling)[:chunk_slots]:
                if ready_at.get(seq, 0.0) > now:
                    continue                 # pages still arriving
                take = min(chunk_tokens, prompt_tokens - prefilling[seq])
                prefilling[seq] += take
                chunk_work += take
            step = max(t_decode_step if running else 0.0,
                       chunk_work * t_prefill_token)
            step = step or t_decode_step
            now += step
            prefill_tokens_done += chunk_work
            for seq in sorted(prefilling):
                if prefilling[seq] >= prompt_tokens:
                    del prefilling[seq]
                    ready_at.pop(seq, None)
                    ttft[seq] = now
                    running[seq] = 1
                    if seq < n_shared:
                        interned = True
            for seq in sorted(running):
                need = (-(-(prompt_tokens + running[seq] + 1) // page_size)
                        - held[seq])
                if need > 0 and free_pages >= need:
                    free_pages -= need
                    held[seq] += need
                running[seq] += 1
                if running[seq] >= new_tokens:
                    free_pages += held.pop(seq)
                    del running[seq]
                    done += 1
        ttft_sorted = sorted(ttft)
        return {
            "ttft_mean": sum(ttft) / n_seqs,
            "ttft_p95": ttft_sorted[min(n_seqs - 1, int(0.95 * n_seqs))],
            "wall": now,
            "prefill_tokens": prefill_tokens_done,
            "far_hits": far_hit_admissions,
        }

    plain = run(sharing=False)
    shared = run(sharing=True)
    return {
        "shared_frac": shared_frac,
        "oversubscription": oversubscription,
        "hit_tokens": hit_tokens,
        "ttft_plain_us": plain["ttft_mean"] * 1e6,
        "ttft_shared_us": shared["ttft_mean"] * 1e6,
        "ttft_p95_plain_us": plain["ttft_p95"] * 1e6,
        "ttft_p95_shared_us": shared["ttft_p95"] * 1e6,
        "ttft_speedup": plain["ttft_mean"] / max(shared["ttft_mean"], 1e-30),
        "prefill_tokens_plain": plain["prefill_tokens"],
        "prefill_tokens_shared": shared["prefill_tokens"],
        "prefill_flops_saved_frac": (
            1.0 - shared["prefill_tokens"] / max(1, plain["prefill_tokens"])),
        "far_hits": shared["far_hits"],
        "wall_speedup": plain["wall"] / max(shared["wall"], 1e-30),
    }


def simulate_slo_schedule(
    oversub: float,
    *,
    max_batch: int = 4,
    n_requests: int = 160,
    page_size: int = 16,
    chunk_tokens: int = 16,
    chunk_slots: int = 2,
    low_watermark: int = 1,
    batch_headroom: int = 2,
    t_decode_step: float = 20e-6,
    t_prefill_token: float = 1.5e-6,
    t_page_fetch: float = 30e-6,
    ttft_slo_steps: float = 75.0,
    tpot_slo_steps: float = 6.0,
    seed: int = 0,
) -> Dict[str, float]:
    """Watermark-FIFO vs SLO-aware scheduling on one production trace.

    Draws ``n_requests`` arrivals from :mod:`repro.serve.workload`
    (bursty diurnal interarrivals, lognormal prompts, Zipf outputs,
    half interactive with TTFT/TPOT SLOs, half batch caring only about
    completion) and rescales arrival times so the offered load is
    exactly ``oversub`` times the *measured* capacity: a calibration
    run with every arrival at t=0 gives the service-limited makespan
    (chunk-slot limits and partial occupancy included), and the
    arrival horizon is that makespan over ``oversub``.  SLOs are
    expressed in decode steps (``ttft_slo_steps``/``tpot_slo_steps``)
    so they track the clock model.  The same trace then runs under
    the engine's two :class:`~repro.serve.engine.SchedulerPolicy`
    flavours, modeled on the chunk-queue virtual clock of
    :func:`simulate_mixed_batching`:

    * **watermark** — FIFO admission whenever a slot is free and the
      pool sits above the low watermark, arrival-order chunk slots, no
      preemption: pure utilisation scheduling, blind to tiers,
    * **slo** — EDF queue ordering with interactive ahead of batch,
      batch admissions shed while free pages sit within
      ``batch_headroom`` of the watermark, and a waiting interactive
      request preempts the maximum-slack running batch request (its
      pages written back BULK; the resume later pays one overlapped
      ``t_page_fetch`` before decoding again, the LATENCY refetch).

    Goodput counts only tokens from requests that met *their own*
    SLOs (batch, unconstrained, always attains on completion), so
    serving a doomed request is wasted work — the metric the SLO
    policy maximises and utilisation scheduling leaves on the table.
    Returns interactive goodput under both policies and their ratio
    (``goodput_ratio > 1`` means tier-aware scheduling won), per-tier
    attainment, interactive TTFT p95s and the preempt/shed counts.
    """
    # imported lazily: repro.serve imports repro.paging, not vice versa
    from repro.serve.workload import WorkloadSpec, generate

    ttft_slo = ttft_slo_steps * t_decode_step
    tpot_slo = tpot_slo_steps * t_decode_step
    trace = generate(n_requests,
                     WorkloadSpec(rate=1000.0, ttft_slo=ttft_slo,
                                  tpot_slo=tpot_slo),
                     seed=seed)
    n = len(trace)
    pages = [-(-(wr.prompt_len + wr.output_len) // page_size)
             for wr in trace]
    pool_pages = (max_batch * pages[int(0.9 * (n - 1))]
                  + low_watermark)
    pool_pages = max(pool_pages, max(pages) + low_watermark)
    interactive = [wr.tier == 0 for wr in trace]   # Tier.INTERACTIVE

    def run(slo_aware: bool, arrival: list,
            span: float = 1.0) -> Dict[str, float]:
        now = 0.0
        free = pool_pages
        nxt = 0                              # next trace index to arrive
        queue: list = []
        running: Dict[int, int] = {}         # idx -> decoded tokens
        prefilling: Dict[int, int] = {}      # idx -> prefilled tokens
        resume_at: Dict[int, float] = {}     # parked resume: pages landing
        held: Dict[int, int] = {}
        t_first = [None] * n
        t_last = [0.0] * n
        parked_progress: Dict[int, int] = {}  # idx -> decoded when parked
        done = 0
        preempts = 0
        sheds = 0

        def deadline(i: int) -> float:
            wr = trace[i]
            if t_first[i] is None:
                return (arrival[i] + wr.ttft_slo
                        if wr.ttft_slo is not None else float("inf"))
            return (t_last[i] + wr.tpot_slo
                    if wr.tpot_slo is not None else float("inf"))

        while done < n:
            while nxt < n and arrival[nxt] <= now:
                queue.append(nxt)
                nxt += 1
            if slo_aware:
                queue.sort(key=lambda i: (int(trace[i].tier), deadline(i),
                                          arrival[i]))
            idle = not running and not prefilling
            shed_here = False
            while queue and (len(running) + len(prefilling)) < max_batch:
                i = queue[0]
                need = pages[i]
                if (slo_aware and not idle
                        and trace[i].tier != 0
                        and free - need < low_watermark + batch_headroom):
                    shed_here = True         # shed batch under pressure
                    break
                if free - need < low_watermark:
                    break
                queue.pop(0)
                free -= need
                held[i] = need
                if i in parked_progress:     # resume: refetch then decode
                    running[i] = parked_progress.pop(i)
                    resume_at[i] = now + t_page_fetch
                else:
                    prefilling[i] = 0
            if shed_here:
                sheds += 1
            # a waiting interactive request evicts the max-slack batch one
            if (slo_aware and queue
                    and (len(running) + len(prefilling)) >= max_batch):
                head = queue[0]
                batch_running = [i for i in running
                                 if not interactive[i]
                                 and resume_at.get(i, 0.0) <= now]
                if (interactive[head] and t_first[head] is None
                        and batch_running):
                    victim = max(batch_running,
                                 key=lambda i: (trace[i].output_len
                                                - running[i], -arrival[i]))
                    parked_progress[victim] = running.pop(victim)
                    free += held.pop(victim)
                    resume_at.pop(victim, None)
                    queue.append(victim)
                    preempts += 1
                    continue                 # re-sort, admit the head
            if not running and not prefilling:
                if not queue and nxt < n:
                    now = max(now, arrival[nxt])   # fast-forward idle gap
                    continue
                if queue:                    # pool-blocked head: wait it out
                    now += t_decode_step
                    continue
                break
            # one fused engine step: decode for every live slot + chunks
            chunk_work = 0
            order = sorted(prefilling, key=(
                (lambda i: (int(trace[i].tier), deadline(i), arrival[i]))
                if slo_aware else (lambda i: arrival[i])))
            for i in order[:chunk_slots]:
                take = min(chunk_tokens, trace[i].prompt_len - prefilling[i])
                prefilling[i] += take
                chunk_work += take
            live = [i for i in running if resume_at.get(i, 0.0) <= now]
            step = max(t_decode_step if live else 0.0,
                       chunk_work * t_prefill_token)
            step = step or t_decode_step
            now += step
            for i in sorted(prefilling):
                if prefilling[i] >= trace[i].prompt_len:
                    del prefilling[i]
                    t_first[i] = now
                    t_last[i] = now
                    running[i] = 1           # first token from prefill
            for i in sorted(running):
                if resume_at.get(i, 0.0) > now:
                    continue                 # parked pages still in flight
                resume_at.pop(i, None)
                running[i] += 1
                t_last[i] = now
                if running[i] >= trace[i].output_len:
                    free += held.pop(i)
                    del running[i]
                    done += 1

        # goodput normalizes by the shared arrival horizon (``span``),
        # not this run's own makespan: both policies face the same
        # offered load over the same window, and the batch drain tail
        # (which shedding deliberately lengthens) should not dilute
        # interactive goodput.
        elapsed = max(now, 1e-30)
        good_tokens = 0
        int_attained = 0
        int_total = 0
        int_ttft = []
        batch_tokens = 0
        for i, wr in enumerate(trace):
            ttft = (t_first[i] - arrival[i]
                    if t_first[i] is not None else float("inf"))
            tpot = ((t_last[i] - t_first[i]) / (wr.output_len - 1)
                    if t_first[i] is not None and wr.output_len > 1 else 0.0)
            ok = ((wr.ttft_slo is None or ttft <= wr.ttft_slo)
                  and (wr.tpot_slo is None or tpot <= wr.tpot_slo))
            if interactive[i]:
                int_total += 1
                int_ttft.append(ttft)
                if ok:
                    int_attained += 1
                    good_tokens += wr.output_len
            else:
                batch_tokens += wr.output_len
        int_ttft.sort()
        return {
            "goodput": good_tokens / max(span, 1e-30),
            "attain": int_attained / max(1, int_total),
            "ttft_p95": int_ttft[min(len(int_ttft) - 1,
                                     int(0.95 * len(int_ttft)))]
            if int_ttft else 0.0,
            "ttft_p99": int_ttft[min(len(int_ttft) - 1,
                                     int(0.99 * len(int_ttft)))]
            if int_ttft else 0.0,
            "batch_tok_per_s": batch_tokens / max(span, 1e-30),
            "wall": elapsed,
            "preempts": preempts,
            "sheds": sheds,
        }

    # self-calibrate capacity: the service-limited makespan with every
    # arrival at t=0 is what max_batch slots can actually do on this
    # trace (chunk-slot limits and partial occupancy included), so the
    # offered load is exactly ``oversub`` x measured capacity.
    makespan = run(slo_aware=False, arrival=[0.0] * n)["wall"]
    horizon = makespan / max(oversub, 1e-9)
    scale = horizon / max(trace[-1].arrival_t, 1e-30)
    arrival = [wr.arrival_t * scale for wr in trace]

    wm = run(slo_aware=False, arrival=arrival, span=horizon)
    slo = run(slo_aware=True, arrival=arrival, span=horizon)
    return {
        "oversub": oversub,
        "pool_pages": pool_pages,
        "n_requests": float(n),
        "int_goodput_wm": wm["goodput"],
        "int_goodput_slo": slo["goodput"],
        "goodput_ratio": slo["goodput"] / max(wm["goodput"], 1e-30),
        "int_attain_wm": wm["attain"],
        "int_attain_slo": slo["attain"],
        "ttft_p95_wm_us": wm["ttft_p95"] * 1e6,
        "ttft_p95_slo_us": slo["ttft_p95"] * 1e6,
        "ttft_p99_wm_us": wm["ttft_p99"] * 1e6,
        "ttft_p99_slo_us": slo["ttft_p99"] * 1e6,
        "batch_tok_per_s_wm": wm["batch_tok_per_s"],
        "batch_tok_per_s_slo": slo["batch_tok_per_s"],
        "preemptions_slo": float(slo["preempts"]),
        "shed_admissions_slo": float(slo["sheds"]),
        "wall_wm": wm["wall"],
        "wall_slo": slo["wall"],
    }

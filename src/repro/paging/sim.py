"""Deterministic serving-policy simulation over the paging subsystem.

Compares, on the SimBackend's virtual clock, the two KV-transfer
policies the paper contrasts:

  * **blocking whole-sequence fetch** — the seed engine's pattern: one
    coarse AMU request for a sequence's entire KV, waited on before any
    of its tokens decode (transfer and compute strictly serialized),
  * **AMU prefetching pager** — page-granularity LATENCY-QoS aloads of
    the *next* sequence's KV issued while the current one decodes, LRU
    eviction of clean pages for free, BULK writeback of the dirty tail.

Everything runs through the real :class:`~repro.paging.Pager` /
:class:`~repro.paging.PagePool` / :class:`~repro.paging.EventLoop`
against a simulated-latency AMU, so the numbers are deterministic and
the benchmark doubles as an integration test of the subsystem.
"""

from __future__ import annotations

from typing import Dict

from repro.core.amu import AMU, AccessConfig, QoS, SimBackend
from repro.paging.events import EventKind, EventLoop
from repro.paging.page_table import PagePool, PageState, PageTable
from repro.paging.pager import Pager

__all__ = ["simulate_paged_serving"]


def simulate_paged_serving(
    oversubscription: float,
    *,
    n_seqs: int = 8,
    pages_per_seq: int = 8,
    page_bytes: int = 256 << 10,
    new_tokens: int = 32,
    tick_s: float = 5e-6,
    base_latency: float = 10e-6,
    bandwidth: float = 10e9,
    latency_window: int = 8,
    densify_bandwidth: float = 20e9,
) -> Dict[str, float]:
    """Serve ``n_seqs`` decode bursts whose KV starts in the far tier,
    with the device pool sized to ``total_pages / oversubscription``.
    Returns virtual-clock timings for both policies plus the pager's
    page hit rate (fraction of pages already resident when a burst
    starts — prefetch that landed in time).

    Also models the *densification tax* the engine paid before decode
    computed on the paged layout directly: every sequence activation
    used to join its pages into a contiguous slot buffer and insert it
    into the batched cache (one full-sequence copy at
    ``densify_bandwidth``), and parking extracted it back out.  The
    ``paged_densify_*`` keys are the paged policy *with* that copy-in/
    copy-out; ``speedup`` (paged, no densification — what the engine
    does now) vs ``speedup_densify`` quantifies what eliminating the
    round-trip buys at the serving level."""
    total_pages = n_seqs * pages_per_seq
    pool_pages = max(pages_per_seq, int(round(total_pages / oversubscription)))
    seq_bytes = pages_per_seq * page_bytes
    total_tokens = n_seqs * new_tokens

    # -- policy 1: blocking whole-sequence fetch ---------------------------
    be = SimBackend(base_latency=base_latency, bandwidth=bandwidth)
    amu = AMU(backend=be, max_outstanding=4)
    cfg = AccessConfig(granularity_bytes=seq_bytes, qos=QoS.STANDARD)
    t0 = be.now
    for _ in range(n_seqs):
        amu.wait(amu.aload(nbytes=seq_bytes, config=cfg))
        be.advance(new_tokens * tick_s)
    blocking_time = be.now - t0

    # -- policy 2: AMU prefetching pager -----------------------------------
    pool = PagePool(pool_pages, page_size=1)
    table = PageTable(pool)
    pamu = AMU(backend=SimBackend(base_latency=base_latency,
                                  bandwidth=bandwidth),
               max_outstanding=latency_window + 4)
    pager = Pager(pool, table, pamu, page_nbytes=page_bytes,
                  latency_window=latency_window, bulk_window=4)
    loop = EventLoop()
    loop.on(EventKind.PAGE_ARRIVED,
            lambda ev: pool.touch(table.entry(*ev.payload).phys))
    for s in range(n_seqs):
        table.register_parked(s, pages_per_seq)
        for l in range(pages_per_seq):
            pager.store_far(s, l, None)

    hits = 0
    t0 = pamu.backend.now
    for s in range(n_seqs):
        hits += len(table.logical_pages(s, PageState.RESIDENT))
        pager.wait_seq(s)                       # demand-fetch the misses
        pinned = []
        for l in range(pages_per_seq):
            phys = table.entry(s, l).phys
            pool.pin(phys)
            pool.touch(phys)
            pinned.append(phys)
        nxt = s + 1
        for _ in range(new_tokens):             # decode burst
            if nxt < n_seqs:
                short = len(table.logical_pages(nxt, PageState.PARKED))
                if short and pool.n_free < short:
                    pager.evict_lru(short - pool.n_free)
                pager.prefetch_seq(nxt, tail_first=True)
            for arrived in pager.advance(tick_s):
                loop.post(EventKind.PAGE_ARRIVED, arrived)
            loop.tick()
        for phys in pinned:
            pool.unpin(phys)
        pool.mark_dirty(pinned[-1])             # decode wrote the tail page
    paged_time = pamu.backend.now - t0

    # densification tax of the pre-paged-decode engine: one whole-sequence
    # join on every activation and one extract on every deactivation
    # (2 x seq_bytes of device copies per sequence served).
    densify_time = n_seqs * 2 * seq_bytes / densify_bandwidth
    paged_densify_time = paged_time + densify_time

    return {
        "oversubscription": oversubscription,
        "pool_pages": pool_pages,
        "blocking_time": blocking_time,
        "paged_time": paged_time,
        "speedup": blocking_time / paged_time,
        "hit_rate": hits / total_pages,
        "blocking_us_per_token": blocking_time / total_tokens * 1e6,
        "paged_us_per_token": paged_time / total_tokens * 1e6,
        "paged_densify_us_per_token": paged_densify_time / total_tokens * 1e6,
        "speedup_densify": blocking_time / paged_densify_time,
        "densify_eliminated_frac": densify_time / paged_densify_time,
        "bulk_writebacks": pager.stats["writeback"],
        "clean_evictions": pager.stats["clean_evict"],
        "demand_fetches": pager.stats["demand_fetch"],
    }

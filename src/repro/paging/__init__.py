"""repro.paging — page-granularity far-memory KV subsystem.

Turns the repo's serving layer from whole-sequence KV offload into a
capacity-oversubscribed paging system, built from three pieces that map
one-to-one onto the source paper's architecture:

  * :mod:`repro.paging.page_table` — the pool of device page frames
    (near tier / SPM) and per-sequence logical→physical maps with
    residency bits (APR-style per-page state),
  * :mod:`repro.paging.pager` — the AMU traffic engine: LATENCY-QoS
    ``aload`` prefetch, BULK-QoS ``astore`` writeback, LRU-with-pinning
    eviction, and per-QoS outstanding windows (MACR QoS at issue),
  * :mod:`repro.paging.events` — the §2.3.2 event-driven model as a
    scheduler: decode ticks, ``getfin`` page arrivals, and free-page-
    watermark admission/preemption decisions,
  * :mod:`repro.paging.sim` — deterministic policy simulations feeding
    the ``paged_kv_sweep`` (pager vs blocking fetch) and
    ``mixed_batch_sweep`` (chunked continuous batching vs serial dense
    prefill) benchmarks.

The serving engine (:mod:`repro.serve.engine`) consumes all of it: both
decode *and* chunked prefill compute directly on the pool layout, so
the page is the unit of transfer, residency, eviction and compute —
see ``docs/ARCHITECTURE.md`` for the paper-to-code map.
"""

from repro.paging.events import Event, EventKind, EventLoop, WatermarkPolicy
from repro.paging.page_table import (NOT_MAPPED, Frame, PagePool, PageState,
                                     PageTable, PagingError, pages_for)
from repro.paging.pager import Pager, QoSWindows

__all__ = [
    "Event", "EventKind", "EventLoop", "WatermarkPolicy",
    "NOT_MAPPED", "Frame", "PagePool", "PageState", "PageTable",
    "PagingError", "pages_for", "Pager", "QoSWindows",
]

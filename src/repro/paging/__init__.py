"""repro.paging — page-granularity far-memory KV subsystem.

Turns the repo's serving layer from whole-sequence KV offload into a
capacity-oversubscribed paging system, built from three pieces that map
one-to-one onto the source paper's architecture:

  * :mod:`repro.paging.page_table` — the pool of device page frames
    (near tier / SPM) and per-sequence logical→physical maps with
    residency bits (APR-style per-page state),
  * :mod:`repro.paging.pager` — the AMU traffic engine: LATENCY-QoS
    ``aload`` prefetch, BULK-QoS ``astore`` writeback, LRU-with-pinning
    eviction, and per-QoS outstanding windows (MACR QoS at issue),
  * :mod:`repro.paging.events` — the §2.3.2 event-driven model as a
    scheduler: decode ticks, ``getfin`` page arrivals, and free-page-
    watermark admission/preemption decisions,
  * :mod:`repro.paging.prefix_cache` — content-addressed cross-request
    prefix sharing: full prompt pages interned by rolling token-id
    hash, mapped into new requests' page tables as refcounted/COW
    shared frames (device hit) or LATENCY far-tier fetches (far hit),
  * :mod:`repro.paging.sim` — deterministic policy simulations feeding
    the ``paged_kv_sweep`` (pager vs blocking fetch),
    ``mixed_batch_sweep`` (chunked continuous batching vs serial dense
    prefill) and ``prefix_reuse_sweep`` (prefix sharing vs recompute)
    benchmarks.

The serving engine (:mod:`repro.serve.engine`) consumes all of it: both
decode *and* chunked prefill compute directly on the pool layout, so
the page is the unit of transfer, residency, eviction and compute —
see ``docs/ARCHITECTURE.md`` for the paper-to-code map.
"""

from repro.paging.events import (DeadlineQueue, Event, EventKind, EventLoop,
                                 WatermarkPolicy)
from repro.paging.page_table import (NOT_MAPPED, Frame, PagePool, PageState,
                                     PageTable, PagingError, pages_for)
from repro.paging.pager import Pager, QoSWindows
from repro.paging.prefix_cache import PREFIX_SEQ, PrefixCache, page_hashes

__all__ = [
    "DeadlineQueue", "Event", "EventKind", "EventLoop", "WatermarkPolicy",
    "NOT_MAPPED", "Frame", "PagePool", "PageState", "PageTable",
    "PagingError", "pages_for", "Pager", "QoSWindows",
    "PREFIX_SEQ", "PrefixCache", "page_hashes",
]

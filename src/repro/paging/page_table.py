"""Page tables over a fixed device page pool.

The paper's far-memory model applied at *page granularity*: instead of
moving one sequence's entire KV as a single AMU request (the coarse
blocking-transfer pattern §1 argues against), KV state is carved into
fixed-size pages of token positions.  A page is the unit of transfer,
residency and eviction — the central systems knob the memory-
disaggregation literature identifies.

Two objects:

  * :class:`PagePool` — the physical device pages (the near tier /
    SPM in paper terms).  A fixed number of frames, a free heap, and
    per-frame metadata: users, residency, dirty, pin/ref counts, COW
    bit, last-use tick.  Frames are reused without zeroing (a page's
    content is always fully overwritten by its next owner before being
    read).
  * :class:`PageTable` — per-sequence logical→physical maps.  Each
    entry is one page's *Access Pattern Register* worth of state: where
    the page lives (device frame / far tier / in flight) plus the
    residency bit the pager flips as ``getfin`` completions land.

Cross-request prefix sharing (``repro.paging.prefix_cache``) makes the
mapping many-to-one: a frame holding a content-addressed shared prompt
page is referenced by several sequences' PTEs at once.  The frame table
therefore carries a *reference count* (mappings), a *pin count* (active
slots among them) and a *copy-on-write bit* (set when a frame is
interned into the prefix cache; a sharer that would write it must break
the share first via :meth:`PageTable.remap_private`).  Releasing a
mapping only returns the frame to the free heap when the last reference
drops.

Mapping onto the paper's vocabulary: a page table entry's physical
frame id is what an APR base address would hold; the per-page
:class:`PageState` is the completion state machine that ``aload`` /
``astore`` / ``getfin`` drive; and the pool's free-frame watermarks are
what the event-driven scheduler (``repro.paging.events``) consults in
place of the paper's free-SPM-slot counts.
"""

from __future__ import annotations

import enum
import heapq
from dataclasses import dataclass, field
from typing import Any, Dict, Hashable, List, Optional, Set, Tuple

from repro.core.amu import AMUError
from repro.obs import NULL_TRACER

__all__ = ["PagingError", "PageState", "Frame", "PagePool", "PageTable",
           "NOT_MAPPED", "pages_for"]

#: Physical frame id meaning "no device frame backs this entry".
NOT_MAPPED: int = -1


class PagingError(AMUError):
    """Invalid paging-layer usage (double free, bad map, pool misuse)."""


class PageState(enum.Enum):
    UNMAPPED = "unmapped"    # never allocated (beyond the sequence's length)
    RESIDENT = "resident"    # device frame holds the page
    PARKED = "parked"        # far tier holds the page; no device frame
    ARRIVING = "arriving"    # aload in flight; device frame reserved


@dataclass
class Frame:
    """Per-physical-page metadata (the pool's frame table row).

    ``refs`` counts page-table mappings (plus the prefix cache's own
    mapping when the frame is interned); ``pins`` counts the mappings
    whose sequence is actively decoding/prefilling.  ``cow`` marks
    content-addressed shared frames: immutable while shared — a writer
    must break the share first.  ``users`` is the reverse map of the
    mappings (maintained by :class:`PageTable`), what lets the LRU
    evictor find the one mapping of a sole-owned frame.
    """

    phys: int
    refs: int = 0
    pins: int = 0
    cow: bool = False
    dirty: bool = False
    last_use: int = 0
    tokens: int = -1         # valid token positions in the frame, when known
    data: Any = None         # frame contents when not materialised elsewhere
    users: Set[Tuple[Hashable, int]] = field(default_factory=set)

    @property
    def pinned(self) -> bool:
        return self.pins > 0

    @property
    def owner(self) -> Optional[Hashable]:
        """Any one mapping's sequence (None when unmapped)."""
        return next(iter(self.users))[0] if self.users else None

    @property
    def logical(self) -> int:
        return next(iter(self.users))[1] if self.users else -1


def pages_for(n_tokens: int, page_size: int) -> int:
    """Number of pages covering ``n_tokens`` positions.

    >>> pages_for(17, 16)
    2
    >>> pages_for(0, 16)
    0
    """
    return -(-max(0, n_tokens) // page_size)


class PagePool:
    """Fixed pool of device page frames with a free heap.

    The near tier of the paper's two-tier model — what SPM is to the
    AMU core (§2.1), the device HBM page frames are to the serving
    engine.  The free list is a min-heap so allocation is O(log n) and
    frame ids are reused lowest-first (deterministic layouts for
    tests).  Example::

        pool = PagePool(n_pages=8, page_size=16)
        phys = pool.alloc(owner=rid, logical=0)
        pool.pin(phys)            # active slots pin their pages
        pool.unpin(phys); pool.free(phys)

    Frames are reference counted so the prefix cache can map one frame
    from several sequences: ``share`` adds a mapping, ``release`` drops
    one, and the frame returns to the free heap only when the last
    reference goes.  ``pin``/``unpin`` are counts for the same reason.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 1:
            raise PagingError("PagePool needs at least one page")
        if page_size < 1:
            raise PagingError("page_size must be >= 1 tokens")
        self.n_pages = n_pages
        self.page_size = page_size
        self.frames: List[Frame] = [Frame(phys=i) for i in range(n_pages)]
        self._free: List[int] = list(range(n_pages))
        heapq.heapify(self._free)
        self._allocated = [False] * n_pages
        self._use_seq = 0            # monotonic recency stamp for LRU

    # -- alloc/free ---------------------------------------------------------
    def alloc(self, owner: Hashable, logical: int) -> int:
        """Take a free frame for (owner, logical); raises when exhausted."""
        if not self._free:
            raise PagingError("page pool exhausted")
        phys = heapq.heappop(self._free)
        self._allocated[phys] = True
        f = self.frames[phys]
        f.refs, f.pins = 1, 0
        f.cow = f.dirty = False
        f.tokens = -1
        f.data = None
        f.users = {(owner, logical)}
        return phys

    def share(self, phys: int, owner: Hashable, logical: int) -> None:
        """Add a mapping to a live frame (prefix sharing)."""
        self._check_live(phys)
        f = self.frames[phys]
        f.refs += 1
        f.users.add((owner, logical))

    def release(self, phys: int, owner: Hashable, logical: int) -> None:
        """Drop one mapping; the frame frees when the last ref goes."""
        self._check_live(phys)
        f = self.frames[phys]
        if f.refs < 1:
            raise PagingError(f"release underflow on frame {phys}")
        if f.refs == 1 and f.pins:
            raise PagingError(f"cannot free pinned frame {phys}")
        f.refs -= 1
        f.users.discard((owner, logical))
        if f.refs == 0:
            f.cow = f.dirty = False
            f.data = None
            f.users = set()
            self._allocated[phys] = False
            heapq.heappush(self._free, phys)

    def free(self, phys: int) -> None:
        """Free a sole-owned frame (compat path; shared frames must go
        through :meth:`release` one mapping at a time)."""
        self._check(phys)
        if not self._allocated[phys]:
            raise PagingError(f"double free of frame {phys}")
        f = self.frames[phys]
        if f.refs > 1:
            raise PagingError(
                f"free of shared frame {phys} (refs={f.refs}); "
                "release each mapping instead")
        user = next(iter(f.users)) if f.users else (None, -1)
        self.release(phys, *user)

    # -- metadata -----------------------------------------------------------
    def pin(self, phys: int) -> None:
        self._check_live(phys)
        self.frames[phys].pins += 1

    def unpin(self, phys: int) -> None:
        self._check_live(phys)
        f = self.frames[phys]
        if f.pins < 1:
            raise PagingError(f"unpin underflow on frame {phys}")
        f.pins -= 1

    def touch(self, phys: int) -> None:
        """Stamp a frame as most-recently-used (internal monotonic
        counter, so pager completions and scheduler ticks share one
        recency order)."""
        self._check_live(phys)
        self._use_seq += 1
        self.frames[phys].last_use = self._use_seq

    def mark_dirty(self, phys: int, dirty: bool = True) -> None:
        self._check_live(phys)
        self.frames[phys].dirty = dirty

    def mark_cow(self, phys: int, cow: bool = True) -> None:
        """Flag a frame copy-on-write (set when the prefix cache interns
        it): sharers must not write it; see PageTable.remap_private."""
        self._check_live(phys)
        self.frames[phys].cow = cow

    def lru_victims(self, n: int) -> List[int]:
        """Up to ``n`` unpinned allocated frames, least-recently-used first."""
        live = [f for f in self.frames
                if self._allocated[f.phys] and not f.pinned]
        live.sort(key=lambda f: (f.last_use, f.phys))
        return [f.phys for f in live[:n]]

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_pages - len(self._free)

    def is_live(self, phys: int) -> bool:
        return 0 <= phys < self.n_pages and self._allocated[phys]

    def _check(self, phys: int) -> None:
        if not 0 <= phys < self.n_pages:
            raise PagingError(f"bad frame id {phys}")

    def _check_live(self, phys: int) -> None:
        self._check(phys)
        if not self._allocated[phys]:
            raise PagingError(f"frame {phys} is not allocated")


@dataclass
class PTE:
    """One logical page's entry: state + device frame when resident.

    ``pinned`` records whether *this mapping* holds one of the frame's
    pins — what lets ``drop`` unpin exactly the dropped sequence's share
    of a frame that other sequences still pin.
    """

    state: PageState = PageState.UNMAPPED
    phys: int = NOT_MAPPED
    pinned: bool = False


class PageTable:
    """Per-sequence logical→physical page maps over one :class:`PagePool`.

    Each entry is one page's Access-Pattern-Register's worth of state
    (§2.2): the frame id an APR base address would hold plus the
    :class:`PageState` residency bit that ``aload``/``astore``/
    ``getfin`` completions drive.  Example::

        table = PageTable(pool)
        table.register(rid)
        table.ensure_capacity(rid, n_tokens=33)   # -> [0, 1, 2] new pages
        table.entry(rid, 0).state                 # PageState.RESIDENT
        table.drop(rid)                           # frees every frame

    Prefix sharing appends *aliased* entries: ``append_shared`` maps a
    new sequence's next logical page onto an existing frame (refcount
    up, no allocation), ``append_parked`` starts it in the far tier.
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self._maps: Dict[Hashable, List[PTE]] = {}
        # bound by Pager.bind_obs: residency transitions emit one
        # instant each on the ("pager", "residency") track when tracing
        self.tracer = NULL_TRACER

    # -- sequence lifecycle --------------------------------------------------
    def register(self, seq: Hashable) -> None:
        if seq in self._maps:
            raise PagingError(f"sequence {seq!r} already registered")
        self._maps[seq] = []

    def register_parked(self, seq: Hashable, n_pages: int) -> None:
        """Register a sequence whose pages all start in the far tier
        (restore / cold-start path: no device frames are taken)."""
        self.register(seq)
        self._maps[seq] = [PTE(state=PageState.PARKED)
                           for _ in range(n_pages)]

    def drop(self, seq: Hashable) -> None:
        """Unregister a sequence, releasing every device frame mapping it
        holds — even pinned ones (drop is terminal for the sequence).  A
        shared frame survives for its other users, keeping their pins."""
        for logical, pte in enumerate(self._entries(seq)):
            if pte.phys != NOT_MAPPED:
                frame = self.pool.frames[pte.phys]
                if frame.refs == 1:
                    frame.pins = 0           # force: sole owner is leaving
                    pte.pinned = False
                self._unmap(seq, logical, pte)
        del self._maps[seq]

    def sequences(self) -> List[Hashable]:
        return list(self._maps)

    # -- growth --------------------------------------------------------------
    def ensure_capacity(self, seq: Hashable, n_tokens: int) -> List[int]:
        """Extend the map so ``n_tokens`` positions are covered by RESIDENT
        pages, allocating frames for any new logical pages.  Returns the
        list of newly-allocated logical page indices."""
        entries = self._entries(seq)
        need = pages_for(n_tokens, self.pool.page_size)
        new: List[int] = []
        while len(entries) < need:
            logical = len(entries)
            phys = self.pool.alloc(seq, logical)
            entries.append(PTE(state=PageState.RESIDENT, phys=phys))
            new.append(logical)
        return new

    def append_shared(self, seq: Hashable, phys: int) -> int:
        """Map ``seq``'s next logical page onto an existing frame
        (prefix hit on a device-resident shared page).  Returns the
        logical index.  The frame's refcount goes up; no allocation."""
        entries = self._entries(seq)
        logical = len(entries)
        self.pool.share(phys, seq, logical)
        entries.append(PTE(state=PageState.RESIDENT, phys=phys))
        return logical

    def append_parked(self, seq: Hashable) -> int:
        """Map ``seq``'s next logical page as far-tier resident (prefix
        hit on a parked shared page: the caller installs the far alias
        and the pager fetches a private copy).  Returns the logical."""
        entries = self._entries(seq)
        entries.append(PTE(state=PageState.PARKED))
        return len(entries) - 1

    def truncate(self, seq: Hashable, n_pages: int) -> None:
        """Drop trailing entries beyond ``n_pages``, releasing any frames
        they hold (growth pages that never received content)."""
        entries = self._entries(seq)
        while len(entries) > n_pages:
            logical = len(entries) - 1
            pte = entries.pop()
            if pte.phys != NOT_MAPPED:
                self._unmap(seq, logical, pte)

    def rewind_tokens(self, seq: Hashable, n_tokens: int) -> int:
        """Rewind ``seq``'s mapping to its first ``n_tokens`` valid
        positions, releasing every wholly-garbage trailing page — the
        page-table half of speculative rollback (the rejected draft
        tail past ``n_tokens`` becomes dead KV; pages that hold no live
        token at all go back to the pool, the partial tail page stays
        and is simply overwritten as the sequence appends).  Returns
        the number of pages released.

        Idempotent, and a no-op when the mapping already fits (the
        all-drafts-accepted case).  The freshness bookkeeping needs no
        touch-up here: a later park derives its per-page valid-token
        tag from the engine's rewound ``pos``, so a rolled-back park
        stays clean for free."""
        keep = pages_for(n_tokens, self.pool.page_size)
        dropped = len(self._entries(seq)) - keep
        if dropped > 0:
            self.truncate(seq, keep)
            return dropped
        return 0

    def pages_needed(self, seq_or_tokens, n_tokens: Optional[int] = None) -> int:
        """Additional frames required to cover ``n_tokens`` positions.
        Call as ``pages_needed(n_tokens)`` for an unregistered sequence."""
        if n_tokens is None:
            return pages_for(seq_or_tokens, self.pool.page_size)
        have = len(self._entries(seq_or_tokens))
        return max(0, pages_for(n_tokens, self.pool.page_size) - have)

    # -- entry access --------------------------------------------------------
    def entry(self, seq: Hashable, logical: int) -> PTE:
        entries = self._entries(seq)
        if not 0 <= logical < len(entries):
            raise PagingError(f"sequence {seq!r} has no logical page {logical}")
        return entries[logical]

    def n_pages(self, seq: Hashable) -> int:
        return len(self._entries(seq))

    def logical_pages(self, seq: Hashable, state: Optional[PageState] = None
                      ) -> List[int]:
        return [i for i, p in enumerate(self._entries(seq))
                if state is None or p.state is state]

    def resident(self, seq: Hashable) -> bool:
        """True iff every mapped page of ``seq`` is device-resident."""
        entries = self._entries(seq)
        return all(p.state is PageState.RESIDENT for p in entries)

    def shared(self, seq: Hashable, logical: int) -> bool:
        """True iff the page's frame is mapped by more than one user."""
        pte = self.entry(seq, logical)
        return (pte.phys != NOT_MAPPED
                and self.pool.frames[pte.phys].refs > 1)

    # -- pinning (mapping-level, so shared frames count correctly) -----------
    def pin_page(self, seq: Hashable, logical: int) -> None:
        pte = self.entry(seq, logical)
        if pte.phys == NOT_MAPPED:
            raise PagingError(f"pin of unmapped page ({seq!r}, {logical})")
        if not pte.pinned:
            self.pool.pin(pte.phys)
            pte.pinned = True

    def unpin_page(self, seq: Hashable, logical: int) -> None:
        pte = self.entry(seq, logical)
        if pte.pinned and pte.phys != NOT_MAPPED:
            self.pool.unpin(pte.phys)
        pte.pinned = False

    # -- state transitions (driven by the pager) -----------------------------
    def mark_parked(self, seq: Hashable, logical: int) -> int:
        """RESIDENT → PARKED; releases this mapping and returns the frame
        id (which frees only if no other sequence still maps it)."""
        pte = self.entry(seq, logical)
        if pte.state is not PageState.RESIDENT:
            raise PagingError(
                f"park of non-resident page ({seq!r}, {logical}): {pte.state}")
        phys = pte.phys
        self._unmap(seq, logical, pte)
        pte.phys = NOT_MAPPED
        pte.state = PageState.PARKED
        if self.tracer.enabled:
            self.tracer.instant("pager", "residency", "PARKED",
                                {"seq": seq, "logical": logical})
        return phys

    def mark_arriving(self, seq: Hashable, logical: int) -> int:
        """PARKED → ARRIVING; allocates and returns the reserved frame."""
        pte = self.entry(seq, logical)
        if pte.state is not PageState.PARKED:
            raise PagingError(
                f"fetch of non-parked page ({seq!r}, {logical}): {pte.state}")
        pte.phys = self.pool.alloc(seq, logical)
        pte.state = PageState.ARRIVING
        if self.tracer.enabled:
            self.tracer.instant("pager", "residency", "ARRIVING",
                                {"seq": seq, "logical": logical})
        return pte.phys

    def mark_resident(self, seq: Hashable, logical: int) -> None:
        """ARRIVING → RESIDENT (the page's residency bit; getfin landed)."""
        pte = self.entry(seq, logical)
        if pte.state is not PageState.ARRIVING:
            raise PagingError(
                f"arrival for page ({seq!r}, {logical}) in state {pte.state}")
        pte.state = PageState.RESIDENT
        if self.tracer.enabled:
            self.tracer.instant("pager", "residency", "RESIDENT",
                                {"seq": seq, "logical": logical})

    def remap_private(self, seq: Hashable, logical: int) -> Tuple[int, int]:
        """Break a COW share: allocate a private frame for this mapping
        and return ``(old_phys, new_phys)`` so the caller can copy the
        page's device content across.  The old frame keeps its other
        users.  No-op (returns ``(phys, phys)``) when already private."""
        pte = self.entry(seq, logical)
        if pte.state is not PageState.RESIDENT or pte.phys == NOT_MAPPED:
            raise PagingError(
                f"remap of non-resident page ({seq!r}, {logical})")
        old = pte.phys
        if self.pool.frames[old].refs <= 1:
            return old, old
        pinned = pte.pinned
        new = self.pool.alloc(seq, logical)
        if pinned:
            self.pool.unpin(old)
            self.pool.pin(new)
        self.pool.release(old, seq, logical)
        pte.phys = new
        return old, new

    # -- internals -----------------------------------------------------------
    def _unmap(self, seq: Hashable, logical: int, pte: PTE) -> None:
        """Release one mapping's pin (if held) and reference."""
        if pte.pinned:
            self.pool.unpin(pte.phys)
            pte.pinned = False
        self.pool.release(pte.phys, seq, logical)

    def _entries(self, seq: Hashable) -> List[PTE]:
        if seq not in self._maps:
            raise PagingError(f"unknown sequence {seq!r}")
        return self._maps[seq]

"""Page tables over a fixed device page pool.

The paper's far-memory model applied at *page granularity*: instead of
moving one sequence's entire KV as a single AMU request (the coarse
blocking-transfer pattern §1 argues against), KV state is carved into
fixed-size pages of token positions.  A page is the unit of transfer,
residency and eviction — the central systems knob the memory-
disaggregation literature identifies.

Two objects:

  * :class:`PagePool` — the physical device pages (the near tier /
    SPM in paper terms).  A fixed number of frames, a free heap, and
    per-frame metadata: owner, residency, dirty, pin, last-use tick.
    Frames are reused without zeroing (CoW-free reuse: a page's content
    is always fully overwritten by its next owner before being read).
  * :class:`PageTable` — per-sequence logical→physical maps.  Each
    entry is one page's *Access Pattern Register* worth of state: where
    the page lives (device frame / far tier / in flight) plus the
    residency bit the pager flips as ``getfin`` completions land.

Mapping onto the paper's vocabulary: a page table entry's physical
frame id is what an APR base address would hold; the per-page
:class:`PageState` is the completion state machine that ``aload`` /
``astore`` / ``getfin`` drive; and the pool's free-frame watermarks are
what the event-driven scheduler (``repro.paging.events``) consults in
place of the paper's free-SPM-slot counts.
"""

from __future__ import annotations

import enum
import heapq
from dataclasses import dataclass, field
from typing import Any, Dict, Hashable, List, Optional

from repro.core.amu import AMUError

__all__ = ["PagingError", "PageState", "Frame", "PagePool", "PageTable",
           "NOT_MAPPED", "pages_for"]

#: Physical frame id meaning "no device frame backs this entry".
NOT_MAPPED: int = -1


class PagingError(AMUError):
    """Invalid paging-layer usage (double free, bad map, pool misuse)."""


class PageState(enum.Enum):
    UNMAPPED = "unmapped"    # never allocated (beyond the sequence's length)
    RESIDENT = "resident"    # device frame holds the page
    PARKED = "parked"        # far tier holds the page; no device frame
    ARRIVING = "arriving"    # aload in flight; device frame reserved


@dataclass
class Frame:
    """Per-physical-page metadata (the pool's frame table row)."""

    phys: int
    owner: Optional[Hashable] = None
    logical: int = -1
    pinned: bool = False
    dirty: bool = False
    last_use: int = 0
    data: Any = None         # frame contents when not materialised elsewhere


def pages_for(n_tokens: int, page_size: int) -> int:
    """Number of pages covering ``n_tokens`` positions.

    >>> pages_for(17, 16)
    2
    >>> pages_for(0, 16)
    0
    """
    return -(-max(0, n_tokens) // page_size)


class PagePool:
    """Fixed pool of device page frames with a free heap.

    The near tier of the paper's two-tier model — what SPM is to the
    AMU core (§2.1), the device HBM page frames are to the serving
    engine.  The free list is a min-heap so allocation is O(log n) and
    frame ids are reused lowest-first (deterministic layouts for
    tests).  Example::

        pool = PagePool(n_pages=8, page_size=16)
        phys = pool.alloc(owner=rid, logical=0)
        pool.pin(phys)            # active slots pin their pages
        pool.unpin(phys); pool.free(phys)
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 1:
            raise PagingError("PagePool needs at least one page")
        if page_size < 1:
            raise PagingError("page_size must be >= 1 tokens")
        self.n_pages = n_pages
        self.page_size = page_size
        self.frames: List[Frame] = [Frame(phys=i) for i in range(n_pages)]
        self._free: List[int] = list(range(n_pages))
        heapq.heapify(self._free)
        self._allocated = [False] * n_pages
        self._use_seq = 0            # monotonic recency stamp for LRU

    # -- alloc/free ---------------------------------------------------------
    def alloc(self, owner: Hashable, logical: int) -> int:
        """Take a free frame for (owner, logical); raises when exhausted."""
        if not self._free:
            raise PagingError("page pool exhausted")
        phys = heapq.heappop(self._free)
        self._allocated[phys] = True
        f = self.frames[phys]
        f.owner, f.logical = owner, logical
        f.pinned = f.dirty = False
        f.data = None
        return phys

    def free(self, phys: int) -> None:
        self._check(phys)
        if not self._allocated[phys]:
            raise PagingError(f"double free of frame {phys}")
        f = self.frames[phys]
        if f.pinned:
            raise PagingError(f"cannot free pinned frame {phys}")
        f.owner, f.logical, f.dirty, f.data = None, -1, False, None
        self._allocated[phys] = False
        heapq.heappush(self._free, phys)

    # -- metadata -----------------------------------------------------------
    def pin(self, phys: int) -> None:
        self._check_live(phys)
        self.frames[phys].pinned = True

    def unpin(self, phys: int) -> None:
        self._check_live(phys)
        self.frames[phys].pinned = False

    def touch(self, phys: int) -> None:
        """Stamp a frame as most-recently-used (internal monotonic
        counter, so pager completions and scheduler ticks share one
        recency order)."""
        self._check_live(phys)
        self._use_seq += 1
        self.frames[phys].last_use = self._use_seq

    def mark_dirty(self, phys: int, dirty: bool = True) -> None:
        self._check_live(phys)
        self.frames[phys].dirty = dirty

    def lru_victims(self, n: int) -> List[int]:
        """Up to ``n`` unpinned allocated frames, least-recently-used first."""
        live = [f for f in self.frames
                if self._allocated[f.phys] and not f.pinned]
        live.sort(key=lambda f: (f.last_use, f.phys))
        return [f.phys for f in live[:n]]

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_pages - len(self._free)

    def _check(self, phys: int) -> None:
        if not 0 <= phys < self.n_pages:
            raise PagingError(f"bad frame id {phys}")

    def _check_live(self, phys: int) -> None:
        self._check(phys)
        if not self._allocated[phys]:
            raise PagingError(f"frame {phys} is not allocated")


@dataclass
class PTE:
    """One logical page's entry: state + device frame when resident."""

    state: PageState = PageState.UNMAPPED
    phys: int = NOT_MAPPED


class PageTable:
    """Per-sequence logical→physical page maps over one :class:`PagePool`.

    Each entry is one page's Access-Pattern-Register's worth of state
    (§2.2): the frame id an APR base address would hold plus the
    :class:`PageState` residency bit that ``aload``/``astore``/
    ``getfin`` completions drive.  Example::

        table = PageTable(pool)
        table.register(rid)
        table.ensure_capacity(rid, n_tokens=33)   # -> [0, 1, 2] new pages
        table.entry(rid, 0).state                 # PageState.RESIDENT
        table.drop(rid)                           # frees every frame
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self._maps: Dict[Hashable, List[PTE]] = {}

    # -- sequence lifecycle --------------------------------------------------
    def register(self, seq: Hashable) -> None:
        if seq in self._maps:
            raise PagingError(f"sequence {seq!r} already registered")
        self._maps[seq] = []

    def register_parked(self, seq: Hashable, n_pages: int) -> None:
        """Register a sequence whose pages all start in the far tier
        (restore / cold-start path: no device frames are taken)."""
        self.register(seq)
        self._maps[seq] = [PTE(state=PageState.PARKED)
                           for _ in range(n_pages)]

    def drop(self, seq: Hashable) -> None:
        """Unregister a sequence, freeing every device frame it maps."""
        for pte in self._entries(seq):
            if pte.phys != NOT_MAPPED:
                self.pool.frames[pte.phys].pinned = False
                self.pool.free(pte.phys)
        del self._maps[seq]

    def sequences(self) -> List[Hashable]:
        return list(self._maps)

    # -- growth --------------------------------------------------------------
    def ensure_capacity(self, seq: Hashable, n_tokens: int) -> List[int]:
        """Extend the map so ``n_tokens`` positions are covered by RESIDENT
        pages, allocating frames for any new logical pages.  Returns the
        list of newly-allocated logical page indices."""
        entries = self._entries(seq)
        need = pages_for(n_tokens, self.pool.page_size)
        new: List[int] = []
        while len(entries) < need:
            logical = len(entries)
            phys = self.pool.alloc(seq, logical)
            entries.append(PTE(state=PageState.RESIDENT, phys=phys))
            new.append(logical)
        return new

    def truncate(self, seq: Hashable, n_pages: int) -> None:
        """Drop trailing entries beyond ``n_pages``, freeing any frames
        they hold (growth pages that never received content)."""
        entries = self._entries(seq)
        while len(entries) > n_pages:
            pte = entries.pop()
            if pte.phys != NOT_MAPPED:
                self.pool.frames[pte.phys].pinned = False
                self.pool.free(pte.phys)

    def pages_needed(self, seq_or_tokens, n_tokens: Optional[int] = None) -> int:
        """Additional frames required to cover ``n_tokens`` positions.
        Call as ``pages_needed(n_tokens)`` for an unregistered sequence."""
        if n_tokens is None:
            return pages_for(seq_or_tokens, self.pool.page_size)
        have = len(self._entries(seq_or_tokens))
        return max(0, pages_for(n_tokens, self.pool.page_size) - have)

    # -- entry access --------------------------------------------------------
    def entry(self, seq: Hashable, logical: int) -> PTE:
        entries = self._entries(seq)
        if not 0 <= logical < len(entries):
            raise PagingError(f"sequence {seq!r} has no logical page {logical}")
        return entries[logical]

    def n_pages(self, seq: Hashable) -> int:
        return len(self._entries(seq))

    def logical_pages(self, seq: Hashable, state: Optional[PageState] = None
                      ) -> List[int]:
        return [i for i, p in enumerate(self._entries(seq))
                if state is None or p.state is state]

    def resident(self, seq: Hashable) -> bool:
        """True iff every mapped page of ``seq`` is device-resident."""
        entries = self._entries(seq)
        return all(p.state is PageState.RESIDENT for p in entries)

    # -- state transitions (driven by the pager) -----------------------------
    def mark_parked(self, seq: Hashable, logical: int) -> int:
        """RESIDENT → PARKED; frees and returns the frame id."""
        pte = self.entry(seq, logical)
        if pte.state is not PageState.RESIDENT:
            raise PagingError(
                f"park of non-resident page ({seq!r}, {logical}): {pte.state}")
        phys, pte.phys = pte.phys, NOT_MAPPED
        pte.state = PageState.PARKED
        self.pool.frames[phys].pinned = False
        self.pool.free(phys)
        return phys

    def mark_arriving(self, seq: Hashable, logical: int) -> int:
        """PARKED → ARRIVING; allocates and returns the reserved frame."""
        pte = self.entry(seq, logical)
        if pte.state is not PageState.PARKED:
            raise PagingError(
                f"fetch of non-parked page ({seq!r}, {logical}): {pte.state}")
        pte.phys = self.pool.alloc(seq, logical)
        pte.state = PageState.ARRIVING
        return pte.phys

    def mark_resident(self, seq: Hashable, logical: int) -> None:
        """ARRIVING → RESIDENT (the page's residency bit; getfin landed)."""
        pte = self.entry(seq, logical)
        if pte.state is not PageState.ARRIVING:
            raise PagingError(
                f"arrival for page ({seq!r}, {logical}) in state {pte.state}")
        pte.state = PageState.RESIDENT

    def _entries(self, seq: Hashable) -> List[PTE]:
        if seq not in self._maps:
            raise PagingError(f"unknown sequence {seq!r}")
        return self._maps[seq]

"""EXPERIMENTS.md table generation from dry-run artifacts.

  PYTHONPATH=src python -m repro.analysis.report \
      --single dryrun_single.jsonl --multi dryrun_multi.jsonl
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional, Tuple


def load(path) -> Dict[Tuple[str, str, str], dict]:
    rows = {}
    p = Path(path)
    if not p.exists():
        return rows
    for line in p.read_text().splitlines():
        if line.strip():
            r = json.loads(line)
            rows[(r["arch"], r["shape"], r["mesh"])] = r
    return rows


def _fmt_t(x: Optional[float]) -> str:
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x * 1e3:.1f}ms"


def _gib(x: Optional[float]) -> str:
    return "-" if x is None else f"{x / 2**30:.2f}"


def dryrun_table(single: dict, multi: dict) -> str:
    out = ["| arch | shape | single-pod (256) | multi-pod (512) | "
           "bytes/dev (arg+tmp) | collective mix (single) |",
           "|---|---|---|---|---|---|"]
    archs = sorted({k[0] for k in single} | {k[0] for k in multi})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    for a in archs:
        for s in shapes:
            rs = single.get((a, s, "single"))
            rm = multi.get((a, s, "multi"))
            if rs is None and rm is None:
                continue
            def stat(r):
                if r is None:
                    return "—"
                if r.get("status") == "skipped":
                    return "skip (O(L²))"
                if r.get("status") != "ok":
                    return "FAIL"
                return "ok"
            bpd = "-"
            mix = "-"
            if rs and rs.get("status") == "ok":
                ma = rs.get("memory_analysis", {})
                bpd = _gib(ma.get("argument_size", 0)
                           + ma.get("temp_size", 0))
                cb = rs.get("collective_breakdown", {})
                tot = sum(cb.values()) or 1
                short = {"all-reduce": "AR", "all-gather": "AG",
                         "reduce-scatter": "RS", "all-to-all": "A2A",
                         "collective-permute": "CP"}
                mix = " ".join(f"{short.get(k, k)}:{100 * v / tot:.0f}%"
                               for k, v in sorted(cb.items(),
                                                  key=lambda kv: -kv[1])[:3])
            out.append(f"| {a} | {s} | {stat(rs)} | {stat(rm)} | {bpd} | "
                       f"{mix} |")
    return "\n".join(out)


def roofline_table(single: dict) -> str:
    out = ["| arch | shape | t_compute | t_memory | t_collective | "
           "bottleneck | useful FLOPs | roofline frac | one-line fix |",
           "|---|---|---|---|---|---|---|---|---|"]
    fixes = {
        ("compute", True): "already compute-bound — overlap the residual "
                           "collectives",
        ("memory", True): "cut f32 activation traffic (bf16 score path, "
                          "fused norms)",
        ("collective", True): "reshard: per-chunk partial-sum all-reduces "
                              "-> one all-gather per layer",
    }
    for (a, s, m), r in sorted(single.items()):
        if m != "single":
            continue
        if r.get("status") == "skipped":
            out.append(f"| {a} | {s} | - | - | - | skipped | - | - | "
                       f"full attention at 500k |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {a} | {s} | - | - | - | FAILED | - | - | |")
            continue
        bn = r["bottleneck"]
        fix = fixes.get((bn, True), "")
        out.append(
            f"| {a} | {s} | {_fmt_t(r['t_compute'])} | "
            f"{_fmt_t(r['t_memory'])} | {_fmt_t(r['t_collective'])} | "
            f"{bn} | {r['useful_flops_frac']:.2f} | "
            f"{r['roofline_frac']:.3f} | {fix} |")
    return "\n".join(out)


def summarize(single: dict, multi: dict) -> str:
    n_ok_s = sum(1 for r in single.values() if r.get("status") == "ok")
    n_sk_s = sum(1 for r in single.values() if r.get("status") == "skipped")
    n_ok_m = sum(1 for r in multi.values() if r.get("status") == "ok")
    n_sk_m = sum(1 for r in multi.values() if r.get("status") == "skipped")
    n_fail = sum(1 for r in list(single.values()) + list(multi.values())
                 if r.get("status") not in ("ok", "skipped"))
    return (f"single-pod: {n_ok_s} ok / {n_sk_s} documented skips; "
            f"multi-pod: {n_ok_m} ok / {n_sk_m} skips; failures: {n_fail}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--single", default="dryrun_single.jsonl")
    ap.add_argument("--multi", default="dryrun_multi.jsonl")
    ap.add_argument("--section", choices=["dryrun", "roofline", "all"],
                    default="all")
    args = ap.parse_args()
    single, multi = load(args.single), load(args.multi)
    print("## summary\n")
    print(summarize(single, multi) + "\n")
    if args.section in ("dryrun", "all"):
        print("## §Dry-run\n")
        print(dryrun_table(single, multi) + "\n")
    if args.section in ("roofline", "all"):
        print("## §Roofline (single-pod, 256 chips)\n")
        print(roofline_table(single) + "\n")


if __name__ == "__main__":
    main()

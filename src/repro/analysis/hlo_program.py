"""Trip-count-aware HLO program analyzer.

WHY THIS EXISTS: ``compiled.cost_analysis()`` counts a ``while`` body
ONCE, but every layer stack here is a ``lax.scan`` -> the FLOPs/bytes/
collectives of an L-layer model would be undercounted by ~L x.  This
module parses the optimized HLO text into computations, recovers each
while loop's trip count from its condition (``compare(iter,
constant(L))``), and rolls up costs recursively:

  cost(entry) = sum over instructions, with
    while     -> trip_count * cost(body)
    call      -> cost(callee)
    fusion    -> FLOPs recurse into the fused computation; BYTES count
                 only the fusion's operands+result (fusion internals do
                 not touch HBM — exactly XLA's own fusion semantics)
    collective -> result bytes (reduce-scatter/all-to-all: max of
                 operand/result), times the enclosing trip counts

FLOPs: ``dot`` exact (2 * prod(result) * prod(contracting dims));
elementwise/reduce approximate (1 flop/element).  Validated against the
6*N*D analytical model in tests (within ~2x, vs ~20x off for the naive
cost_analysis on deep scans).
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Dict, List, Optional, Tuple

__all__ = ["HloProgram", "analyze_hlo", "ProgramCost"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

# result-materialising opcodes for the bytes model
_NONMATERIAL = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "partition-id", "replica-id", "iota", "opt-barrier",
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "cbrt", "negate", "abs", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "select", "compare", "and",
    "or", "xor", "not", "clamp", "remainder", "atan2", "logistic", "cosine",
    "sine", "erf",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclass
class Instruction:
    name: str
    result_type: str
    opcode: str
    line: str


@dataclass
class Computation:
    name: str
    instructions: List[Instruction] = field(default_factory=list)
    # name -> result_type for operand lookup
    symbols: Dict[str, str] = field(default_factory=dict)


@dataclass
class ProgramCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = field(default_factory=dict)
    dot_flops: float = 0.0

    def __iadd__(self, other: "ProgramCost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.collective_bytes += other.collective_bytes
        self.dot_flops += other.dot_flops
        for k, v in other.collective_by_kind.items():
            self.collective_by_kind[k] = self.collective_by_kind.get(k, 0) + v
        return self

    def scaled(self, f: float) -> "ProgramCost":
        return ProgramCost(
            flops=self.flops * f, bytes=self.bytes * f,
            collective_bytes=self.collective_bytes * f,
            collective_by_kind={k: v * f
                                for k, v in self.collective_by_kind.items()},
            dot_flops=self.dot_flops * f)


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-$]+)\s*\(")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_WHILE_ATTRS = re.compile(r"condition=%?([\w.\-]+).*?body=%?([\w.\-]+)|"
                          r"body=%?([\w.\-]+).*?condition=%?([\w.\-]+)")
_TRIP_COUNT = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_FUSION_CALL = re.compile(r"calls=%?([\w.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_OPERAND = re.compile(r"%([\w.\-]+)")


class HloProgram:
    def __init__(self, text: str):
        self.computations: Dict[str, Computation] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._memo: Dict[str, ProgramCost] = {}

    # -- parsing -------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: Optional[Computation] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            if not line.startswith(" ") and line.endswith("{"):
                m = _COMP_HEADER.match(line.strip())
                if m:
                    cur = Computation(name=m.group(1))
                    self.computations[cur.name] = cur
                    if line.lstrip().startswith("ENTRY"):
                        self.entry = cur.name
                    continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _INSTR.match(line)
            if not m:
                continue
            name, rtype, opcode = m.group(1), m.group(2), m.group(3)
            cur.instructions.append(Instruction(name, rtype, opcode, line))
            cur.symbols[name] = rtype

    # -- trip counts ----------------------------------------------------------
    @lru_cache(maxsize=None)
    def trip_count(self, cond_name: str) -> int:
        """Heuristic: the loop bound is the max integer constant in the
        condition computation (jax scan: compare(i, constant(L)))."""
        comp = self.computations.get(cond_name)
        if comp is None:
            return 1
        best = 1
        for ins in comp.instructions:
            for c in _CONST_INT.findall(ins.line):
                best = max(best, int(c))
        return best

    # -- cost rollup -----------------------------------------------------------
    def cost(self, comp_name: Optional[str] = None, *,
             _for_fusion: bool = False) -> ProgramCost:
        comp_name = comp_name or self.entry
        key = f"{comp_name}|{_for_fusion}"
        if key in self._memo:
            return self._memo[key]
        comp = self.computations.get(comp_name)
        total = ProgramCost()
        if comp is None:
            return total
        for ins in comp.instructions:
            total += self._instr_cost(comp, ins, _for_fusion)
        self._memo[key] = total
        return total

    def _operand_bytes(self, comp: Computation, ins: Instruction) -> int:
        # operands named on the line, excluding the instruction itself
        total = 0
        seen_self = False
        for name in _OPERAND.findall(ins.line):
            if not seen_self and name == ins.name:
                seen_self = True
                continue
            t = comp.symbols.get(name)
            if t:
                total += _shape_bytes(t)
        return total

    def _instr_cost(self, comp: Computation, ins: Instruction,
                    in_fusion: bool) -> ProgramCost:
        op = ins.opcode
        c = ProgramCost()

        if op == "while":
            m = _WHILE_ATTRS.search(ins.line)
            if m:
                cond = m.group(1) or m.group(4)
                body = m.group(3) or m.group(2)
                # prefer XLA's own annotation, fall back to the condition
                tm = _TRIP_COUNT.search(ins.line)
                trips = int(tm.group(1)) if tm else self.trip_count(cond)
                c += self.cost(body).scaled(trips)
            return c

        if op in ("call", "async-start"):
            m = _CALLS.search(ins.line)
            if m:
                c += self.cost(m.group(1))
            return c

        if op == "conditional":
            # count each branch once (upper-bounds a single execution of
            # the hot branch; branches are usually symmetric here)
            for callee in re.findall(r"branch_computations={([^}]*)}",
                                     ins.line):
                for b in re.findall(r"%?([\w.\-]+)", callee):
                    c += self.cost(b)
            return c

        kind = next((k for k in _COLLECTIVES if op.startswith(k)), None)
        if kind is not None:
            if op.endswith("-done"):
                return c
            rbytes = _shape_bytes(ins.result_type)
            obytes = self._operand_bytes(comp, ins)
            vol = max(rbytes, obytes)     # RS/A2A shrink result; AG grows
            c.collective_bytes += vol
            c.collective_by_kind[kind] = \
                c.collective_by_kind.get(kind, 0) + vol
            c.bytes += rbytes + obytes
            return c

        if op == "fusion":
            m = _FUSION_CALL.search(ins.line)
            root_op = None
            if m:
                inner = self.cost(m.group(1), _for_fusion=True)
                c.flops += inner.flops
                c.dot_flops += inner.dot_flops
                root_op = self._fusion_kind(m.group(1))
            c.bytes += self._materialized_bytes(comp, ins, root_op)
            return c

        if op == "dot":
            flops = self._dot_flops(comp, ins)
            c.flops += flops
            c.dot_flops += flops
            if not in_fusion:
                c.bytes += _shape_bytes(ins.result_type) + \
                    self._operand_bytes(comp, ins)
            return c

        if op == "convolution":
            # rare here; approximate as dot on result x window
            c.flops += 2 * _shape_elems(ins.result_type)
            if not in_fusion:
                c.bytes += _shape_bytes(ins.result_type) + \
                    self._operand_bytes(comp, ins)
            return c

        if op in _ELEMENTWISE or op in ("reduce", "reduce-window"):
            n = _shape_elems(ins.result_type)
            if op in ("reduce", "reduce-window"):
                n = max(n, self._operand_bytes(comp, ins) // 4)
            c.flops += n
        if not in_fusion and op not in _NONMATERIAL:
            c.bytes += self._materialized_bytes(comp, ins, op)
        return c

    @lru_cache(maxsize=None)
    def _fusion_kind(self, comp_name: str) -> Optional[str]:
        """Classify a fused computation for the bytes model.

        A fusion *containing* a dynamic-update-slice aliases its big
        operand (XLA writes only the slice, whatever dtype juggling wraps
        it); one containing only dynamic-slice/gather reads only slices
        of its big operands.
        """
        comp = self.computations.get(comp_name)
        if comp is None:
            return None
        ops = {i.opcode for i in comp.instructions}
        if "dynamic-update-slice" in ops or "scatter" in ops:
            return "dynamic-update-slice"
        if "dynamic-slice" in ops or "gather" in ops:
            return "dynamic-slice"
        return None

    @staticmethod
    def _dims(type_str: str) -> Optional[str]:
        m = _SHAPE_RE.search(type_str)
        return m.group(2) if m else None

    def _materialized_bytes(self, comp: Computation, ins: Instruction,
                            effective_op: Optional[str]) -> int:
        """HBM-traffic model with in-place-update aliasing.

        dynamic-update-slice (or a fusion containing one) aliases its big
        input buffer: XLA writes only the slice, so charging the full
        buffer per scan iteration would be O(L^2)-wrong.  Rules:
          * DUS-like: operands whose *dimensions* match the result are
            aliased (charged 0); 2 x the remaining slice-sized operands;
          * DS-like: big operands (>= 4 x result) are internally sliced —
            charge one result-sized read instead of the full buffer.
        """
        rbytes = _shape_bytes(ins.result_type)
        if effective_op in ("dynamic-slice", "gather"):
            total = 2 * rbytes
            seen_self = False
            for name in _OPERAND.findall(ins.line):
                if not seen_self and name == ins.name:
                    seen_self = True
                    continue
                t = comp.symbols.get(name)
                if not t:
                    continue
                ob = _shape_bytes(t)
                total += min(ob, rbytes)       # sliced reads of big bufs
            return total
        if effective_op in ("dynamic-update-slice", "scatter"):
            total = 0
            rdims = self._dims(ins.result_type)
            seen_self = False
            for name in _OPERAND.findall(ins.line):
                if not seen_self and name == ins.name:
                    seen_self = True
                    continue
                t = comp.symbols.get(name)
                if not t:
                    continue
                if rdims is not None and self._dims(t) == rdims:
                    continue            # aliased in-place buffer
                total += _shape_bytes(t)
            return 2 * total
        return rbytes + self._operand_bytes(comp, ins)

    def _dot_flops(self, comp: Computation, ins: Instruction) -> float:
        result_elems = _shape_elems(ins.result_type)
        m = re.search(r"lhs_contracting_dims={([0-9,]*)}", ins.line)
        ops = _OPERAND.findall(ins.line)
        # first operand after self-reference is lhs
        names = [n for n in ops if n != ins.name]
        if m and names:
            lhs_type = comp.symbols.get(names[0], "")
            sm = _SHAPE_RE.search(lhs_type)
            if sm and sm.group(2):
                dims = [int(d) for d in sm.group(2).split(",")]
                contract = 1
                for di in m.group(1).split(","):
                    if di != "" and int(di) < len(dims):
                        contract *= dims[int(di)]
                return 2.0 * result_elems * contract
        return 2.0 * result_elems


def analyze_hlo(text: str) -> ProgramCost:
    return HloProgram(text).cost()

"""HLO text analysis: collective bytes + op census.

``compiled.cost_analysis()`` has FLOPs and memory traffic but NOT
collective traffic, so we parse the optimized HLO: every
``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` op contributes its *result* buffer size (operand
size for reduce-scatter, which shrinks its output).
"""

from __future__ import annotations

import re
from collections import Counter
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

__all__ = ["collective_stats", "CollectiveStats", "parse_shape_bytes",
           "duplicate_op_census"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

# e.g.  bf16[256,1024]{1,0}   or  f32[]   or tuple components
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def parse_shape_bytes(shape_str: str) -> int:
    """Total bytes of every array shape appearing in ``shape_str``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def row(self) -> Dict[str, float]:
        out = {f"{k}_bytes": float(v) for k, v in self.bytes_by_kind.items()}
        out["collective_bytes"] = float(self.total_bytes)
        out["collective_count"] = float(self.total_count)
        return out


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum result-buffer sizes of collective ops in optimized HLO text."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        # result-shape = op-name(...) — match "<shape> <opname>(" pattern
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)",
                     s)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
        if kind is None:
            continue
        if op.endswith("-start") and not op.startswith("all-reduce"):
            pass  # count the -start (has the shape); -done repeats it
        if op.endswith("-done"):
            continue
        nbytes = parse_shape_bytes(shape_str)
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


def duplicate_op_census(hlo_text: str, top: int = 10) -> List[Tuple[str, int]]:
    """Most-repeated fusion/op names — a cheap remat/redundancy smell test."""
    names = Counter()
    for line in hlo_text.splitlines():
        m = re.match(r"\s*%?([\w.\-]+?)(?:\.\d+)?\s*=", line)
        if m:
            names[m.group(1)] += 1
    return names.most_common(top)

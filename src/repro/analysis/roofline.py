"""Three-term roofline from a compiled dry-run artifact.

    compute term    = HLO_FLOPs   / (chips x peak_FLOP/s)
    memory term     = HLO_bytes   / (chips x HBM_bw)
    collective term = coll_bytes  / (chips x link_bw)

Sources: ``compiled.cost_analysis()`` (flops, bytes accessed);
``analysis.hlo.collective_stats`` over the optimized HLO for collective
bytes.  All terms are *seconds per step* at TPU v5e constants; the
dominant term is the bottleneck and MODEL_FLOPS/HLO_FLOPs measures how
much compiled compute is useful work (remat/redundancy waste shows up
here).
"""

from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass, asdict
from typing import Any, Dict, Optional

from repro.analysis.hlo import collective_stats, CollectiveStats
from repro.launch.mesh import HW

__all__ = ["RooflineReport", "roofline_from_compiled", "model_flops"]


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    # raw
    hlo_flops: float                 # whole-program FLOPs (all devices)
    hlo_bytes: float                 # bytes accessed (all devices)
    collective_bytes: float          # per-device collective result bytes
    collective_breakdown: Dict[str, float]
    model_flops: float               # 6*N*D (or 6*N_active*D) useful FLOPs
    # terms (seconds)
    t_compute: float
    t_memory: float
    t_collective: float
    # derived
    bottleneck: str
    useful_flops_frac: float         # model_flops / hlo_flops
    roofline_frac: float             # t_bound / max(t_*) -> how balanced
    step_time_lower_bound: float     # max of the three terms
    bytes_per_device: Optional[float] = None
    notes: str = ""

    def to_json(self) -> str:
        return json.dumps(asdict(self))


def model_flops(cfg, shape) -> float:
    """Useful FLOPs per step: 6·N·D for training, 2·N·D for fwd-only.

    N = active params (MoE counts routed experts only); D = tokens
    processed (decode: batch tokens, one each).
    """
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence; attention reads the cache too but
    # param-flops dominate the useful-work definition
    return 2.0 * n * shape.global_batch


def roofline_from_compiled(
    *,
    arch: str,
    shape_name: str,
    shape,
    cfg,
    mesh_name: str,
    n_devices: int,
    cost: Dict[str, float],
    hlo_text: str,
    memory_stats: Optional[Any] = None,
) -> RooflineReport:
    # NOTE: compiled.cost_analysis() counts while-loop bodies ONCE — with
    # scan-over-layers that undercounts ~L x.  The trip-count-aware HLO
    # analyzer is the source of truth; cost_analysis kept for reference.
    from repro.analysis.hlo_program import analyze_hlo
    prog = analyze_hlo(hlo_text)

    # the SPMD program is per-device (GSPMD partitions before codegen)
    per_dev_flops = float(prog.flops)
    per_dev_bytes = float(prog.bytes)
    per_dev_coll = float(prog.collective_bytes)

    class _Coll:
        bytes_by_kind = prog.collective_by_kind
    coll = _Coll()

    t_compute = per_dev_flops / HW.PEAK_FLOPS_BF16
    t_memory = per_dev_bytes / HW.HBM_BW
    t_collective = per_dev_coll / HW.ICI_BW

    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective}
    bottleneck = max(terms, key=terms.get)
    mflops = model_flops(cfg, shape)
    total_hlo_flops = per_dev_flops * n_devices
    useful = mflops / total_hlo_flops if total_hlo_flops else 0.0
    t_max = max(terms.values())
    others = sorted(terms.values())[:-1]
    bpd = None
    if memory_stats is not None:
        try:
            bpd = float(memory_stats.argument_size_in_bytes
                        + memory_stats.output_size_in_bytes
                        + memory_stats.temp_size_in_bytes)
        except Exception:
            bpd = None
    return RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, n_devices=n_devices,
        hlo_flops=total_hlo_flops, hlo_bytes=per_dev_bytes * n_devices,
        collective_bytes=per_dev_coll,
        collective_breakdown={k: float(v) for k, v in coll.bytes_by_kind.items()},
        model_flops=mflops,
        t_compute=t_compute, t_memory=t_memory, t_collective=t_collective,
        bottleneck=bottleneck,
        useful_flops_frac=useful,
        roofline_frac=(t_compute / t_max) if t_max else 0.0,
        step_time_lower_bound=t_max,
        bytes_per_device=bpd,
    )

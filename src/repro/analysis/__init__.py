"""repro.analysis"""

"""Per-instruction cost breakdown — the dry-run 'profiler'.

With no hardware to trace, the optimized HLO *is* the profile: this walks
the program with trip-count multipliers (like
:mod:`repro.analysis.hlo_program`) but keeps per-instruction rows, so the
perf loop can ask "which ops move the most bytes / flops / collective
traffic?" and "which buffers are f32 that should be bf16?".
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import List, Optional

from repro.analysis import hlo_program as H

__all__ = ["top_contributors", "Contribution"]


@dataclass
class Contribution:
    bytes: float
    flops: float
    collective_bytes: float
    trips: int
    opcode: str
    computation: str
    line: str


def top_contributors(hlo_text: str, *, n: int = 20,
                     sort_by: str = "bytes") -> List[Contribution]:
    prog = H.HloProgram(hlo_text)
    rows: List[Contribution] = []

    def walk(comp_name: str, mult: int):
        comp = prog.computations.get(comp_name)
        if comp is None:
            return
        for ins in comp.instructions:
            if ins.opcode == "while":
                m = H._WHILE_ATTRS.search(ins.line)
                if m:
                    tm = H._TRIP_COUNT.search(ins.line)
                    trips = (int(tm.group(1)) if tm else
                             prog.trip_count(m.group(1) or m.group(4)))
                    walk(m.group(3) or m.group(2), mult * trips)
                continue
            if ins.opcode == "call":
                mm = H._CALLS.search(ins.line)
                if mm:
                    walk(mm.group(1), mult)
                continue
            c = prog._instr_cost(comp, ins, False)
            if c.bytes or c.flops or c.collective_bytes:
                rows.append(Contribution(
                    bytes=c.bytes * mult, flops=c.flops * mult,
                    collective_bytes=c.collective_bytes * mult,
                    trips=mult, opcode=ins.opcode, computation=comp_name,
                    line=ins.line.strip()[:160]))

    walk(prog.entry, 1)
    rows.sort(key=lambda r: getattr(r, sort_by), reverse=True)
    return rows[:n]


def print_breakdown(hlo_text: str, *, n: int = 15,
                    sort_by: str = "bytes") -> None:
    rows = top_contributors(hlo_text, n=n, sort_by=sort_by)
    total = sum(getattr(r, sort_by) for r in
                top_contributors(hlo_text, n=10 ** 6, sort_by=sort_by))
    print(f"top {n} by {sort_by} (total {total:.3e}):")
    for r in rows:
        val = getattr(r, sort_by)
        print(f"  {val:9.3e} ({100 * val / max(total, 1e-30):4.1f}%) "
              f"x{r.trips:<5d} {r.opcode:22s} {r.line[:95]}")

"""AMU core — the paper's contribution (async memory unit) as a JAX runtime.

Layers:
  * :mod:`repro.core.amu`      — request queue, ids, getfin, config registers
  * :mod:`repro.core.patterns` — access-pattern registers (stream/stride/gather)
  * :mod:`repro.core.spm`      — SPM (VMEM) budget planner / cache-SPM split
  * :mod:`repro.core.offload`  — far-memory tier + streaming prefetcher
  * :mod:`repro.core.sim`      — Fig-1 discrete-event reproduction
"""

from repro.core.amu import (
    AMU,
    AccessConfig,
    AMUError,
    QoS,
    QueueFullPolicy,
    Request,
    RequestState,
    SimBackend,
    DeviceTransferBackend,
    FAILURE_CODE,
)
from repro.core.offload import FarMemoryTier, StreamingPrefetcher
from repro.core.patterns import (
    AccessPattern,
    GatherPattern,
    ScatterPattern,
    StreamPattern,
    StridePattern,
    coalescing_ratio,
    granules,
)
from repro.core.spm import SPMPlan, plan_attention_blocks, plan_matmul_blocks

__all__ = [
    "AMU", "AccessConfig", "AMUError", "QoS", "QueueFullPolicy", "Request",
    "RequestState", "SimBackend", "DeviceTransferBackend", "FAILURE_CODE",
    "FarMemoryTier", "StreamingPrefetcher",
    "AccessPattern", "GatherPattern", "ScatterPattern", "StreamPattern",
    "StridePattern", "coalescing_ratio", "granules",
    "SPMPlan", "plan_attention_blocks", "plan_matmul_blocks",
]

"""Fig-1 reproduction: blocking load/store vs AMU under far-memory latency.

The paper's only quantitative claim (Fig 1 + §1) is qualitative:

  * an OoO core's memory-level parallelism is capped by ROB/IQ/MSHR
    entries, and a long-latency load at ROB head stalls retirement, so
    achieved bandwidth collapses as far-memory latency grows into the
    300 ns – 10 µs band;
  * an asynchronous unit with many outstanding slots and *variable
    granularity* keeps the link saturated across that band.

This module reproduces that claim with a small discrete-event model that
is deliberately faithful to the paper's resource vocabulary (ROB, MSHR,
outstanding slots, granularity), plus closed-form Little's-law bounds so
tests can check the DES against analysis.  It is pure Python/NumPy —
deterministic, seedable, CPU-fast — and drives
``benchmarks/bench_sim.py`` and EXPERIMENTS.md §Paper-claims.

Latency distributions model the paper's tiers: local DRAM ~100-200 ns,
disaggregated pool 300 ns – 2 µs, NVM / remote-node tail up to 10 µs.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "LatencyModel",
    "CoreParams",
    "AMUParams",
    "simulate_blocking_core",
    "simulate_amu",
    "little_bound_blocking",
    "little_bound_amu",
    "bandwidth_sweep",
]


@dataclass(frozen=True)
class LatencyModel:
    """Far-memory latency distribution (seconds).

    ``kind``: "fixed" | "uniform" | "lognormal" | "bimodal".
    ``lo``/``hi`` bound the support; bimodal mixes (lo, hi) with
    ``tail_frac`` mass at ``hi`` (DRAM pool + slow-NVM-tail scenario).
    """

    kind: str = "fixed"
    lo: float = 200e-9
    hi: float = 200e-9
    tail_frac: float = 0.1

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        if self.kind == "fixed":
            return np.full(n, self.lo)
        if self.kind == "uniform":
            return rng.uniform(self.lo, self.hi, n)
        if self.kind == "lognormal":
            mu = math.log(math.sqrt(self.lo * self.hi))
            sigma = math.log(self.hi / self.lo) / 4 if self.hi > self.lo else 0.0
            return np.clip(rng.lognormal(mu, sigma, n), self.lo, self.hi)
        if self.kind == "bimodal":
            tail = rng.random(n) < self.tail_frac
            return np.where(tail, self.hi, self.lo)
        raise ValueError(f"unknown latency kind {self.kind!r}")

    @property
    def mean(self) -> float:
        if self.kind == "fixed":
            return self.lo
        if self.kind == "uniform":
            return 0.5 * (self.lo + self.hi)
        if self.kind == "bimodal":
            return (1 - self.tail_frac) * self.lo + self.tail_frac * self.hi
        # lognormal clipped — close enough to geometric mean for bounds
        return math.sqrt(self.lo * self.hi)


@dataclass(frozen=True)
class CoreParams:
    """Blocking (sync load/store) OoO core — paper Fig 1 left side."""

    rob_entries: int = 256
    mshr_entries: int = 16
    granularity: int = 64          # cache line
    insts_per_access: int = 4      # non-memory work between loads
    cpi: float = 0.25              # cycles/inst at 2 GHz superscalar
    freq_hz: float = 2e9


@dataclass(frozen=True)
class AMUParams:
    """AMU — outstanding-slot count and variable granularity."""

    outstanding: int = 512
    granularity: int = 4096
    issue_overhead: float = 2e-9   # one aload + amortized getfin polling


@dataclass(frozen=True)
class SimResult:
    bytes_moved: int
    elapsed: float
    achieved_bw: float             # bytes/s
    link_bw: float
    utilization: float             # achieved / link
    mean_mlp: float                # time-avg outstanding requests


def _result(bytes_moved: int, elapsed: float, link_bw: float,
            mlp_integral: float) -> SimResult:
    bw = bytes_moved / elapsed if elapsed > 0 else 0.0
    return SimResult(bytes_moved=bytes_moved, elapsed=elapsed,
                     achieved_bw=bw, link_bw=link_bw,
                     utilization=min(1.0, bw / link_bw),
                     mean_mlp=mlp_integral / elapsed if elapsed else 0.0)


def simulate_blocking_core(
    total_bytes: int,
    latency: LatencyModel,
    core: CoreParams = CoreParams(),
    link_bw: float = 50e9,
    seed: int = 0,
) -> SimResult:
    """DES of an OoO core issuing blocking loads over far memory.

    Faithful to the paper's argument, not to any specific µarch:

      * at most ``mshr_entries`` loads in flight,
      * at most ``rob_entries / insts_per_access`` loads in the window
        (in-order retirement: a load at ROB head blocks retirement, so the
        window caps loads between the oldest incomplete and the youngest),
      * issue rate additionally capped by the frontend (cpi · freq),
      * each load moves ``granularity`` bytes; the link serialises bytes
        at ``link_bw`` (so tiny granules also waste the link on latency).
    """
    rng = np.random.default_rng(seed)
    n_req = max(1, total_bytes // core.granularity)
    window = max(1, core.rob_entries // core.insts_per_access)
    mlp_cap = min(core.mshr_entries, window)
    issue_gap = core.insts_per_access * core.cpi / core.freq_hz

    lat = latency.sample(rng, n_req)
    # completion times with in-order retirement: request i may issue only
    # when request i-mlp_cap has *retired* (left the window/MSHR).
    issue_t = np.zeros(n_req)
    done_t = np.zeros(n_req)
    retire_t = np.zeros(n_req)      # in-order: max of own done & predecessor
    link_free = 0.0
    for i in range(n_req):
        t = issue_t[i - 1] + issue_gap if i else 0.0
        if i >= mlp_cap:
            t = max(t, retire_t[i - mlp_cap])
        issue_t[i] = t
        # serialise link occupancy (granularity bytes at link_bw)
        xfer = core.granularity / link_bw
        start_xfer = max(t + lat[i], link_free)
        link_free = start_xfer + xfer
        done_t[i] = start_xfer + xfer
        retire_t[i] = max(done_t[i], retire_t[i - 1] if i else 0.0)
    elapsed = float(retire_t[-1])
    mlp_integral = float(np.sum(done_t - issue_t))
    return _result(n_req * core.granularity, elapsed, link_bw, mlp_integral)


def simulate_amu(
    total_bytes: int,
    latency: LatencyModel,
    amu: AMUParams = AMUParams(),
    link_bw: float = 50e9,
    seed: int = 0,
) -> SimResult:
    """DES of the AMU: ``outstanding`` slots, completion via getfin.

    No in-order retirement — a slot frees the moment its request lands
    (the paper's key structural difference), so long-latency stragglers
    do not block younger requests.
    """
    rng = np.random.default_rng(seed)
    n_req = max(1, total_bytes // amu.granularity)
    lat = latency.sample(rng, n_req)
    slots: List[float] = [0.0] * min(amu.outstanding, n_req)  # free-at times
    heapq.heapify(slots)
    link_free = 0.0
    issue_ready = 0.0
    mlp_integral = 0.0
    last_done = 0.0
    for i in range(n_req):
        slot_free = heapq.heappop(slots)
        t = max(slot_free, issue_ready)
        issue_ready = t + amu.issue_overhead
        xfer = amu.granularity / link_bw
        start_xfer = max(t + lat[i], link_free)
        link_free = start_xfer + xfer
        done = start_xfer + xfer
        heapq.heappush(slots, done)
        mlp_integral += done - t
        last_done = max(last_done, done)
    return _result(n_req * amu.granularity, last_done, link_bw, mlp_integral)


# -- closed-form Little's-law bounds (checked against the DES in tests) ----

def little_bound_blocking(latency_mean: float, core: CoreParams,
                          link_bw: float = 50e9) -> float:
    """Upper bound on blocking-core bandwidth: W·G/(E[L]+G/BW)."""
    window = max(1, core.rob_entries // core.insts_per_access)
    mlp = min(core.mshr_entries, window)
    per_req = latency_mean + core.granularity / link_bw
    return min(link_bw, mlp * core.granularity / per_req)


def little_bound_amu(latency_mean: float, amu: AMUParams,
                     link_bw: float = 50e9) -> float:
    per_req = latency_mean + amu.granularity / link_bw
    issue_cap = amu.granularity / amu.issue_overhead if amu.issue_overhead else link_bw
    return min(link_bw, issue_cap, amu.outstanding * amu.granularity / per_req)


def bandwidth_sweep(
    latencies: Sequence[float],
    total_bytes: int = 1 << 26,
    core: CoreParams = CoreParams(),
    amu: AMUParams = AMUParams(),
    link_bw: float = 50e9,
    kind: str = "fixed",
    seed: int = 0,
) -> List[Dict[str, float]]:
    """The Fig-1 sweep: utilization vs far-memory latency, both designs."""
    rows = []
    for lat in latencies:
        lm = LatencyModel(kind=kind, lo=lat, hi=lat if kind == "fixed" else lat * 10)
        sync = simulate_blocking_core(total_bytes, lm, core, link_bw, seed)
        asyn = simulate_amu(total_bytes, lm, amu, link_bw, seed)
        rows.append({
            "latency_s": lat,
            "sync_util": sync.utilization,
            "amu_util": asyn.utilization,
            "sync_bw": sync.achieved_bw,
            "amu_bw": asyn.achieved_bw,
            "sync_mlp": sync.mean_mlp,
            "amu_mlp": asyn.mean_mlp,
            "speedup": asyn.achieved_bw / max(sync.achieved_bw, 1e-30),
        })
    return rows

"""Far-memory tier manager built on the AMU runtime.

THE host far tier of the two-tier KV hierarchy (and the general
key→tensor offload store).  Production use-cases, all driven through
one :class:`FarMemoryTier`:

  * paged-KV far tier — *every* cold KV page of the serving engine
    (preempted, evicted or finished) is a page-granularity resident
    here; the :class:`~repro.paging.Pager` is the traffic engine that
    moves pages in and out with LATENCY aloads / BULK astores under
    per-QoS windows, while this class is the single storage backend
    (``put``/``home``/``discard``) plus the off-hot-path fetch API the
    finished-sequence reuse path reads through,
  * optimizer-state offload — ZeRO-offload style: Adam moments live in
    the far tier (host DRAM) and stream in/out around the update,
  * parameter streaming — for models larger than HBM (llama4-maverick
    400B on one pod), layer blocks are aload-ed ``prefetch_depth``
    layers ahead of use, the paper's stream pattern at tensor
    granularity.

Everything is expressed as aload/astore + getfin against an :class:`AMU`,
so tests can swap in the simulated backend and assert overlap behaviour
deterministically.  Fetches are fault-safe: a failed aload never loses
the home copy — the entry stays fetchable and a retry re-issues.
"""

from __future__ import annotations

import collections
from dataclasses import dataclass
from typing import Any, Callable, Dict, Hashable, List, Optional, Tuple

import jax
import numpy as np

from .amu import AMU, AMUError, AccessConfig, QoS, FAILURE_CODE

__all__ = ["FarMemoryTier", "StreamingPrefetcher", "OffloadedBuffer"]


def _tree_nbytes(value: Any) -> int:
    """Total bytes of an array, pytree of arrays, or None (0)."""
    if value is None:
        return 0
    total = 0
    for leaf in jax.tree_util.tree_leaves(value):
        total += int(getattr(leaf, "nbytes", np.asarray(leaf).nbytes))
    return total


@dataclass
class OffloadedBuffer:
    """A named tensor (or pytree) whose home is the far tier."""

    key: Hashable
    home: Any                   # array in far memory (host tier)
    nbytes: int
    resident: Any = None        # near-tier copy when fetched
    pending_rid: int = FAILURE_CODE
    tokens: int = -1            # payload-defined freshness tag (KV pages:
                                # valid token positions when stored)


class FarMemoryTier:
    """Key→tensor store in far memory with async fetch/evict via the AMU.

    One instance is the single far-tier backend for a serving engine:
    the pager parks pages into it (``put`` + its own windowed astores),
    prefetches out of it (``home`` + windowed aloads), and the
    finished-sequence path reads it with the ``prefetch``/``get`` API
    below (QoS-prioritised by the AMU's issue queue).  ``store_qos`` /
    ``fetch_qos`` are the §2.2 MACR QoS classes stamped on each
    direction: BULK writeback must never outrank a LATENCY fetch.
    """

    def __init__(self, amu: Optional[AMU] = None,
                 fetch_qos: QoS = QoS.LATENCY,
                 store_qos: QoS = QoS.BULK) -> None:
        self.amu = amu or AMU()
        self.fetch_config = AccessConfig(granularity_bytes=1 << 20,
                                         qos=fetch_qos)
        self.store_config = AccessConfig(granularity_bytes=1 << 20,
                                         qos=store_qos)
        self._store: Dict[Hashable, OffloadedBuffer] = {}
        self._rid_to_key: Dict[int, Hashable] = {}
        self.stats = collections.Counter()

    # -- write path ---------------------------------------------------------
    def put(self, key: Hashable, value: Any, *, nbytes: Optional[int] = None,
            tokens: int = -1) -> None:
        """Install ``value`` as ``key``'s home copy with *no* transfer
        traffic — the storage half of a transfer someone else models
        (the pager's windowed astores), or an alias of an existing host
        payload (shared prefix pages).  ``tokens`` is an optional
        freshness tag (for KV pages: valid positions when stored) that
        :meth:`tokens_of` reports back, letting the engine tell a
        current far copy from a stale one without content hashing."""
        self._store[key] = OffloadedBuffer(
            key=key, home=value,
            nbytes=_tree_nbytes(value) if nbytes is None else int(nbytes),
            tokens=tokens)
        self.stats["put"] += 1

    def offload(self, key: Hashable, value: Any, *, async_: bool = True,
                tokens: int = -1) -> int:
        """astore ``value`` into the far tier under ``key`` (BULK QoS)."""
        buf = OffloadedBuffer(key=key, home=value, nbytes=_tree_nbytes(value),
                              tokens=tokens)
        self._store[key] = buf
        rid = self.amu.astore(value, nbytes=max(1, buf.nbytes),
                              config=self.store_config)
        self.stats["offload"] += 1
        if not async_:
            self.amu.wait(rid)
            buf.home = self.amu.result(rid)
        return rid

    # -- storage bookkeeping -------------------------------------------------
    def home(self, key: Hashable) -> Any:
        """The far-tier home copy (no transfer; the pager's aloads model
        the device-bound traffic for pages read this way)."""
        return self._require(key).home

    def tokens_of(self, key: Hashable) -> int:
        """The freshness tag ``put``/``offload`` stored (-1 = untagged)."""
        buf = self._store.get(key)
        return -1 if buf is None else buf.tokens

    def __contains__(self, key: Hashable) -> bool:
        return key in self._store

    def discard(self, key: Hashable) -> None:
        """Forget one entry (frees the far copy; no transfer)."""
        buf = self._store.pop(key, None)
        if buf is not None and buf.pending_rid != FAILURE_CODE:
            self._rid_to_key.pop(buf.pending_rid, None)

    def discard_seq(self, seq: Hashable) -> None:
        """Forget every ``(seq, logical)`` entry of one sequence."""
        for key in [k for k in self._store
                    if isinstance(k, tuple) and k and k[0] == seq]:
            self.discard(key)

    def far_bytes(self) -> int:
        return sum(b.nbytes for b in self._store.values())

    # -- read path ------------------------------------------------------------
    def prefetch(self, key: Hashable) -> int:
        """Issue an aload for ``key``; returns the request id (non-blocking)."""
        buf = self._require(key)
        if buf.resident is not None:
            return FAILURE_CODE          # already near
        if buf.pending_rid != FAILURE_CODE:
            return buf.pending_rid       # already in flight
        rid = self.amu.aload(buf.home, nbytes=max(1, buf.nbytes),
                             config=self.fetch_config)
        buf.pending_rid = rid
        self._rid_to_key[rid] = key
        return rid

    def poll(self) -> Optional[Hashable]:
        """getfin: complete at most one outstanding fetch; return its key.

        A FAILED request is reaped — its entry reverts to fetchable (the
        home copy is intact) — and reported as no completion."""
        try:
            rid = self.amu.getfin()
        except AMUError:
            self._reap_failed()
            return None
        if rid == FAILURE_CODE:
            return None
        return self.complete_rid(rid, self.amu.request(rid).payload)

    def get(self, key: Hashable) -> Any:
        """Blocking read: prefetch if needed, wait, return near copy.

        Fault-safe: a failed transfer raises :class:`AMUError` but the
        entry's home copy survives and ``pending_rid`` is cleared, so a
        retry after the fault clears re-issues the aload — the far tier
        never loses data to a transient fetch fault."""
        buf = self._require(key)
        if buf.resident is not None:
            return buf.resident
        rid = buf.pending_rid
        if rid == FAILURE_CODE:
            rid = self.prefetch(key)
        req = self.amu.wait(rid)
        self._rid_to_key.pop(rid, None)
        buf.pending_rid = FAILURE_CODE
        if req.error is not None:
            self.stats["fetch_failed"] += 1
            raise AMUError(
                f"far-tier fetch of {key!r} failed") from req.error
        buf.resident = req.payload
        return buf.resident

    # -- shared-AMU completion forwarding ------------------------------------
    def complete_rid(self, rid: int, payload: Any,
                     error: Optional[BaseException] = None
                     ) -> Optional[Hashable]:
        """Land a completion consumed elsewhere on a *shared* AMU (the
        pager's poll drains one completion queue for both consumers and
        forwards ids it does not own here).  Returns the key, or None
        for a foreign/unknown rid."""
        key = self._rid_to_key.pop(rid, None)
        if key is None:
            return None
        buf = self._store.get(key)
        if buf is None:
            return None
        buf.pending_rid = FAILURE_CODE
        if error is not None:
            self.stats["fetch_failed"] += 1
            return None                  # home intact: retry re-issues
        buf.resident = payload
        return key

    def _reap_failed(self) -> None:
        from .amu import RequestState
        for rid in list(self._rid_to_key):
            req = self.amu.request(rid)
            if req.state is RequestState.FAILED:
                self.complete_rid(rid, None, error=req.error
                                  or AMUError(f"request {rid} failed"))

    def evict(self, key: Hashable) -> None:
        """Drop the near-tier copy (home copy remains)."""
        self._require(key).resident = None

    def keys(self) -> List[Hashable]:
        return list(self._store)

    def resident_bytes(self) -> int:
        return sum(b.nbytes for b in self._store.values()
                   if b.resident is not None)

    def _require(self, key: Hashable) -> OffloadedBuffer:
        if key not in self._store:
            raise KeyError(f"far tier has no entry {key!r}")
        return self._store[key]


class StreamingPrefetcher:
    """Layer-weight streaming: aload layer i+depth while computing layer i.

    The paper's stream pattern at tensor granularity.  ``schedule`` is the
    ordered key sequence (e.g. layer indices); ``step()`` is called once
    per consumed element and keeps ``depth`` fetches in flight.
    """

    def __init__(self, tier: FarMemoryTier, schedule: List[Hashable],
                 depth: int = 2) -> None:
        if depth < 1:
            raise ValueError("prefetch depth must be >= 1")
        self.tier = tier
        self.schedule = list(schedule)
        self.depth = depth
        self._next_fetch = 0
        self._next_consume = 0
        self.fetch_overlap_events = 0   # fetches issued while compute pending

    def start(self) -> None:
        for _ in range(min(self.depth, len(self.schedule))):
            self.tier.prefetch(self.schedule[self._next_fetch])
            self._next_fetch += 1

    def step(self) -> Any:
        """Blocking get of the next element; tops up the pipeline."""
        if self._next_consume >= len(self.schedule):
            raise IndexError("prefetcher exhausted")
        key = self.schedule[self._next_consume]
        self._next_consume += 1
        value = self.tier.get(key)
        if self._next_fetch < len(self.schedule):
            self.tier.prefetch(self.schedule[self._next_fetch])
            self._next_fetch += 1
            self.fetch_overlap_events += 1
        return value

    def consume_all(self, fn: Callable[[Any], None]) -> None:
        self.start()
        for _ in range(len(self.schedule) - self._next_consume):
            fn(self.step())

"""Far-memory tier manager built on the AMU runtime.

Production use-cases (all driven through :class:`FarMemoryTier`):

  * optimizer-state offload — ZeRO-offload style: Adam moments live in the
    far tier (host DRAM) and stream in/out around the update,
  * paged-KV offload — cold KV pages for long-context serving park on the
    host and are fetched with LATENCY QoS when a sequence is scheduled,
  * parameter streaming — for models larger than HBM (llama4-maverick
    400B on one pod), layer blocks are aload-ed ``prefetch_depth`` layers
    ahead of use, the paper's stream pattern at tensor granularity.

Everything is expressed as aload/astore + getfin against an :class:`AMU`,
so tests can swap in the simulated backend and assert overlap behaviour
deterministically.
"""

from __future__ import annotations

import collections
from dataclasses import dataclass
from typing import Any, Callable, Dict, Hashable, List, Optional, Tuple

import jax
import numpy as np

from .amu import AMU, AccessConfig, QoS, FAILURE_CODE

__all__ = ["FarMemoryTier", "StreamingPrefetcher", "OffloadedBuffer"]


@dataclass
class OffloadedBuffer:
    """A named tensor whose home is the far tier."""

    key: Hashable
    home: Any                   # array in far memory (host tier)
    nbytes: int
    resident: Any = None        # near-tier copy when fetched
    pending_rid: int = FAILURE_CODE


class FarMemoryTier:
    """Key→tensor store in far memory with async fetch/evict via the AMU."""

    def __init__(self, amu: Optional[AMU] = None,
                 fetch_qos: QoS = QoS.STANDARD) -> None:
        self.amu = amu or AMU()
        self.fetch_config = AccessConfig(granularity_bytes=1 << 20, qos=fetch_qos)
        self._store: Dict[Hashable, OffloadedBuffer] = {}
        self._rid_to_key: Dict[int, Hashable] = {}

    # -- write path ---------------------------------------------------------
    def offload(self, key: Hashable, value: Any, *, async_: bool = True) -> int:
        """astore ``value`` into the far tier under ``key``."""
        nbytes = int(getattr(value, "nbytes", np.asarray(value).nbytes))
        buf = OffloadedBuffer(key=key, home=value, nbytes=nbytes)
        self._store[key] = buf
        rid = self.amu.astore(value, config=self.fetch_config)
        if not async_:
            self.amu.wait(rid)
            buf.home = self.amu.result(rid)
        return rid

    # -- read path ------------------------------------------------------------
    def prefetch(self, key: Hashable) -> int:
        """Issue an aload for ``key``; returns the request id (non-blocking)."""
        buf = self._require(key)
        if buf.resident is not None:
            return FAILURE_CODE          # already near
        if buf.pending_rid != FAILURE_CODE:
            return buf.pending_rid       # already in flight
        rid = self.amu.aload(buf.home, config=self.fetch_config)
        buf.pending_rid = rid
        self._rid_to_key[rid] = key
        return rid

    def poll(self) -> Optional[Hashable]:
        """getfin: complete at most one outstanding fetch; return its key."""
        rid = self.amu.getfin()
        if rid == FAILURE_CODE:
            return None
        key = self._rid_to_key.pop(rid, None)
        if key is not None:
            buf = self._store[key]
            buf.resident = self.amu.request(rid).payload
            buf.pending_rid = FAILURE_CODE
        return key

    def get(self, key: Hashable) -> Any:
        """Blocking read: prefetch if needed, wait, return near copy."""
        buf = self._require(key)
        if buf.resident is not None:
            return buf.resident
        rid = buf.pending_rid
        if rid == FAILURE_CODE:
            rid = self.prefetch(key)
        req = self.amu.wait(rid)
        self._rid_to_key.pop(rid, None)
        buf.resident = req.payload
        buf.pending_rid = FAILURE_CODE
        return buf.resident

    def evict(self, key: Hashable) -> None:
        """Drop the near-tier copy (home copy remains)."""
        self._require(key).resident = None

    def keys(self) -> List[Hashable]:
        return list(self._store)

    def resident_bytes(self) -> int:
        return sum(b.nbytes for b in self._store.values()
                   if b.resident is not None)

    def _require(self, key: Hashable) -> OffloadedBuffer:
        if key not in self._store:
            raise KeyError(f"far tier has no entry {key!r}")
        return self._store[key]


class StreamingPrefetcher:
    """Layer-weight streaming: aload layer i+depth while computing layer i.

    The paper's stream pattern at tensor granularity.  ``schedule`` is the
    ordered key sequence (e.g. layer indices); ``step()`` is called once
    per consumed element and keeps ``depth`` fetches in flight.
    """

    def __init__(self, tier: FarMemoryTier, schedule: List[Hashable],
                 depth: int = 2) -> None:
        if depth < 1:
            raise ValueError("prefetch depth must be >= 1")
        self.tier = tier
        self.schedule = list(schedule)
        self.depth = depth
        self._next_fetch = 0
        self._next_consume = 0
        self.fetch_overlap_events = 0   # fetches issued while compute pending

    def start(self) -> None:
        for _ in range(min(self.depth, len(self.schedule))):
            self.tier.prefetch(self.schedule[self._next_fetch])
            self._next_fetch += 1

    def step(self) -> Any:
        """Blocking get of the next element; tops up the pipeline."""
        if self._next_consume >= len(self.schedule):
            raise IndexError("prefetcher exhausted")
        key = self.schedule[self._next_consume]
        self._next_consume += 1
        value = self.tier.get(key)
        if self._next_fetch < len(self.schedule):
            self.tier.prefetch(self.schedule[self._next_fetch])
            self._next_fetch += 1
            self.fetch_overlap_events += 1
        return value

    def consume_all(self, fn: Callable[[Any], None]) -> None:
        self.start()
        for _ in range(len(self.schedule) - self._next_consume):
            fn(self.step())

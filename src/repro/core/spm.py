"""SPM planner — the paper's reconfigurable Cache/SPM split, for VMEM.

The paper lets applications carve part of the cache into scratch-pad memory
and size it per workload.  On TPU all of VMEM is software-managed, so the
*knob that survives* is how a kernel splits its VMEM budget between

  * working tiles (the "cache" share — data being computed on now), and
  * prefetch buffers (the "SPM" share — tiles in flight via async DMA).

:class:`SPMPlan` turns (VMEM budget, tile byte-sizes, desired pipeline
depth) into concrete block shapes + buffer counts that kernels and the
dry-run use.  It is deliberately analytical — the same arithmetic a kernel
author does on a napkin — so tests can assert its invariants.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

__all__ = ["VMEM_BYTES", "SPMPlan", "plan_matmul_blocks", "plan_attention_blocks"]

#: v5e VMEM per core (128 MiB); kernels plan against a safety margin.
VMEM_BYTES: int = 128 * 1024 * 1024
_SAFETY = 0.8

#: MXU/VPU-aligned tiling: last dim multiples of 128, second-to-last of 8.
LANE = 128
SUBLANE = 8


def _round_down(x: int, m: int) -> int:
    return max(m, (x // m) * m)


@dataclass(frozen=True)
class SPMPlan:
    """A concrete VMEM split for one kernel invocation."""

    block_shapes: Dict[str, Tuple[int, ...]]
    buffers: Dict[str, int]          # #copies per operand (2 = double buffer)
    vmem_bytes: int                  # total planned footprint
    pipeline_depth: int              # outstanding DMA per operand

    def __post_init__(self):
        if self.vmem_bytes > VMEM_BYTES:
            raise ValueError(
                f"SPM plan exceeds VMEM: {self.vmem_bytes} > {VMEM_BYTES}")

    @property
    def utilization(self) -> float:
        return self.vmem_bytes / VMEM_BYTES


def _bytes_of(shape: Sequence[int], dtype_bytes: int) -> int:
    return int(math.prod(shape)) * dtype_bytes


def plan_matmul_blocks(
    m: int, k: int, n: int,
    dtype_bytes: int = 2,
    acc_bytes: int = 4,
    pipeline_depth: int = 2,
    vmem_budget: int = int(VMEM_BYTES * _SAFETY),
) -> SPMPlan:
    """Pick (bm, bk, bn) for an AMU-pipelined matmul.

    Footprint = depth·(bm·bk + bk·bn)·dtype + bm·bn·acc.  We prefer large
    bn/bk (MXU likes 128-multiples on the contracting/lane dims), then grow
    bm while the budget holds.
    """
    bm = _round_down(min(m, 512), SUBLANE)
    bk = _round_down(min(k, 512), LANE)
    bn = _round_down(min(n, 1024), LANE)

    def footprint(bm, bk, bn):
        return (pipeline_depth * (_bytes_of((bm, bk), dtype_bytes)
                                  + _bytes_of((bk, bn), dtype_bytes))
                + _bytes_of((bm, bn), acc_bytes))

    # shrink until it fits, preferring to keep lane dims large
    for dim in ("bm", "bk", "bn", "bm", "bk", "bn", "bm"):
        if footprint(bm, bk, bn) <= vmem_budget:
            break
        if dim == "bm" and bm > SUBLANE:
            bm = _round_down(bm // 2, SUBLANE)
        elif dim == "bk" and bk > LANE:
            bk = _round_down(bk // 2, LANE)
        elif dim == "bn" and bn > LANE:
            bn = _round_down(bn // 2, LANE)
    fp = footprint(bm, bk, bn)
    if fp > vmem_budget:
        raise ValueError(f"cannot fit matmul tiles in VMEM budget ({fp}B)")
    return SPMPlan(
        block_shapes={"x": (bm, bk), "w": (bk, bn), "out": (bm, bn)},
        buffers={"x": pipeline_depth, "w": pipeline_depth, "out": 1},
        vmem_bytes=fp,
        pipeline_depth=pipeline_depth,
    )


def plan_attention_blocks(
    q_len: int, kv_len: int, head_dim: int,
    dtype_bytes: int = 2,
    pipeline_depth: int = 2,
    vmem_budget: int = int(VMEM_BYTES * _SAFETY),
) -> SPMPlan:
    """Pick (block_q, block_kv) for streaming flash attention.

    K/V stream through SPM (the AMU stream pattern); Q and the softmax
    state are the resident working set.
    """
    block_q = _round_down(min(q_len, 512), SUBLANE)
    block_kv = _round_down(min(kv_len, 1024), LANE)
    hd = max(head_dim, LANE)

    def footprint(bq, bkv):
        q = _bytes_of((bq, hd), dtype_bytes)
        kv = 2 * pipeline_depth * _bytes_of((bkv, hd), dtype_bytes)
        acc = _bytes_of((bq, hd), 4) + 2 * _bytes_of((bq, LANE), 4)
        s = _bytes_of((bq, bkv), 4)
        return q + kv + acc + s

    while footprint(block_q, block_kv) > vmem_budget:
        if block_kv > LANE:
            block_kv = _round_down(block_kv // 2, LANE)
        elif block_q > SUBLANE:
            block_q = _round_down(block_q // 2, SUBLANE)
        else:
            raise ValueError("cannot fit attention tiles in VMEM budget")
    return SPMPlan(
        block_shapes={"q": (block_q, hd), "kv": (block_kv, hd)},
        buffers={"q": 1, "k": pipeline_depth, "v": pipeline_depth},
        vmem_bytes=footprint(block_q, block_kv),
        pipeline_depth=pipeline_depth,
    )

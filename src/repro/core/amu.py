"""Asynchronous Memory access Unit (AMU) — the paper's contribution as a runtime.

The paper (Wang et al., CS.AR 2021) proposes an in-core unit that lets
software issue *asynchronous* variable-granularity memory requests
(``aload``/``astore``), poll for completions (``getfin``), and stage data in
a scratch-pad memory (SPM).  On TPU the hardware analogue already exists
(DMA engines + semaphores + VMEM); this module implements the paper's
*programming model* at the runtime level, where "far memory" is host DRAM
(behind PCIe), another chip's HBM (behind ICI) or another pod (behind DCN):

  * :class:`AMU` — the unit: bounded outstanding-request queue, request ids,
    non-blocking ``getfin``, blocking ``wait``.
  * :class:`AccessConfig` — the paper's *Memory Access Configuration
    Register* (granularity, QoS class) and *Default Configuration Register*.
  * :class:`AccessPattern` (see :mod:`repro.core.patterns`) — the paper's
    *Access Pattern Register* (stride / stream / gather / scatter).

Two transfer backends are provided:

  * ``DeviceTransferBackend`` — real ``jax.device_put`` transfers between
    memory kinds (``device`` ↔ ``pinned_host``), which are dispatch-
    asynchronous in JAX: the put returns immediately and completion is
    observed via ``block_until_ready`` (our ``getfin``).
  * ``SimBackend`` — deterministic simulated-latency backend used by tests
    and by the Fig-1 reproduction, so queue behaviour under 300ns–10µs
    far-memory latency is testable on CPU.

Inside Pallas kernels the same model appears at tile granularity
(``pltpu.make_async_copy`` = aload, semaphore wait = getfin); see
``repro/kernels/amu_matmul.py``.
"""

from __future__ import annotations

import collections
import enum
import heapq
import itertools
import time
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.obs import NULL_TRACER

__all__ = [
    "QoS",
    "AccessConfig",
    "Request",
    "RequestState",
    "AMU",
    "AMUError",
    "QueueFullPolicy",
    "SimBackend",
    "DeviceTransferBackend",
    "FAILURE_CODE",
]

#: ``getfin`` returns this when no request has completed — the paper's
#: "failure code" (non-blocking poll must never stall the pipeline).
FAILURE_CODE: int = -1


class AMUError(RuntimeError):
    """Raised on invalid AMU usage (bad id, double-consume, queue misuse)."""


class QoS(enum.IntEnum):
    """QoS label carried in the Memory Access Configuration Register."""

    BULK = 0        # large background transfers (checkpoint, offload)
    STANDARD = 1    # normal tile/page traffic
    LATENCY = 2     # latency-critical (decode-path KV fetch)


class QueueFullPolicy(enum.Enum):
    """What ``aload``/``astore`` do when all outstanding slots are busy."""

    BLOCK = "block"      # wait for a completion (backpressure)
    FAIL = "fail"        # return FAILURE_CODE (caller retries — true async)


@dataclass(frozen=True)
class AccessConfig:
    """Memory Access Configuration Register contents.

    granularity_bytes
        The unit of transfer the request is split into.  The paper's
        *variable granularity*: small for latency-critical random access,
        large to exploit aggregated far-memory bandwidth.
    qos
        Priority class; the AMU engine issues LATENCY before STANDARD
        before BULK when link slots are contended.
    software_defined
        Free-form key/values forwarded to message-interface memory systems
        (paper §2.2 "software-defined configuration information").
    """

    granularity_bytes: int = 512
    qos: QoS = QoS.STANDARD
    software_defined: Dict[str, Any] = field(default_factory=dict)

    def with_granularity(self, nbytes: int) -> "AccessConfig":
        return replace(self, granularity_bytes=int(nbytes))


class RequestState(enum.Enum):
    PENDING = "pending"
    IN_FLIGHT = "in_flight"
    DONE = "done"
    CONSUMED = "consumed"     # returned by getfin/wait exactly once
    FAILED = "failed"


@dataclass
class Request:
    """One asynchronous request (the id in ``Rd`` of aload/astore)."""

    rid: int
    kind: str                     # "aload" | "astore"
    nbytes: int
    config: AccessConfig
    state: RequestState = RequestState.PENDING
    issue_t: float = 0.0
    start_t: float = 0.0          # backend start (0.0 = never started)
    done_t: float = 0.0
    payload: Any = None           # backend-specific handle / result
    error: Optional[BaseException] = None

    @property
    def latency(self) -> float:
        return self.done_t - self.issue_t if self.state in (
            RequestState.DONE, RequestState.CONSUMED) else float("nan")


# ---------------------------------------------------------------------------
# Transfer backends
# ---------------------------------------------------------------------------


class TransferBackend:
    """Moves bytes for the AMU.  start() must be non-blocking."""

    def start(self, req: Request) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def poll(self, req: Request) -> bool:
        """Return True iff ``req`` has completed (non-blocking)."""
        raise NotImplementedError

    def finish(self, req: Request) -> None:
        """Block until ``req`` completes."""
        raise NotImplementedError


class SimBackend(TransferBackend):
    """Deterministic simulated-latency backend (virtual clock).

    Latency model per request::

        t = base_latency + nbytes / bandwidth   (+ per-granule overhead)

    ``latency_fn`` may override ``base_latency`` per request to model the
    paper's *widely distributed* far-memory latency (e.g. sampled from a
    trace).  The virtual clock advances only via :meth:`advance`, keeping
    tests deterministic.
    """

    def __init__(
        self,
        base_latency: float = 1e-6,
        bandwidth: float = 10e9,
        granule_overhead: float = 0.0,
        latency_fn: Optional[Callable[[Request], float]] = None,
    ) -> None:
        self.base_latency = base_latency
        self.bandwidth = bandwidth
        self.granule_overhead = granule_overhead
        self.latency_fn = latency_fn
        self.now = 0.0
        self._done_at: Dict[int, float] = {}

    def transfer_time(self, req: Request) -> float:
        base = (self.latency_fn(req) if self.latency_fn is not None
                else self.base_latency)
        granules = max(1, -(-req.nbytes // max(1, req.config.granularity_bytes)))
        return base + req.nbytes / self.bandwidth + granules * self.granule_overhead

    def start(self, req: Request) -> None:
        if isinstance(req.payload, tuple) and len(req.payload) == 2:
            req.payload = req.payload[0]   # unwrap (src, memory_kind)
        self._done_at[req.rid] = self.now + self.transfer_time(req)

    def poll(self, req: Request) -> bool:
        return self.now >= self._done_at[req.rid]

    def finish(self, req: Request) -> None:
        self.now = max(self.now, self._done_at[req.rid])

    def advance(self, dt: float) -> None:
        self.now += dt


class DeviceTransferBackend(TransferBackend):
    """Real JAX transfers between memory kinds (device ↔ pinned_host).

    ``jax.device_put`` is dispatch-asynchronous: it returns a future-like
    Array immediately.  ``poll`` uses the array's readiness; ``finish``
    blocks.  On CPU-only containers both memory kinds resolve to host
    memory, so semantics (not speed) are what tests exercise.
    """

    def __init__(self, device: Optional[jax.Device] = None) -> None:
        self.device = device or jax.devices()[0]

    def _sharding(self, memory_kind: Optional[str]):
        s = jax.sharding.SingleDeviceSharding(self.device)
        if memory_kind is not None:
            try:
                s = s.with_memory_kind(memory_kind)
            except Exception:  # backend without memory-kind support
                pass
        return s

    def start(self, req: Request) -> None:
        src, memory_kind = req.payload
        req.payload = jax.device_put(src, self._sharding(memory_kind))

    def poll(self, req: Request) -> bool:
        try:
            return req.payload.is_ready()
        except AttributeError:
            return True

    def finish(self, req: Request) -> None:
        jax.block_until_ready(req.payload)


# ---------------------------------------------------------------------------
# The AMU proper
# ---------------------------------------------------------------------------


class AMU:
    """The Asynchronous Memory access Unit runtime.

    Mirrors the paper's architecture: a bounded number of outstanding
    request slots (hardware queue entries), per-request ids, a completion
    queue drained by ``getfin``, QoS-ordered issue, and configuration
    registers (``default_config`` = the paper's Default Configuration
    Register; per-call overrides = specifying a config register in the
    instruction).
    """

    def __init__(
        self,
        backend: Optional[TransferBackend] = None,
        max_outstanding: int = 64,
        default_config: Optional[AccessConfig] = None,
        full_policy: QueueFullPolicy = QueueFullPolicy.BLOCK,
        clock: Callable[[], float] = time.monotonic,
        tracer=None,
        metrics=None,
    ) -> None:
        if max_outstanding < 1:
            raise AMUError("max_outstanding must be >= 1")
        self.backend = backend or SimBackend()
        self.max_outstanding = max_outstanding
        self.default_config = default_config or AccessConfig()
        self.full_policy = full_policy
        self._clock = (self.backend_clock
                       if isinstance(self.backend, SimBackend) else clock)
        self._ids = itertools.count()
        self._requests: Dict[int, Request] = {}
        self._issue_q: List[Tuple[int, int, int]] = []   # (-qos, seq, rid)
        self._seq = itertools.count()
        self._in_flight: Dict[int, Request] = {}
        self._completed: Deque[int] = collections.deque()
        self.stats = collections.Counter()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics
        self._notes: Dict[int, dict] = {}   # rid -> extra span args

    def annotate(self, rid: int, **kw) -> None:
        """Attach key/values to the transfer span emitted when ``rid``
        retires (callers — the pager — tag seq/logical/window-wait).
        Only call under ``tracer.enabled`` — notes die with the span."""
        note = self._notes.get(rid)
        if note is None:
            note = self._notes[rid] = {}
        note.update(kw)

    # -- clocks ------------------------------------------------------------
    def backend_clock(self) -> float:
        return self.backend.now  # type: ignore[attr-defined]

    # -- issue path (aload / astore) ---------------------------------------
    def _issue(self, kind: str, nbytes: int, payload: Any,
               config: Optional[AccessConfig],
               qos: Optional[QoS] = None) -> int:
        cfg = config or self.default_config
        if qos is not None and qos != cfg.qos:
            cfg = replace(cfg, qos=QoS(qos))
        if nbytes <= 0:
            raise AMUError(f"{kind}: nbytes must be positive, got {nbytes}")
        if self.outstanding >= self.max_outstanding:
            if self.full_policy is QueueFullPolicy.FAIL:
                self.stats["rejected"] += 1
                return FAILURE_CODE
            self._wait_for_slot()
        rid = next(self._ids)
        req = Request(rid=rid, kind=kind, nbytes=nbytes, config=cfg,
                      issue_t=self._clock(), payload=payload)
        self._requests[rid] = req
        heapq.heappush(self._issue_q, (-int(cfg.qos), next(self._seq), rid))
        self.stats[kind] += 1
        self._pump()
        return rid

    def aload(self, src: Any = None, nbytes: int = 0,
              config: Optional[AccessConfig] = None,
              memory_kind: Optional[str] = "device",
              qos: Optional[QoS] = None) -> int:
        """Issue an asynchronous load (far memory → SPM/near tier).

        Returns the request id immediately (or FAILURE_CODE under the
        FAIL policy when all outstanding slots are busy).  ``qos``
        overrides only the QoS class of the effective config — the
        paper's per-instruction MACR override without callers having to
        rebuild a whole :class:`AccessConfig`.
        """
        nbytes = nbytes or _nbytes_of(src)
        return self._issue("aload", nbytes, (src, memory_kind), config, qos)

    def astore(self, src: Any = None, nbytes: int = 0,
               config: Optional[AccessConfig] = None,
               memory_kind: Optional[str] = "pinned_host",
               qos: Optional[QoS] = None) -> int:
        """Issue an asynchronous store (SPM/near tier → far memory)."""
        nbytes = nbytes or _nbytes_of(src)
        return self._issue("astore", nbytes, (src, memory_kind), config, qos)

    def _pump(self) -> None:
        """Move queued requests into flight and harvest completions."""
        while self._issue_q and len(self._in_flight) < self.max_outstanding:
            _, _, rid = heapq.heappop(self._issue_q)
            req = self._requests[rid]
            try:
                self.backend.start(req)
                req.state = RequestState.IN_FLIGHT
                req.start_t = self._clock()
                self._in_flight[rid] = req
            except BaseException as e:  # failed issue -> FAILED, poison req
                req.state = RequestState.FAILED
                req.error = e
                self._completed.append(rid)
                if self.tracer.enabled:
                    self.tracer.instant(
                        "amu", req.config.qos.name, "fault",
                        {"rid": rid, "kind": req.kind,
                         "nbytes": req.nbytes,
                         **self._notes.pop(rid, {})})
        for rid in list(self._in_flight):
            req = self._in_flight[rid]
            if self.backend.poll(req):
                self._retire(req)

    def _wait_for_slot(self) -> None:
        """Block until a slot frees.  Completions are *retired* into the
        completion queue (still observable via getfin) — never consumed."""
        self._pump()
        while self.outstanding >= self.max_outstanding and self._in_flight:
            rid = next(iter(self._in_flight))
            req = self._in_flight[rid]
            self.backend.finish(req)
            self._retire(req)
            self._pump()

    def _retire(self, req: Request) -> None:
        self._in_flight.pop(req.rid, None)
        req.state = RequestState.DONE
        req.done_t = self._clock()
        self._completed.append(req.rid)
        self.stats["completed"] += 1
        qos = req.config.qos.name
        if self.tracer.enabled:
            # one span per transfer, issue -> retire, on the QoS track
            # (queued_us = time waiting for a queue slot before the
            # backend started moving bytes)
            self.tracer.complete(
                "amu", qos, req.kind, req.issue_t, req.done_t,
                {"rid": req.rid, "nbytes": req.nbytes, "qos": qos,
                 "queued_us": (req.start_t - req.issue_t) * 1e6,
                 **self._notes.pop(req.rid, {})})
        if self.metrics is not None:
            self.metrics.observe(f"amu/latency_s/{req.kind}/{qos}",
                                 req.done_t - req.issue_t)

    # -- completion path (getfin / wait) ------------------------------------
    def getfin(self) -> int:
        """Non-blocking: id of one finished request, or FAILURE_CODE.

        This is the paper's ``getfin`` instruction: it never blocks, and
        each completed id is returned exactly once.
        """
        self._pump()
        if not self._completed:
            return FAILURE_CODE
        rid = self._completed.popleft()
        req = self._requests[rid]
        if req.state is RequestState.FAILED:
            raise AMUError(f"request {rid} failed") from req.error
        req.state = RequestState.CONSUMED
        return rid

    def wait(self, rid: int) -> Request:
        """Block until a *specific* request completes, consume and return it."""
        req = self._requests.get(rid)
        if req is None:
            raise AMUError(f"unknown request id {rid}")
        if req.state is RequestState.CONSUMED:
            raise AMUError(f"request {rid} already consumed")
        if req.state is RequestState.PENDING:
            # force it into flight ahead of queue order
            self._issue_q = [(q, s, r) for (q, s, r) in self._issue_q if r != rid]
            heapq.heapify(self._issue_q)
            self.backend.start(req)
            req.state = RequestState.IN_FLIGHT
            req.start_t = self._clock()
            self._in_flight[rid] = req
        if req.state is RequestState.IN_FLIGHT:
            self.backend.finish(req)
            self._retire(req)
        self._completed.remove(rid)
        req.state = RequestState.CONSUMED
        return req

    def wait_any(self) -> int:
        """Block until *some* request completes; return its id (consumed)."""
        self._pump()
        if self._completed:
            return self.getfin()
        if not self._in_flight:
            raise AMUError("wait_any with no requests in flight")
        # finish the earliest in-flight request
        rid = next(iter(self._in_flight))
        req = self._in_flight[rid]
        self.backend.finish(req)
        self._retire(req)
        return self.getfin()

    def drain(self) -> List[int]:
        """Wait for everything; return all completed ids in order."""
        out: List[int] = []
        while self.outstanding or self._completed:
            out.append(self.wait_any() if not self._completed else self.getfin())
        return out

    # -- introspection -------------------------------------------------------
    @property
    def outstanding(self) -> int:
        return len(self._in_flight) + len(self._issue_q)

    def request(self, rid: int) -> Request:
        try:
            return self._requests[rid]
        except KeyError:
            raise AMUError(f"unknown request id {rid}") from None

    def result(self, rid: int) -> Any:
        """Payload of a consumed request (the landed Array for aload)."""
        req = self.request(rid)
        if req.state is not RequestState.CONSUMED:
            raise AMUError(f"request {rid} not consumed yet (state={req.state})")
        return req.payload


def _nbytes_of(x: Any) -> int:
    if x is None:
        raise AMUError("nbytes or a sized src is required")
    if hasattr(x, "nbytes"):
        return int(x.nbytes)
    return int(np.asarray(x).nbytes)

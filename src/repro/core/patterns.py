"""Access Pattern Register contents (paper §2.2).

The paper's AMU can be programmed with *complex access patterns* (stride,
stream, ...) so one instruction moves a whole structured region.  We keep
the same vocabulary and use the descriptors in three places:

  * the runtime AMU splits a pattern into granules (requests),
  * the SPM planner sizes prefetch buffers from the pattern's reuse,
  * kernels pick their BlockSpec / DMA schedule from the pattern kind.

Patterns are plain dataclasses so they can live in configs and be hashed
into jit static args.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "AccessPattern",
    "StreamPattern",
    "StridePattern",
    "GatherPattern",
    "ScatterPattern",
    "granules",
]


@dataclass(frozen=True)
class AccessPattern:
    """Base descriptor: a logical region of ``total_bytes``."""

    total_bytes: int

    def granule_ranges(self, granularity: int) -> Iterator[Tuple[int, int]]:
        """Yield (offset, nbytes) granules covering the pattern."""
        raise NotImplementedError


@dataclass(frozen=True)
class StreamPattern(AccessPattern):
    """Contiguous stream — the double-buffered pipeline case."""

    def granule_ranges(self, granularity: int) -> Iterator[Tuple[int, int]]:
        off = 0
        while off < self.total_bytes:
            yield off, min(granularity, self.total_bytes - off)
            off += granularity


@dataclass(frozen=True)
class StridePattern(AccessPattern):
    """``count`` blocks of ``block_bytes`` separated by ``stride_bytes``."""

    block_bytes: int = 0
    stride_bytes: int = 0
    count: int = 0

    def __post_init__(self):
        if self.block_bytes > self.stride_bytes > 0:
            raise ValueError("block_bytes must not exceed stride_bytes")

    def granule_ranges(self, granularity: int) -> Iterator[Tuple[int, int]]:
        for i in range(self.count):
            base = i * self.stride_bytes
            off = 0
            while off < self.block_bytes:
                yield base + off, min(granularity, self.block_bytes - off)
                off += granularity


@dataclass(frozen=True)
class GatherPattern(AccessPattern):
    """Indexed reads (MoE expert dispatch, paged-KV fetch).

    ``indices`` are element offsets of ``elem_bytes`` each; contiguous runs
    are coalesced into one granule up to ``granularity`` — the AMU's
    variable-granularity win for semi-sorted gathers.
    """

    indices: Tuple[int, ...] = field(default_factory=tuple)
    elem_bytes: int = 1

    def granule_ranges(self, granularity: int) -> Iterator[Tuple[int, int]]:
        if not self.indices:
            return
        run_start = prev = self.indices[0]
        run_len = 1
        for ix in self.indices[1:]:
            contiguous = ix == prev + 1
            if contiguous and (run_len + 1) * self.elem_bytes <= granularity:
                run_len += 1
            else:
                yield run_start * self.elem_bytes, run_len * self.elem_bytes
                run_start, run_len = ix, 1
            prev = ix
        yield run_start * self.elem_bytes, run_len * self.elem_bytes


@dataclass(frozen=True)
class ScatterPattern(GatherPattern):
    """Indexed writes — same coalescing as GatherPattern."""


def granules(pattern: AccessPattern, granularity: int) -> int:
    """Number of requests the AMU issues for ``pattern`` at ``granularity``."""
    return sum(1 for _ in pattern.granule_ranges(granularity))


def coalescing_ratio(indices: Sequence[int], elem_bytes: int,
                     granularity: int) -> float:
    """requests(naive one-per-element) / requests(coalesced).

    >1 means the AMU's variable granularity reduced request count — the
    paper's aggregated-bandwidth argument in one number.
    """
    idx = tuple(int(i) for i in indices)
    if not idx:
        return 1.0
    pat = GatherPattern(total_bytes=len(idx) * elem_bytes, indices=idx,
                        elem_bytes=elem_bytes)
    return len(idx) / max(1, granules(pat, granularity))

"""rwkv6-7b — RWKV-6 "Finch" 7B: attention-free, data-dependent decay.

[arXiv:2404.05892; hf:RWKV/rwkv-6-world-7b]  32L, d_model 4096,
head_size 64 (=> 64 heads), channel-mix ratio 3.5 (d_ff 14336),
vocab 65536 (RWKV World tokenizer).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,          # head_size 64
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    attention="none",
)

SMOKE = ModelConfig(
    name="rwkv6-smoke",
    family="ssm",
    num_layers=3,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=448,
    vocab_size=512,
    attention="none",
)

"""zamba2-1.2b — hybrid: Mamba2 backbone + ONE shared attention block.

[arXiv:2411.15242; hf:Zyphra/Zamba2-1.2B]  38 Mamba2 blocks, d_model 2048,
ssm_state 64, head_dim 64 (d_inner 4096 => 64 mamba heads); the shared
attention+MLP block (32 heads, kv 32, d_ff 8192) is applied with REUSED
weights every 6 mamba blocks (Zamba2's shared-block design).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    shared_attn_every=6,
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    num_layers=8,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    ssm_state=8,
    shared_attn_every=3,
)

"""phi4-mini-3.8b — dense LM: RoPE + SwiGLU + GQA.

[arXiv:2412.08905; hf:microsoft/Phi-4-mini]  32L, d_model 3072, 24 heads
(GQA kv 8, head_dim 128), d_ff 8192, vocab 200064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
)

SMOKE = ModelConfig(
    name="phi4-smoke",
    family="dense",
    num_layers=3,
    d_model=96,
    num_heads=6,
    num_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
)

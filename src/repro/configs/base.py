"""Config dataclasses: model architecture, input shapes, mesh, training.

Frozen dataclasses so they hash into jit static arguments.  Every assigned
architecture in ``repro/configs/<id>.py`` instantiates :class:`ModelConfig`
with the exact published dimensions plus a ``smoke()`` reduction of the
same family for CPU tests.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "MeshConfig", "TrainConfig", "SHAPES"]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description (family + dimensions + feature flags)."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # -- attention flavour --------------------------------------------------
    attention: str = "full"          # full | swa | none
    window: int = 0                  # SWA window (h2o-danube)
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w)
    qk_norm: bool = False            # command-r-plus style
    logit_scale: float = 1.0
    tie_embeddings: bool = False

    # -- MoE -----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1               # MoE on layers where (i % moe_every)==moe_every-1
    shared_expert: bool = False      # llama4-style shared expert
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # -- SSM / RWKV ----------------------------------------------------------
    ssm_state: int = 0               # N (mamba2) / head K dim (rwkv6 uses head_dim)
    ssm_conv: int = 4                # depthwise causal conv width
    ssm_expand: int = 2              # d_inner = expand * d_model
    shared_attn_every: int = 0       # zamba2: shared attn block cadence

    # -- encoder-decoder -----------------------------------------------------
    encoder_layers: int = 0          # seamless-m4t
    frontend: str = "none"           # none | audio_stub | vision_stub

    # -- numerics ------------------------------------------------------------
    norm_eps: float = 1e-5
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(1, self.num_heads))
        if self.num_heads and self.num_kv_heads and self.num_heads % self.num_kv_heads:
            raise ValueError(f"{self.name}: num_heads must divide by num_kv_heads")

    # -- derived -------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to 256 so it shards on any mesh axis we use."""
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(1, self.num_kv_heads)

    @property
    def is_subquadratic(self) -> bool:
        """May run long_500k: SSM/linear/hybrid/SWA families."""
        return self.family in ("ssm", "hybrid") or self.attention == "swa"

    @property
    def has_decoder(self) -> bool:
        return True   # all assigned archs decode (enc-dec has a decoder)

    def param_count(self) -> int:
        """Analytical parameter count (embedding + blocks + head)."""
        d, V = self.d_model, self.padded_vocab
        total = V * d                       # input embedding
        if not self.tie_embeddings:
            total += V * d                  # lm head
        total += self.num_layers * self._block_params()
        if self.family == "encdec":
            total += self.encoder_layers * self._encoder_block_params()
        if self.shared_attn_every:
            total += self._shared_attn_params()
        return total

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        return (d * self.num_heads * hd          # q
                + 2 * d * self.num_kv_heads * hd  # k, v
                + self.num_heads * hd * d)        # o

    def _ffn_params(self, d_ff: Optional[int] = None) -> int:
        ff = d_ff or self.d_ff
        return 3 * self.d_model * ff             # swiglu gate/up/down

    def _block_params(self) -> int:
        d = self.d_model
        if self.family == "ssm" and self.name.startswith("rwkv"):
            # time-mix (r,k,v,g,o ~ 5 d^2 + decay lora) + channel-mix
            return 5 * d * d + 2 * d * self.d_ff + 2 * d
        if self.family in ("ssm", "hybrid"):
            di = self.d_inner
            n = self.ssm_state
            blk = d * (2 * di + 2 * n * (di // max(1, self.head_dim)) if False else 0)
            # mamba2: in_proj d->(2*di + 2*n_groups*N + heads), out_proj di->d
            heads = di // self.head_dim
            blk = d * (2 * di + 2 * n + heads) + di * d + self.ssm_conv * (di + 2 * n)
            return blk + 2 * d
        moe_layer = (self.num_experts > 0)
        ffn = self._ffn_params()
        if moe_layer:
            n_moe = self.num_layers // self.moe_every
            n_dense = self.num_layers - n_moe
            per_moe = self.num_experts * ffn + (ffn if self.shared_expert else 0) \
                + self.d_model * self.num_experts
            avg = (n_moe * per_moe + n_dense * ffn) / self.num_layers
            return int(self._attn_params() + avg + 2 * self.d_model)
        return self._attn_params() + ffn + 2 * self.d_model

    def _encoder_block_params(self) -> int:
        return self._attn_params() + self._ffn_params() + 2 * self.d_model

    def _shared_attn_params(self) -> int:
        return self._attn_params() + self._ffn_params() + 2 * self.d_model

    def active_param_count(self) -> int:
        """Per-token active params (MoE: routed top-k only) for 6·N_active·D."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        ffn = self._ffn_params()
        n_moe = self.num_layers // self.moe_every
        n_dense = self.num_layers - n_moe
        active_blocks = self.num_layers * (self._attn_params() + 2 * d) \
            + n_dense * ffn \
            + n_moe * (self.experts_per_token * ffn
                       + (ffn if self.shared_expert else 0)
                       + d * self.num_experts)
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return emb + active_blocks


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape suite cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


@dataclass(frozen=True)
class MeshConfig:
    """Logical mesh description (see launch/mesh.py)."""

    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def n_devices(self) -> int:
        return math.prod(self.shape)

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axes if a in ("pod", "data"))

    @property
    def axis_size(self):
        return dict(zip(self.axes, self.shape))


@dataclass(frozen=True)
class TrainConfig:
    """Training-loop knobs."""

    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    microbatches: int = 1            # gradient accumulation
    remat: str = "block"             # none | block | full
    zero1: bool = True               # shard optimizer state over data axis
    grad_compression: str = "none"   # none | bf16
    seed: int = 0
    checkpoint_every: int = 100
    log_every: int = 10
    act_sharding: str = "baseline"   # baseline | optimized (see dist/act_sharding)

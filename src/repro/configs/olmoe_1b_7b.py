"""olmoe-1b-7b — fully open MoE: 64 experts, top-8, every layer.

[arXiv:2409.02060; hf:allenai/OLMoE-1B-7B]  16L, d_model 2048, 16 heads
(kv 16 => MHA), expert d_ff 1024, vocab 50304, 64 experts top-8.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    num_experts=64,
    experts_per_token=8,
    moe_every=1,
)

SMOKE = ModelConfig(
    name="olmoe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=32,
    vocab_size=512,
    num_experts=8,
    experts_per_token=2,
    moe_every=1,
)

"""command-r-plus-104b — large dense LM, GQA, no biases, tied embeddings.

[hf:CohereForAI/c4ai-command-r-plus; unverified tier]  64L, d_model 12288,
96 heads (GQA kv 8, head_dim 128), d_ff 33792, vocab 256000, qk-norm,
tied embeddings with logit_scale (Cohere convention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    rope_theta=75_000_000.0,
    qk_norm=True,
    tie_embeddings=True,
    logit_scale=0.0625,
)

SMOKE = ModelConfig(
    name="commandr-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    qk_norm=True,
    tie_embeddings=True,
    logit_scale=0.0625,
)

"""h2o-danube-1.8b — llama/mistral-style dense LM with sliding-window attn.

[arXiv:2401.16818; hf:h2oai/h2o-danube-1.8b-base]  24L, d_model 2560,
32 heads (GQA kv 8, head_dim 80), d_ff 6912, vocab 32000, SWA window 4096.
SWA makes it sub-quadratic, so the long_500k shape RUNS for this arch.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32000,
    attention="swa",
    window=4096,
)

SMOKE = ModelConfig(
    name="danube-smoke",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    attention="swa",
    window=8,
)

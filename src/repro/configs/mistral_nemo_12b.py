"""mistral-nemo-12b — dense 128k-context LM.

[hf:mistralai/Mistral-Nemo-Base-2407]  40L, d_model 5120, 32 heads
(GQA kv 8), head_dim 128 (explicit — not d_model/heads), d_ff 14336,
vocab 131072, rope_theta 1e6.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="nemo-smoke",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,           # head_dim != d_model/heads, like the real config
    d_ff=256,
    vocab_size=512,
)

"""seamless-m4t-medium — encoder-decoder multimodal translation backbone.

[arXiv:2308.11596; hf:facebook/seamless-m4t-medium]  12L enc + 12L dec,
d_model 1024, 16 heads (kv 16 => MHA), d_ff 4096, vocab 256206.
The speech/text frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, S, d) for the encoder.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,            # decoder
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    frontend="audio_stub",
)

SMOKE = ModelConfig(
    name="seamless-smoke",
    family="encdec",
    num_layers=2,
    encoder_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    frontend="audio_stub",
)

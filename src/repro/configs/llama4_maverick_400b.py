"""llama4-maverick-400b-a17b — MoE with early fusion, 128 experts top-1.

[hf:meta-llama/Llama-4-Maverick-17B-128E; unverified tier]  48L,
d_model 5120, 40 heads (GQA kv 8, head_dim 128), expert d_ff 8192,
vocab 202048, 128 experts top-1 + shared expert.

DEVIATION (documented in DESIGN.md §Arch-applicability): MoE on alternate
layers (``moe_every=2``), matching the released model's interleaved
MoE/dense pattern and the "400B total / 17B active" name; a flat
48Lx128e reading would give ~780B total, contradicting the name.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    rope_theta=500_000.0,
    num_experts=128,
    experts_per_token=1,
    moe_every=2,
    shared_expert=True,
)

SMOKE = ModelConfig(
    name="llama4-smoke",
    family="moe",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=64,
    vocab_size=512,
    num_experts=8,
    experts_per_token=1,
    moe_every=2,
    shared_expert=True,
)

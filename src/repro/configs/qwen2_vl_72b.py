"""qwen2-vl-72b — VLM backbone with M-RoPE and dynamic resolution.

[arXiv:2409.12191; hf:Qwen/Qwen2-VL-72B]  80L, d_model 8192, 64 heads
(GQA kv 8, head_dim 128), d_ff 29568, vocab 152064,
mrope_section (16, 24, 24).  The vision tower is a STUB per the
assignment: early-fused token/patch streams arrive as token ids plus
(t, h, w) position ids of shape (3, B, S).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    frontend="vision_stub",
)

SMOKE = ModelConfig(
    name="qwen2vl-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    mrope_sections=(4, 2, 2),
    frontend="vision_stub",
)

"""Architecture registry: the 10 assigned archs + smoke reductions.

``get_config(arch_id)`` returns the exact published configuration;
``get_smoke(arch_id)`` returns a reduced same-family config for CPU tests.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (ModelConfig, ShapeConfig, MeshConfig,
                                TrainConfig, SHAPES)

_ARCH_MODULES: Dict[str, str] = {
    "rwkv6-7b": "rwkv6_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "command-r-plus-104b": "command_r_plus_104b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "zamba2-1.2b": "zamba2_1_2b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def _module(arch_id: str):
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke(arch_id: str) -> ModelConfig:
    return _module(arch_id).SMOKE


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; known: {[s.name for s in SHAPES]}")


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k only runs for sub-quadratic archs (SSM/hybrid/SWA)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False
    return True


__all__ = [
    "ModelConfig", "ShapeConfig", "MeshConfig", "TrainConfig", "SHAPES",
    "ARCH_IDS", "get_config", "get_smoke", "get_shape", "cell_is_runnable",
]

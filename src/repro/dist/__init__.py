"""Sharded-execution layer: activation policies, parameter/optimizer
PartitionSpecs, and jitted step factories over the launch mesh axes.

Three modules, consumed by ``repro.models`` (lazily, per call site),
``repro.launch`` and the serve engine:

  * :mod:`repro.dist.act_sharding` — scoped activation sharding /
    precision policy (baseline = paper-faithful GSPMD-implicit layout;
    optimized = explicit heads-/seq-sharded attention, seq-sharded
    residual stream, native-dtype norms),
  * :mod:`repro.dist.sharding` — PartitionSpec assignment for parameter,
    optimizer (ZeRO-1) and batch pytrees over the (pod, data, model)
    mesh axes built by :mod:`repro.launch.mesh`,
  * :mod:`repro.dist.steps` — jitted, donated, mesh-sharded train /
    prefill / serve step factories plus abstract-input builders for the
    compile-only dry-run.

The AMU thesis at system scale: latency (far memory there, inter-chip
collectives here) is hidden by keeping many independent units of work in
flight — here, donated mesh-parallel step functions whose parameters and
KV state live sharded across devices.
"""

from repro.dist import act_sharding, sharding, steps

__all__ = ["act_sharding", "sharding", "steps"]

"""Jitted, donated, mesh-sharded step factories (train / prefill / serve).

Each ``make_*_step`` returns ``(fn, specs)``:

  * ``fn`` — a callable that enters the mesh context and invokes the
    underlying ``jax.jit``; it also exposes ``.lower(*abstract_args)``
    so the compile-only dry-run can lower cells without allocating,
  * ``specs`` — the PartitionSpec trees (``params`` / ``opt`` /
    ``batch``) the caller uses to place inputs.

Sharding is enforced *inside* the step via ``with_sharding_constraint``
(callers may hand in replicated arrays — restore/elastic paths do), and
train outputs carry explicit ``out_shardings`` so donation lines up and
updated parameters stay TP/ZeRO-sharded across steps.

``abstract_params`` / ``abstract_opt_state`` / ``train_inputs`` /
``decode_inputs`` build ``ShapeDtypeStruct`` pytrees — nothing is
allocated — for spec construction and dry-run lowering.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.dist import act_sharding as acts
from repro.dist.sharding import batch_specs, opt_state_specs, param_specs
from repro.models import model as model_mod
from repro.optim.adamw import adamw_init, adamw_update

__all__ = [
    "make_train_step", "make_prefill_step", "make_serve_step",
    "make_mixed_step", "abstract_params", "abstract_opt_state",
    "train_inputs", "decode_inputs", "paged_cache_specs",
]


# -- abstract inputs (ShapeDtypeStruct pytrees; nothing allocated) --------------

def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: model_mod.init_params(cfg, k),
                          jax.random.PRNGKey(0))


def abstract_opt_state(cfg: ModelConfig):
    return jax.eval_shape(adamw_init, abstract_params(cfg))


def train_inputs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   jnp.float32)
    return batch


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: model_mod.init_cache(cfg, B, S))
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return cache, tokens


def paged_cache_specs(mesh, cfg: ModelConfig) -> Dict[str, P]:
    """PartitionSpecs for the PagedCache pool layout.

    Frames are ``(L, N, page, Hkv, D)``: the KV-head axis shards over
    ``model`` exactly like the dense per-slot cache would, provided the
    head count divides the axis; otherwise the pool replicates (the
    page table and positions always do — they are tiny int32 control
    state every shard needs whole, like the paper's APRs).

    Cross-request prefix sharing does not change these specs: a shared
    frame is the same ``N``-axis row read by several slots' page-table
    rows, and the frame axis is never sharded — only the KV-head axis
    inside a frame is.  Sharing interacts with *donation* instead; see
    the audit note on :func:`make_serve_step`.
    """
    model_size = mesh.shape.get("model", 1)
    pages = (P(None, None, None, "model", None)
             if model_size > 1 and cfg.num_kv_heads % model_size == 0
             else P())
    return {"k_pages": pages, "v_pages": pages, "page_table": P()}


# -- shared plumbing -----------------------------------------------------------

def _policy_for(act_policy: Optional[acts.ActPolicy],
                tcfg: Optional[TrainConfig] = None) -> acts.ActPolicy:
    if act_policy is not None:
        return act_policy
    if tcfg is not None and tcfg.act_sharding == "optimized":
        return acts.OPTIMIZED
    return acts.BASELINE


def _constrain_tree(tree, specs, mesh):
    return jax.tree_util.tree_map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, s)),
        tree, specs)


def _named(mesh, specs):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


class _MeshedStep:
    """Jitted step bound to its mesh: entering the mesh context at call
    time makes the thread-local mesh visible to trace-time policy code
    (``act_sharding.constrain``) even when the caller sits outside any
    ``with mesh:`` block (the training loop does)."""

    def __init__(self, fn, mesh):
        self._fn = fn
        self.mesh = mesh

    def __call__(self, *args):
        with self.mesh:
            return self._fn(*args)

    def lower(self, *args):
        with self.mesh:
            return self._fn.lower(*args)


# -- train ---------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh,
                    shape: ShapeConfig, *, donate: bool = True,
                    act_policy: Optional[acts.ActPolicy] = None):
    """Build the sharded train step: ``fn(params, opt, batch) ->
    (params, opt, metrics)`` with gradient accumulation over
    ``tcfg.microbatches`` and optional bf16 gradient compression."""
    pshapes = abstract_params(cfg)
    pspecs = param_specs(mesh, pshapes)
    ospecs = opt_state_specs(mesh, pshapes, zero1=tcfg.zero1)
    bspecs = batch_specs(mesh, cfg, shape)
    pol = _policy_for(act_policy, tcfg)
    k = max(1, tcfg.microbatches)
    if shape.global_batch % k:
        raise ValueError(
            f"microbatches ({k}) must divide the global batch "
            f"({shape.global_batch})")

    def _compress(g):
        if tcfg.grad_compression == "bf16":
            return jax.tree_util.tree_map(
                lambda x: x.astype(jnp.bfloat16), g)
        return g

    def loss_fn(p, mb):
        return model_mod.train_loss(p, cfg, mb, remat=tcfg.remat)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt, batch):
        params = _constrain_tree(params, pspecs, mesh)
        opt = _constrain_tree(opt, ospecs, mesh)
        batch = _constrain_tree(batch, bspecs, mesh)
        with acts.policy(pol):
            if k == 1:
                (_, metrics), grads = grad_fn(params, batch)
                grads = jax.tree_util.tree_map(
                    lambda x: x.astype(jnp.float32), _compress(grads))
            else:
                def micro(acc, mb):
                    (_, m), g = grad_fn(params, mb)
                    acc = jax.tree_util.tree_map(
                        lambda a, x: a + x.astype(jnp.float32), acc,
                        _compress(g))
                    return acc, m

                mbatch = jax.tree_util.tree_map(
                    lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                    batch)
                acc0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                grads, metrics = jax.lax.scan(micro, acc0, mbatch)
                grads = jax.tree_util.tree_map(lambda g: g / k, grads)
                metrics = jax.tree_util.tree_map(lambda m: m.mean(), metrics)
            new_p, new_opt, opt_metrics = adamw_update(grads, opt, params,
                                                       tcfg)
        return new_p, new_opt, {**metrics, **opt_metrics}

    fn = jax.jit(
        step,
        donate_argnums=(0, 1) if donate else (),
        out_shardings=(_named(mesh, pspecs), _named(mesh, ospecs),
                       NamedSharding(mesh, P())))
    specs = {"params": pspecs, "opt": ospecs, "batch": bspecs}
    return _MeshedStep(fn, mesh), specs


# -- inference -----------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, mesh, shape: ShapeConfig, *,
                      act_policy: Optional[acts.ActPolicy] = None,
                      max_len: Optional[int] = None):
    """Build the sharded prefill: ``fn(params, batch) -> (logits, cache)``.

    The cache is sized to ``max_len`` (default: the shape's sequence
    length) so the serve step built from the same shape accepts it."""
    pshapes = abstract_params(cfg)
    pspecs = param_specs(mesh, pshapes)
    bspecs = batch_specs(mesh, cfg, shape)
    pol = _policy_for(act_policy)
    cache_len = max_len or shape.seq_len

    def step(params, batch):
        params = _constrain_tree(params, pspecs, mesh)
        with acts.policy(pol):
            return model_mod.prefill(params, cfg, batch, max_len=cache_len)

    fn = jax.jit(step)
    return _MeshedStep(fn, mesh), {"params": pspecs, "batch": bspecs}


def make_serve_step(cfg: ModelConfig, mesh, shape: ShapeConfig, *,
                    donate: bool = True,
                    act_policy: Optional[acts.ActPolicy] = None,
                    paged: bool = False, kernel_impl: str = "auto",
                    speculate_k: int = 0):
    """Build the sharded one-token decode: ``fn(params, cache, tokens) ->
    (logits, cache)`` with the cache donated (in-place KV update).

    With ``paged=True`` the step consumes a
    :class:`~repro.models.model.PagedCache` — decode computes directly
    on the page-pool layout, with the pool arrays mesh-constrained via
    :func:`paged_cache_specs` so the sharded serve step reads frames
    without a resharding collective.  ``kernel_impl`` selects the
    paged-attention backend (``auto``: the Pallas gather kernel on TPU,
    the XLA gather elsewhere).

    With ``speculate_k > 0`` (paged only) the step is the speculative
    **verify-K branch** instead: ``fn(params, cache, tokens, length) ->
    (logits, cache)`` with ``tokens`` (B, K+1) — the last committed
    token plus K drafts per slot — ``length`` (B,) the valid rows, and
    ``logits`` (B, K+1, V) scoring every draft in one jitted program
    (:func:`~repro.models.model.verify_step`).  ``cache.pos`` is NOT
    advanced; the engine decides acceptance host-side and writes the
    rewound positions back.

    Donation audit (prefix sharing): the cache is donated, so the pool
    frames update *in place* — with refcounted shared frames this is
    safe only because no live schedule ever routes a write at a frame
    with more than one mapping: decode scatters at ``pos``, which lies
    strictly past every shared (full, interned) page; empty slots write
    the trash frame; and the engine's COW guard
    (``Engine._ensure_private``) remaps before any write that would
    violate this.  Reads of a shared frame from several slots in one
    step are unordered but read-only — no aliasing hazard.  The verify
    branch widens the write window to ``[pos, pos + length)``: still
    strictly past the shared prefix (``pos`` never rewinds into it),
    and the engine extends the COW guard over the whole draft range
    before speculating (``_ensure_growth``'s draft-aware pass)."""
    pshapes = abstract_params(cfg)
    pspecs = param_specs(mesh, pshapes)
    pol = _policy_for(act_policy)
    cspecs = paged_cache_specs(mesh, cfg) if paged else None
    if speculate_k and not paged:
        raise ValueError("speculate_k requires the paged serve step")

    def _constrain_cache(cache):
        kv = dict(cache.kv)
        for name, spec in cspecs.items():
            kv[name] = jax.lax.with_sharding_constraint(
                kv[name], NamedSharding(mesh, spec))
        return cache._replace(kv=kv)

    if speculate_k:
        def step(params, cache, tokens, length):
            params = _constrain_tree(params, pspecs, mesh)
            cache = _constrain_cache(cache)
            with acts.policy(pol):
                return model_mod.verify_step(params, cfg, cache, tokens,
                                             length, impl=kernel_impl)
    else:
        def step(params, cache, tokens):
            params = _constrain_tree(params, pspecs, mesh)
            if cspecs is not None:
                cache = _constrain_cache(cache)
            with acts.policy(pol):
                return model_mod.decode_step(params, cfg, cache, tokens,
                                             impl=kernel_impl)

    fn = jax.jit(step, donate_argnums=(1,) if donate else ())
    specs = {"params": pspecs}
    if cspecs is not None:
        specs["paged_cache"] = cspecs
    return _MeshedStep(fn, mesh), specs


def make_mixed_step(cfg: ModelConfig, mesh, shape: ShapeConfig, *,
                    donate: bool = True,
                    act_policy: Optional[acts.ActPolicy] = None,
                    kernel_impl: str = "auto", speculate_k: int = 0):
    """Build the continuously-batched serve step: one decode token for
    every running slot **fused with** one paged prompt chunk for up to C
    admitting slots, in a single jitted, donated, mesh-bound program —
    ``fn(params, cache, tokens, chunk) -> (logits, chunk_logits,
    chunk_carry, cache)``.

    The decode half is exactly :func:`make_serve_step`'s paged program
    (same ``decode_step`` trace, so running slots' tokens are unchanged
    by the fusion); the chunk half is
    :func:`~repro.models.model.prefill_chunk`, which scatters the
    chunk's K/V into its page-table-mapped pool frames and attends the
    pool-resident prefix.  Fusing them is the serving-level version of
    the paper's overlap thesis: admission work rides the same step that
    keeps every running sequence's decode in flight, so a new request
    never serialises a dense-prefill bubble in front of running decodes.

    The cache is donated (pool frames update in place); the paged-cache
    pool arrays are mesh-constrained via :func:`paged_cache_specs`, and
    the chunk's control state (tokens, offsets, page rows) is replicated
    like the page table — tiny int32 state every shard needs whole, the
    APR analogue.  ``chunk`` layouts are documented on
    :func:`~repro.models.model.prefill_chunk`; jit re-specialises per
    (chunk rows, chunk length) shape, which the engine keeps to a small
    fixed set.

    Donation audit (prefix sharing): chunk rows may point at shared
    (prefix-cache) frames for the resident prefix — those are gathered
    read-only; the chunk's own K/V scatter lands at
    ``[offset, offset + length)``, which starts past the shared pages
    by construction (``prefill_pos`` skips them), so the in-place
    update never writes a multi-mapped frame.  See
    :func:`make_serve_step` for the decode half of the audit.

    With ``speculate_k > 0`` the decode half becomes the speculative
    verify-K branch (``fn(params, cache, tokens, length, chunk)`` with
    ``tokens`` (B, K+1), ``logits`` (B, K+1, V), positions host-owned —
    see :func:`make_serve_step`); the chunk half is byte-identical to
    the non-speculative program, so admitting slots' graduation logits
    are unchanged by the fusion either way.
    """
    pshapes = abstract_params(cfg)
    pspecs = param_specs(mesh, pshapes)
    pol = _policy_for(act_policy)
    cspecs = paged_cache_specs(mesh, cfg)

    def _constrain(params, cache, chunk):
        params = _constrain_tree(params, pspecs, mesh)
        kv = dict(cache.kv)
        for name, spec in cspecs.items():
            kv[name] = jax.lax.with_sharding_constraint(
                kv[name], NamedSharding(mesh, spec))
        cache = cache._replace(kv=kv)
        chunk = jax.tree_util.tree_map(
            lambda x: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P())), chunk)
        return params, cache, chunk

    if speculate_k:
        def step(params, cache, tokens, length, chunk):
            params, cache, chunk = _constrain(params, cache, chunk)
            with acts.policy(pol):
                logits, cache = model_mod.verify_step(
                    params, cfg, cache, tokens, length, impl=kernel_impl)
                chunk_logits, cache, carry = model_mod.prefill_chunk(
                    params, cfg, cache, chunk, impl=kernel_impl)
            return logits, chunk_logits, carry, cache
    else:
        def step(params, cache, tokens, chunk):
            params, cache, chunk = _constrain(params, cache, chunk)
            with acts.policy(pol):
                logits, cache = model_mod.decode_step(
                    params, cfg, cache, tokens, impl=kernel_impl)
                chunk_logits, cache, carry = model_mod.prefill_chunk(
                    params, cfg, cache, chunk, impl=kernel_impl)
            return logits, chunk_logits, carry, cache

    fn = jax.jit(step, donate_argnums=(1,) if donate else ())
    return _MeshedStep(fn, mesh), {"params": pspecs, "paged_cache": cspecs}

"""Scoped activation-sharding / precision policy.

A policy is a small frozen value object; the *active* policy is a
dynamically-scoped stack entry (``with policy(OPTIMIZED): ...``) read by
the model layers at trace time.  Two named instances:

  * :data:`BASELINE`  — paper-faithful run: f32 einsum operands, no
    explicit activation layouts (GSPMD decides everything from the
    parameter shardings),
  * :data:`OPTIMIZED` — the beyond-paper perf path: operands stay in the
    native compute dtype (f32 accumulation), attention layouts are
    constrained explicitly (heads- or query-seq-sharded over ``model``),
    the residual stream is Megatron-SP sequence-sharded between layers,
    and SSM kernels use the factorized chunk form with head sharding.

Everything degrades to a no-op when no mesh is active or when a shape
does not divide the mesh axis — single-device tests exercise the exact
same code path as the 512-way dry-run.

Layout selection for attention (:func:`attn_plan`):

  ``("heads", ax)``  H % ax_size == 0 — shard heads; K/V are repeated to
                     full H locally so no collective appears inside the
                     KV-chunk scan (the AMU rule: keep the stream loop
                     free of synchronisation);
  ``("seq", ax)``    otherwise, if Sq % ax_size == 0 — shard the query
                     sequence (also forced while the residual stream is
                     seq-sharded, so attention consumes the layout the
                     residual already has);
  ``None``           nothing fits — leave the layout to GSPMD.
"""

from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "ActPolicy", "BASELINE", "OPTIMIZED", "policy", "current",
    "residual_layout", "residual_spec", "attn_plan", "constrain",
    "dp_spec_prefix", "model_axis_size",
]


@dataclass(frozen=True)
class ActPolicy:
    """Activation sharding/precision knobs (value object, hash/eq by value)."""

    native_dtype: bool = False     # einsum operands in compute dtype (f32 acc)
    attn_explicit: bool = False    # constrain attention layouts explicitly
    seq_residual: bool = False     # Megatron-SP residual stream over model
    ssm_factorized: bool = False   # factorized chunk form in wkv6/ssd
    ssm_head_shard: bool = False   # constrain SSM head dims over model
    model_axis: str = "model"      # mesh axis carrying intra-layer sharding


BASELINE = ActPolicy()
OPTIMIZED = ActPolicy(native_dtype=True, attn_explicit=True,
                      seq_residual=True, ssm_factorized=True,
                      ssm_head_shard=True)

_policy_stack: List[ActPolicy] = []
_residual_stack: List[bool] = []


def current() -> ActPolicy:
    """The innermost active policy (``BASELINE`` outside any context)."""
    return _policy_stack[-1] if _policy_stack else BASELINE


@contextmanager
def policy(pol: ActPolicy):
    """Scope ``pol`` as the active policy (re-entrant, nestable)."""
    _policy_stack.append(pol)
    try:
        yield pol
    finally:
        _policy_stack.pop()


@contextmanager
def residual_layout(seq_sharded: bool):
    """Layer-scoped signal: the residual stream entering attention is
    sequence-sharded, so :func:`attn_plan` must pick the seq plan even
    when the head count divides the mesh axis."""
    _residual_stack.append(bool(seq_sharded))
    try:
        yield
    finally:
        _residual_stack.pop()


def _residual_is_seq() -> bool:
    return _residual_stack[-1] if _residual_stack else False


# -- mesh introspection (module-level so tests can monkeypatch) -----------------

def _current_mesh():
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:  # pragma: no cover - jax internals moved
        pass
    return None


def _mesh_axis_sizes() -> Dict[str, int]:
    """Axis name -> size of the active mesh ({} when single-device)."""
    m = _current_mesh()
    return dict(m.shape) if m is not None else {}


def model_axis_size() -> int:
    """Size of the active policy's model axis on the current mesh (1
    when no mesh is active or the axis is absent)."""
    return _mesh_axis_sizes().get(current().model_axis, 1)


def dp_spec_prefix():
    """Spec entry for the batch dim: data-parallel axes of the active mesh.

    Returns a single axis name, a tuple of axis names (multipod), or
    ``None`` when no data-parallel axis exists.
    """
    sizes = _mesh_axis_sizes()
    axes = tuple(a for a in ("pod", "data") if sizes.get(a, 1) > 1)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


# -- layout decisions -----------------------------------------------------------

def attn_plan(num_heads: int, num_kv_heads: int, seq_len: int
              ) -> Optional[Tuple[str, str]]:
    """Pick the attention layout under the active policy.

    Returns ``("heads", axis)``, ``("seq", axis)`` or ``None`` (leave it
    to GSPMD).  ``num_kv_heads`` is carried for future plans that shard
    the KV heads instead of repeating them.
    """
    pol = current()
    if not pol.attn_explicit:
        return None
    m = _mesh_axis_sizes().get(pol.model_axis, 1)
    if m <= 1:
        return None
    if _residual_is_seq():
        # the residual stream is already seq-sharded: attention must
        # consume that layout or pay a reshard on every layer boundary
        return ("seq", pol.model_axis) if seq_len % m == 0 else None
    if num_heads % m == 0:
        return ("heads", pol.model_axis)
    if seq_len % m == 0:
        return ("seq", pol.model_axis)
    return None


def residual_spec(seq_len: int, *, gather: bool = False):
    """PartitionSpec for the (B, S, d) residual stream between layers.

    ``None`` unless the active policy seq-shards the residual AND the
    sequence divides the model axis.  ``gather=True`` returns the spec
    that collects the sequence back to full (MoE layers need the whole
    sequence per row for sort-based dispatch).
    """
    pol = current()
    if not pol.seq_residual:
        return None
    m = _mesh_axis_sizes().get(pol.model_axis, 1)
    if m <= 1 or seq_len % m != 0:
        return None
    dp = dp_spec_prefix()
    if gather:
        return P(dp, None, None)
    return P(dp, pol.model_axis, None)


def constrain(x, spec):
    """``with_sharding_constraint`` under the active mesh; no-op when the
    spec is ``None`` or no mesh is active (single-device tests)."""
    if spec is None:
        return x
    mesh = _current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

"""PartitionSpec assignment for parameter / optimizer / batch pytrees.

Sharding vocabulary over the ``repro.launch.mesh`` axes:

  * **TP** (``model`` axis) — Megatron-style intra-layer parallelism:
    column-parallel input projections (q/k/v, gate/up) shard their output
    dim; row-parallel output projections (o, down, out_proj, Wo, cWv)
    shard their input dim; embedding/unembedding tables shard the vocab
    dim,
  * **EP** (``model`` axis) — stacked expert weights (E, d, ff) shard the
    expert dim; MoE dispatch stays per-sequence so the only collective is
    the combine all-reduce,
  * **DP / ZeRO-1** (``pod`` + ``data`` axes) — the batch shards over the
    data axes; optimizer moments additionally shard their first
    evenly-divisible unsharded dim over the data axes (reduce-scatter +
    all-gather around the update, a la ZeRO stage 1).

Every rule checks divisibility and falls back to replication, so the
same code serves the (2, 4) CPU test mesh, the (16, 16) pod and the
(2, 16, 16) multipod without special cases.  Specs are always
``PartitionSpec`` instances (never ``None``) so spec trees stay
structure-compatible with parameter trees under ``tree_map``.

``mesh`` arguments accept a ``jax.sharding.Mesh`` or a plain
``{axis: size}`` mapping (handy for single-process unit tests).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Mapping, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.optim.adamw import OptState

__all__ = [
    "MODEL_AXIS", "DATA_AXES", "param_specs", "opt_state_specs",
    "batch_specs", "data_axes_of",
]

MODEL_AXIS = "model"
DATA_AXES = ("pod", "data")

#: projections whose *input* dim is model-sharded (their producer is
#: column-parallel, so row-parallel here elides one all-gather)
_ROW_PARALLEL = frozenset({"o", "down", "out_proj", "Wo", "cWv"})

#: raw stacked expert tensors (E, d, ff) / (E, ff, d) — expert dim at -3
_EXPERT_STACKED = frozenset({"gate", "up", "down"})

MeshLike = Union[Mesh, Mapping[str, int]]


def _axis_sizes(mesh: MeshLike) -> Dict[str, int]:
    if mesh is None:
        return {}
    if isinstance(mesh, Mapping):
        return dict(mesh)
    return dict(mesh.shape)


def data_axes_of(mesh: MeshLike) -> Tuple[str, ...]:
    """Data-parallel axes present (size > 1) on this mesh, outer first."""
    sizes = _axis_sizes(mesh)
    return tuple(a for a in DATA_AXES if sizes.get(a, 1) > 1)


def _dp_entry(dp_axes: Sequence[str]):
    return tuple(dp_axes) if len(dp_axes) > 1 else dp_axes[0]


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:  # pragma: no cover - unknown key type
            out.append(str(k))
    return tuple(out)


def _param_spec(names: Tuple[str, ...], shape: Tuple[int, ...],
                sizes: Mapping[str, int], model_axis: str) -> P:
    m = sizes.get(model_axis, 1)
    rank = len(shape)
    spec = [None] * rank

    def fits(dim: int) -> bool:
        return m > 1 and shape[dim] > 0 and shape[dim] % m == 0

    last = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""

    if last == "table" and rank >= 2:
        if fits(rank - 2):                       # vocab dim of (V, d)
            spec[rank - 2] = model_axis
    elif last == "w" and rank >= 2:
        if parent in _ROW_PARALLEL:
            if fits(rank - 2):
                spec[rank - 2] = model_axis
        elif fits(rank - 1):                     # column-parallel default
            spec[rank - 1] = model_axis
    elif last in _EXPERT_STACKED and rank >= 3:  # raw (…, E, d, ff) stacks
        if fits(rank - 3):
            spec[rank - 3] = model_axis
    return P(*spec)


def param_specs(mesh: MeshLike, pshapes: Any, *,
                model_axis: str = MODEL_AXIS) -> Any:
    """PartitionSpec tree (same structure as ``pshapes``) for parameters.

    ``pshapes`` is any pytree whose leaves have ``.shape`` — typically
    ``repro.dist.steps.abstract_params(cfg)``.
    """
    sizes = _axis_sizes(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_spec(_path_names(path), tuple(leaf.shape),
                                       sizes, model_axis),
        pshapes)


def _zero_spec(spec: P, shape: Tuple[int, ...], dp_axes: Tuple[str, ...],
               dp: int) -> P:
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, dim in enumerate(shape):
        if parts[i] is None and dim > 0 and dim % dp == 0:
            parts[i] = _dp_entry(dp_axes)
            break
    return P(*parts)


def opt_state_specs(mesh: MeshLike, pshapes: Any, *, zero1: bool = True,
                    model_axis: str = MODEL_AXIS) -> OptState:
    """Specs for :class:`repro.optim.adamw.OptState` over ``pshapes``.

    Moments inherit the parameter TP layout; with ``zero1`` they
    additionally shard their first evenly-divisible unsharded dim over
    the data axes.  ``step`` is a replicated scalar.
    """
    pspecs = param_specs(mesh, pshapes, model_axis=model_axis)
    dp_axes = data_axes_of(mesh)
    if zero1 and dp_axes:
        dp = math.prod(_axis_sizes(mesh)[a] for a in dp_axes)
        mspecs = jax.tree_util.tree_map(
            lambda leaf, spec: _zero_spec(spec, tuple(leaf.shape),
                                          dp_axes, dp),
            pshapes, pspecs)
    else:
        mspecs = pspecs
    return OptState(m=mspecs, v=mspecs, step=P())


def batch_specs(mesh: MeshLike, cfg: ModelConfig,
                shape: ShapeConfig) -> Dict[str, P]:
    """Specs for the input batch: (B, S) token/label grids DP-sharded
    over the data axes (replicated if B does not divide them)."""
    sizes = _axis_sizes(mesh)
    dp_axes = data_axes_of(mesh)
    dp = math.prod(sizes[a] for a in dp_axes) if dp_axes else 1
    b_entry = (_dp_entry(dp_axes)
               if dp_axes and shape.global_batch % dp == 0 else None)
    specs = {"tokens": P(b_entry, None), "labels": P(b_entry, None)}
    if cfg.family == "encdec":
        specs["src_embeds"] = P(b_entry, None, None)
    return specs

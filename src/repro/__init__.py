"""repro: AMU (async memory unit) training/serving framework in JAX."""

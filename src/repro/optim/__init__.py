"""repro.optim"""

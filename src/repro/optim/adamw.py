"""AdamW with global-norm clipping and warmup+cosine schedule.

Pure-pytree implementation (no optax in this environment).  The moment
tensors are stored fp32 and are ZeRO-1 shardable: ``dist/zero.py`` assigns
them shardings over the ``data`` axis, and GSPMD turns the update into
reduce-scatter + all-gather around this arithmetic.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

__all__ = ["OptState", "adamw_init", "adamw_update", "lr_schedule",
           "global_norm"]


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jnp.ndarray


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def lr_schedule(cfg: TrainConfig) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = cfg.lr * (step + 1.0) / max(1, cfg.warmup_steps)
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
        cos = 0.5 * cfg.lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < cfg.warmup_steps, warm, cos)
    return fn


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(
    grads, opt_state: OptState, params, cfg: TrainConfig,
) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    step = opt_state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.ones(())
    lr = lr_schedule(cfg)(step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + 1e-8) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state.m)
    flat_v = treedef.flatten_up_to(opt_state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(m=new_m, v=new_v, step=step), metrics

"""Chunked Mamba2 SSD Pallas kernel.

Same streaming structure as the WKV6 kernel, but the decay is a *scalar
per head per step* (Mamba2's restriction — what makes SSD hardware
friendly): the intra-chunk pairwise decay is a (c, c) matrix instead of
(c, c, K), so the whole chunk update is three small matmuls — ideal MXU
shape.  State (N x P per head) is the resident SPM working set carried
across the sequential chunk grid dimension.

Per head h:
  S_t = e^{-A_h dt_t} S_{t-1} + dt_t B_t x_t^T     (S: N x P)
  y_t = C_t^T S_t + D_h x_t
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd"]


def _ssd_kernel(x_ref, dt_ref, B_ref, C_ref, A_ref, D_ref, o_ref, S, *,
                c: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _():
        S[...] = jnp.zeros_like(S)

    x = x_ref[0, 0].astype(jnp.float32)        # (c, P)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (c, 1) -> (c,)
    Bm = B_ref[0].astype(jnp.float32)          # (c, N)
    Cm = C_ref[0].astype(jnp.float32)          # (c, N)
    A = A_ref[0, 0].astype(jnp.float32)        # scalar (1, 1)
    Dh = D_ref[0, 0].astype(jnp.float32)

    dA = -A * dt                               # (c, 1) log decay <= 0
    L = jnp.cumsum(dA, axis=0)                 # inclusive (c, 1)

    # inter-chunk: y_t += e^{L_t} C_t @ S_in
    y = jnp.exp(L) * jax.lax.dot(Cm, S[...])   # (c, P)

    # intra-chunk: G[t,s] = e^{L_t - L_s} dt_s (C_t . B_s)  (s <= t)
    pair = L - L.T                             # (c, c) L_t - L_s
    tri = (jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (c, c), 1))
    G = jnp.exp(jnp.minimum(pair, 0.0)) * tri
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # (c, c)
    M = CB * G * dt.T                          # dt_s broadcast over rows
    y = y + jax.lax.dot(M, x)

    # skip connection
    y = y + Dh * x

    # state update: S_out = e^{L_last} S + sum_s e^{L_last - L_s} dt_s B_s x_s^T
    Ll = L[-1:, :]                             # (1, 1)
    kdec = Bm * (jnp.exp(Ll - L) * dt)         # (c, N)
    S[...] = jnp.exp(Ll) * S[...] + jax.lax.dot_general(
        kdec, x, (((0,), (0,)), ((), ())))     # (N, P)

    o_ref[0, 0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(
    x: jnp.ndarray,            # (B, T, H, P)
    dt: jnp.ndarray,           # (B, T, H)  (post-softplus)
    A: jnp.ndarray,            # (H,)
    Bm: jnp.ndarray,           # (B, T, N)
    Cm: jnp.ndarray,           # (B, T, N)
    D: jnp.ndarray,            # (H,)
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, T, H, P = x.shape
    N = Bm.shape[-1]
    c = min(chunk, T)
    assert T % c == 0, (T, c)

    xT = x.transpose(0, 2, 1, 3)               # (B, H, T, P)
    dtT = dt.transpose(0, 2, 1)[..., None]     # (B, H, T, 1)
    A2 = A.reshape(H, 1, 1)
    D2 = D.reshape(H, 1, 1)

    kernel = functools.partial(_ssd_kernel, c=c)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, T // c),
        in_specs=[
            pl.BlockSpec((1, 1, c, P), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, c, 1), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, c, N), lambda b, h, j: (b, j, 0)),
            pl.BlockSpec((1, c, N), lambda b, h, j: (b, j, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, h, j: (h, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, h, j: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, c, P), lambda b, h, j: (b, h, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xT, dtT, Bm, Cm, A2, D2)
    return out.transpose(0, 2, 1, 3)

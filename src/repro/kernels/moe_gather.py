"""Indexed row gather — the AMU *gather pattern* at kernel level.

MoE dispatch (and paged-KV fetch) reduce to: out[i] = src[idx[i]] for a
dynamic index vector.  This kernel uses ``PrefetchScalarGridSpec`` so the
index vector is prefetched into SMEM *before* the grid runs — the Pallas
analogue of the paper's Access Pattern Register: the pattern (the
indices) is programmed into the unit first, then the unit streams the
granules.  Each grid step copies one ``rows_per_block`` granule whose
source rows are resolved from the prefetched indices via the BlockSpec
index map (for block-aligned gathers) or a manual DMA per row (general
case, ``gather_rows``).

``granularity``: rows per DMA — the paper's variable-granularity knob.
Coalescing for semi-sorted indices happens upstream in
``repro.core.patterns.GatherPattern``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gather_rows", "gather_blocks"]


def _gather_rows_kernel(idx_ref, src_hbm, o_ref, row_buf, sem, *,
                        rows_per_block: int):
    """General gather: one manual DMA per row (aload), landing in the
    output VMEM block (SPM), paced by a single semaphore (getfin)."""
    i = pl.program_id(0)

    def body(r, _):
        src_row = idx_ref[i * rows_per_block + r]
        copy = pltpu.make_async_copy(
            src_hbm.at[pl.ds(src_row, 1), :], row_buf, sem)
        copy.start()
        copy.wait()
        o_ref[pl.ds(r, 1), :] = row_buf[...]
        return ()

    jax.lax.fori_loop(0, rows_per_block, body, ())


@functools.partial(jax.jit, static_argnames=("rows_per_block", "interpret"))
def gather_rows(
    src: jnp.ndarray,          # (N, d)
    idx: jnp.ndarray,          # (M,) int32
    *,
    rows_per_block: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    N, d = src.shape
    M = idx.shape[0]
    assert M % rows_per_block == 0, (M, rows_per_block)
    kernel = functools.partial(_gather_rows_kernel,
                               rows_per_block=rows_per_block)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M // rows_per_block,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((rows_per_block, d), lambda i, idx_ref: (i, 0)),
        scratch_shapes=[pltpu.VMEM((1, d), src.dtype),
                        pltpu.SemaphoreType.DMA],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, d), src.dtype),
        interpret=interpret,
    )(idx, src)


def _gather_blocks_kernel(idx_ref, src_ref, o_ref):
    # src block already resolved by the index map from prefetched indices
    o_ref[...] = src_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def gather_blocks(
    src: jnp.ndarray,          # (N, d): N = nblocks * block_rows
    block_idx: jnp.ndarray,    # (Mb,) int32 — indices of row-blocks
    *,
    block_rows: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    """Block-aligned gather: the index map itself reads the prefetched
    scalar indices, so the compiler pipelines the DMAs (large-granularity
    fast path — one aload per block)."""
    N, d = src.shape
    Mb = block_idx.shape[0]
    assert N % block_rows == 0
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Mb,),
        in_specs=[pl.BlockSpec((block_rows, d),
                               lambda i, idx_ref: (idx_ref[i], 0))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_blocks_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Mb * block_rows, d), src.dtype),
        interpret=interpret,
    )(block_idx, src)

"""Pallas TPU kernels (+ pure-jnp oracles and dispatch wrappers).

Kernels (each: <name>.py with pl.pallas_call + BlockSpec, ref.py oracle,
ops.py jit'd wrapper):
  * amu_matmul       — manual double-buffered DMA matmul (aload/getfin/SPM)
  * flash_attention  — streaming attention (causal/SWA/GQA)
  * decode_attention — one-token attention vs long KV cache (paged stream),
                       plus the gather-by-page-table variant over the
                       repro.paging pool layout (scalar-prefetch frame ids)
  * rwkv6            — chunked WKV6, state-resident linear recurrence
  * mamba2           — chunked SSD (scalar per-head decay)
  * moe_gather       — scalar-prefetch indexed gather (AMU gather pattern)
"""

from repro.kernels import ops, ref
from repro.kernels.ops import (matmul, flash_attention, decode_attention,
                               paged_decode_attention, wkv6, ssd,
                               gather_rows)

__all__ = ["ops", "ref", "matmul", "flash_attention", "decode_attention",
           "paged_decode_attention", "wkv6", "ssd", "gather_rows"]

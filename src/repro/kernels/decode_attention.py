"""Decode attention: one query token vs a long KV cache (paged layout).

The decode path is the purest far-memory case in the paper's sense: the
KV cache is huge (up to 500k tokens), cold, and read-once per step —
exactly the access profile the AMU targets.  The cache stays in HBM and
pages of ``bkv`` positions stream through VMEM (compiler-pipelined);
online softmax state is carried in scratch across the sequential page
grid dimension, so the kernel is O(1) in VMEM regardless of context
length.

Layout: q (B, H, D); k/v (B, Skv, Hkv, D); valid_len masks the tail.
GQA is handled by computing all G = H/Hkv query heads of one KV head
together: q is pre-reshaped to (B, Hkv, G, D) and a (G, bkv) score tile
is produced per page — G is a free MXU dim, so grouped queries ride
along for free (the variable-granularity argument: one aload of a KV
page serves G consumers).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention", "paged_decode_attention",
           "paged_verify_attention", "default_interpret"]

NEG_INF = -1e30
_LANE = 128


def default_interpret() -> bool:
    """Backend auto-detection for the ``interpret`` flag.

    Mosaic can only compile Pallas kernels for TPU; every other backend
    (CPU containers, the tier-1 suite) must run the kernel body in
    interpreter mode.  Defaulting to a *hard-coded* ``True`` silently
    forced interpreter mode on TPU too — interpret only when no
    compiled-kernel backend is available.
    """
    return jax.default_backend() != "tpu"


def _decode_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
                   scale: float, bkv: int, valid_len: int, G: int):
    j = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(j == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    first_kv = j * bkv

    @pl.when(first_kv < valid_len)
    def _():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)                # (bkv, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, bkv)
        kv_pos = first_kv + jax.lax.broadcasted_iota(jnp.int32, (G, bkv), 1)
        s = jnp.where(kv_pos < valid_len, s, NEG_INF)
        m_prev = m_s[:, :1]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_s[:, :1] = l_s[:, :1] * corr + p.sum(-1, keepdims=True)
        m_s[:, :1] = m_new
        v = v_ref[0, 0].astype(jnp.float32)                # (bkv, D)
        acc[...] = acc[...] * corr + jax.lax.dot(p, v)

    @pl.when(j == n_kv - 1)
    def _():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_s[:, :1], 1e-30)) \
            .astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("valid_len", "bkv", "interpret"))
def decode_attention(
    q: jnp.ndarray,            # (B, H, D)
    k: jnp.ndarray,            # (B, Skv, Hkv, D)
    v: jnp.ndarray,
    *,
    valid_len: Optional[int] = None,
    bkv: int = 256,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    if interpret is None:                  # auto: compiled on TPU only
        interpret = default_interpret()
    B, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = H // Hkv
    valid_len = Skv if valid_len is None else valid_len
    bkv = min(bkv, Skv)
    assert Skv % bkv == 0

    qg = q.reshape(B, Hkv, G, D)
    kT = k.transpose(0, 2, 1, 3)     # (B, Hkv, Skv, D)
    vT = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_decode_kernel, scale=1.0 / math.sqrt(D),
                               bkv=bkv, valid_len=valid_len, G=G)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, Skv // bkv),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, _LANE), jnp.float32),
            pltpu.VMEM((G, _LANE), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kT, vT)
    return out.reshape(B, H, D)


# ---------------------------------------------------------------------------
# paged variant: gather-by-page-table (repro.paging pool layout)
# ---------------------------------------------------------------------------


def _paged_decode_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         acc, m_s, l_s, *, scale: float, page: int, G: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    n_pg = pl.num_programs(2)

    @pl.when(j == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    valid_len = len_ref[b]
    first_kv = j * page

    @pl.when(first_kv < valid_len)
    def _():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)             # (page, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, page)
        kv_pos = first_kv + jax.lax.broadcasted_iota(jnp.int32, (G, page), 1)
        s = jnp.where(kv_pos < valid_len, s, NEG_INF)
        m_prev = m_s[:, :1]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_s[:, :1] = l_s[:, :1] * corr + p.sum(-1, keepdims=True)
        m_s[:, :1] = m_new
        v = v_ref[0, :, 0].astype(jnp.float32)             # (page, D)
        acc[...] = acc[...] * corr + jax.lax.dot(p, v)

    @pl.when(j == n_pg - 1)
    def _():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_s[:, :1], 1e-30)) \
            .astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(
    q: jnp.ndarray,            # (B, H, D)
    k_pages: jnp.ndarray,      # (N, page, Hkv, D) — the device page pool
    v_pages: jnp.ndarray,      # (N, page, Hkv, D)
    page_table: jnp.ndarray,   # (B, pages_per_seq) int32 physical frame ids
    lengths: jnp.ndarray,      # (B,) int32 valid KV length per sequence
    *,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Decode attention reading the paged KV layout directly.

    Instead of a dense per-slot (B, Skv, Hkv, D) cache, k/v live in the
    ``repro.paging`` pool layout — a flat array of page frames — and
    ``page_table`` holds each sequence's logical→physical frame map.
    The page-table row is a *scalar-prefetch* operand: the k/v index
    maps dereference it to pick which frame each grid step streams
    through VMEM, so the gather rides the compiler-pipelined DMA for
    free (the AMU gather pattern at page granularity — same scheme as
    ``moe_gather.gather_blocks``).  Entries past a sequence's last page
    must still hold in-bounds frame ids (0 is fine): their tiles are
    skipped by the per-sequence ``lengths`` mask but may be prefetched.

    Per-sequence ``lengths`` (unlike the dense kernel's static
    ``valid_len``) make one call serve the engine's mixed-depth batch.
    """
    if interpret is None:                  # auto: compiled on TPU only
        interpret = default_interpret()
    B, H, D = q.shape
    N, page, Hkv, _ = k_pages.shape
    G = H // Hkv
    pages_per_seq = page_table.shape[1]

    qg = q.reshape(B, Hkv, G, D)

    kernel = functools.partial(_paged_decode_kernel,
                               scale=1.0 / math.sqrt(D), page=page, G=G)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, pages_per_seq),
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, j, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, j, pt, ln: (pt[b, j], 0, h, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, j, pt, ln: (pt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, j, pt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, _LANE), jnp.float32),
            pltpu.VMEM((G, _LANE), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32), qg,
      k_pages, v_pages)
    return out.reshape(B, H, D)


# ---------------------------------------------------------------------------
# multi-query paged variant: S query rows per sequence (speculative verify)
# ---------------------------------------------------------------------------


def _paged_verify_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         acc, m_s, l_s, *, scale: float, page: int,
                         G: int, S: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    n_pg = pl.num_programs(2)

    @pl.when(j == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    # per-row valid lengths: verify row s sees s more KV positions than
    # row 0 (its own token plus every draft before it).  S is small and
    # static, so the SMEM reads unroll at trace time.
    vals = [len_ref[b, s] for s in range(S)]
    valid_max = vals[0]
    for vl in vals[1:]:
        valid_max = jnp.maximum(valid_max, vl)
    valid_rows = jnp.broadcast_to(jnp.stack(vals)[:, None],
                                  (S, G)).reshape(S * G, 1)
    first_kv = j * page

    @pl.when(first_kv < valid_max)
    def _():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (S*G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)             # (page, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (S*G, page)
        kv_pos = first_kv + jax.lax.broadcasted_iota(
            jnp.int32, (S * G, page), 1)
        s = jnp.where(kv_pos < valid_rows, s, NEG_INF)
        m_prev = m_s[:, :1]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_s[:, :1] = l_s[:, :1] * corr + p.sum(-1, keepdims=True)
        m_s[:, :1] = m_new
        v = v_ref[0, :, 0].astype(jnp.float32)             # (page, D)
        acc[...] = acc[...] * corr + jax.lax.dot(p, v)

    @pl.when(j == n_pg - 1)
    def _():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_s[:, :1], 1e-30)) \
            .astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_verify_attention(
    q: jnp.ndarray,            # (B, S, H, D) — S = K + 1 verify rows
    k_pages: jnp.ndarray,      # (N, page, Hkv, D) — the device page pool
    v_pages: jnp.ndarray,      # (N, page, Hkv, D)
    page_table: jnp.ndarray,   # (B, pages_per_seq) int32 physical frame ids
    lengths: jnp.ndarray,      # (B, S) int32 per-row valid KV length
    *,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Multi-query paged decode attention for speculative verify-K.

    :func:`paged_decode_attention` with ``S`` query rows per sequence
    sharing one pass over the sequence's pages: the score tile grows
    from (G, page) to (S*G, page) — like GQA's G, the extra verify rows
    are a free MXU dim, so one aload of a KV page serves S*G consumers
    instead of G.  This is the kernel-level payoff of self-speculative
    decode: the page-fetch traffic of ONE decode step verifies K+1
    tokens (the paper's amortise-per-access-overhead lever).

    ``lengths[b, s]`` masks row ``s`` independently (row s's causal view
    includes the draft rows before it).  A fully-masked row
    (``lengths[b, s] == 0``) returns zeros here; the XLA reference path
    returns the uniform value average instead — callers only consume
    rows with ``lengths >= 1``, where the two agree.
    """
    if interpret is None:                  # auto: compiled on TPU only
        interpret = default_interpret()
    B, S, H, D = q.shape
    N, page, Hkv, _ = k_pages.shape
    G = H // Hkv
    pages_per_seq = page_table.shape[1]

    # (B, S, Hkv, G, D) -> (B, Hkv, S*G, D): rows of one KV head stay
    # contiguous so the kernel's (S*G, page) tile covers all verify rows
    qg = q.reshape(B, S, Hkv, G, D).transpose(0, 2, 1, 3, 4) \
         .reshape(B, Hkv, S * G, D)

    kernel = functools.partial(_paged_verify_kernel,
                               scale=1.0 / math.sqrt(D), page=page,
                               G=G, S=S)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, pages_per_seq),
        in_specs=[
            pl.BlockSpec((1, 1, S * G, D),
                         lambda b, h, j, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, j, pt, ln: (pt[b, j], 0, h, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, j, pt, ln: (pt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, S * G, D),
                               lambda b, h, j, pt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((S * G, D), jnp.float32),
            pltpu.VMEM((S * G, _LANE), jnp.float32),
            pltpu.VMEM((S * G, _LANE), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, S * G, D), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32), qg,
      k_pages, v_pages)
    return out.reshape(B, Hkv, S, G, D).transpose(0, 2, 1, 3, 4) \
              .reshape(B, S, H, D)

"""Dispatch wrappers: one public op per kernel, backend-selected.

``impl``:
  * ``"pallas"``    — compiled Pallas kernel (TPU target),
  * ``"interpret"`` — Pallas kernel body interpreted on CPU (correctness),
  * ``"xla"``       — the pure-jnp path (oracle-equivalent, what the
    dry-run lowers, since Mosaic cannot target the CPU backend),
  * ``"auto"``      — pallas on TPU, xla elsewhere.

Model code calls these wrappers; tests sweep ``interpret`` vs ``xla``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.amu_matmul import amu_matmul as _amu_matmul
from repro.kernels.decode_attention import decode_attention as _decode_attn
from repro.kernels.decode_attention import default_interpret
from repro.kernels.decode_attention import \
    paged_decode_attention as _paged_decode_attn
from repro.kernels.decode_attention import \
    paged_verify_attention as _paged_verify_attn
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.flash_attention import \
    paged_prefill_flash as _paged_prefill_flash
from repro.kernels.mamba2 import ssd as _ssd
from repro.kernels.moe_gather import gather_rows as _gather_rows
from repro.kernels.rwkv6 import wkv6 as _wkv6

__all__ = ["matmul", "flash_attention", "decode_attention",
           "paged_decode_attention", "paged_verify_attention",
           "paged_prefill_attention", "wkv6",
           "ssd", "gather_rows", "on_tpu", "resolve_impl",
           "default_interpret"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_impl(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if on_tpu() else "xla"


def matmul(x, w, *, impl: str = "auto", **block_kw):
    impl = resolve_impl(impl)
    if impl == "xla":
        return _ref.matmul_ref(x, w)
    return _amu_matmul(x, w, interpret=(impl == "interpret"), **block_kw)


def flash_attention(q, k, v, *, causal=True, window=0, impl: str = "auto",
                    **kw):
    """q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D) — model layout."""
    impl = resolve_impl(impl)
    if impl == "xla":
        from repro.models.attention import chunked_attention
        return chunked_attention(q, k, v, causal=causal, window=window)
    qT, kT, vT = (a.transpose(0, 2, 1, 3) for a in (q, k, v))
    out = _flash(qT, kT, vT, causal=causal, window=window,
                 interpret=(impl == "interpret"), **kw)
    return out.transpose(0, 2, 1, 3)


def decode_attention(q, k, v, *, valid_len=None, impl: str = "auto", **kw):
    impl = resolve_impl(impl)
    if impl == "xla":
        vl = q.shape[0] if valid_len is None else valid_len
        return _ref.decode_attention_ref(q, k, v, k.shape[1]
                                         if valid_len is None else valid_len)
    return _decode_attn(q, k, v, valid_len=valid_len,
                        interpret=(impl == "interpret"), **kw)


def paged_decode_attention(q, k_pages, v_pages, page_table, lengths, *,
                           impl: str = "auto", **kw):
    """q: (B, H, D); k/v_pages: (N, page, Hkv, D) pool layout;
    page_table: (B, pages_per_seq) frame ids; lengths: (B,) valid KV."""
    impl = resolve_impl(impl)
    if impl == "xla":
        # gather the dense view and defer to the shared one-token
        # reference (the dense decode block's exact expressions) so the
        # paged and dense decode paths stay bit-exact
        from repro.models.attention import one_token_attention
        B, H, D = q.shape
        _, page, Hkv, _ = k_pages.shape
        k = jnp.take(k_pages, page_table, axis=0)         # (B, pps, page, ...)
        v = jnp.take(v_pages, page_table, axis=0)
        Skv = k.shape[1] * page
        k = k.reshape(B, Skv, Hkv, D)
        v = v.reshape(B, Skv, Hkv, D)
        out = one_token_attention(q, k, v, lengths, Hkv)
        return out.reshape(B, H, D).astype(q.dtype)
    return _paged_decode_attn(q, k_pages, v_pages, page_table, lengths,
                              interpret=(impl == "interpret"), **kw)


def paged_verify_attention(q, k_pages, v_pages, page_table, lengths, *,
                           impl: str = "auto", **kw):
    """q: (B, S, H, D) — S = K + 1 speculative verify rows; k/v_pages:
    (N, page, Hkv, D) pool layout; page_table: (B, pages_per_seq) frame
    ids; lengths: (B, S) per-row valid KV.

    The XLA path gathers the dense view once and defers to the shared
    ``multi_token_attention`` reference — the one-token decode
    expressions with an S axis — so verify-row s stays bit-exact with
    the sequential decode step it replaces (the property speculative
    token-exactness rests on).
    """
    impl = resolve_impl(impl)
    if impl == "xla":
        from repro.models.attention import multi_token_attention
        B, S, H, D = q.shape
        _, page, Hkv, _ = k_pages.shape
        k = jnp.take(k_pages, page_table, axis=0)         # (B, pps, page, ...)
        v = jnp.take(v_pages, page_table, axis=0)
        Skv = k.shape[1] * page
        k = k.reshape(B, Skv, Hkv, D)
        v = v.reshape(B, Skv, Hkv, D)
        out = multi_token_attention(q, k, v, lengths, Hkv)
        return out.reshape(B, S, H, D).astype(q.dtype)
    return _paged_verify_attn(q, k_pages, v_pages, page_table, lengths,
                              interpret=(impl == "interpret"), **kw)


def paged_prefill_attention(q, k_pages, v_pages, page_rows, offset, lengths,
                            *, window: int = 0, impl: str = "auto", **kw):
    """Prompt-chunk attention over the paged KV pool (chunked prefill).

    q: (C, T, H, D) — one prompt chunk per row, model layout;
    k/v_pages: (N, page, Hkv, D) pool layout; page_rows: (C, pages_per_seq)
    frame ids; offset/lengths: (C,) absolute start + valid tokens per row.

    The XLA path gathers each row's page-table view and runs the exact
    ``chunked_attention`` expressions dense prefill uses (per-row
    ``q_offset`` shifts the causal wedge), which is what keeps a chunked
    prefill's generated tokens equal to an uninterrupted dense prefill's.
    The pallas/interpret path is the scalar-prefetch flash kernel
    (``flash_attention.paged_prefill_flash``).
    """
    impl = resolve_impl(impl)
    if impl == "xla":
        from repro.models.attention import chunked_attention
        C, T, H, D = q.shape
        _, page, Hkv, _ = k_pages.shape
        k = jnp.take(k_pages, page_rows, axis=0)       # (C, pps, page, ...)
        v = jnp.take(v_pages, page_rows, axis=0)
        Skv = k.shape[1] * page
        k = k.reshape(C, Skv, Hkv, D)
        v = v.reshape(C, Skv, Hkv, D)
        return chunked_attention(q, k, v, causal=True, window=window,
                                 q_offset=offset)
    qT = q.transpose(0, 2, 1, 3)                       # (C, H, T, D)
    out = _paged_prefill_flash(qT, k_pages, v_pages, page_rows, offset,
                               lengths, window=window,
                               interpret=(impl == "interpret"), **kw)
    return out.transpose(0, 2, 1, 3)


def wkv6(r, k, v, w, u, *, impl: str = "auto", chunk: int = 64):
    impl = resolve_impl(impl)
    if impl == "xla":
        from repro.models.ssm import wkv6_chunked
        return wkv6_chunked(r, k, v, w, u, chunk=chunk)
    return _wkv6(r, k, v, w, u, chunk=chunk, interpret=(impl == "interpret"))


def ssd(x, dt, A, B, C, D, *, impl: str = "auto", chunk: int = 128):
    impl = resolve_impl(impl)
    if impl == "xla":
        from repro.models.ssm import ssd_chunked
        return ssd_chunked(x, dt, A, B, C, D, chunk=chunk)
    return _ssd(x, dt, A, B, C, D, chunk=chunk,
                interpret=(impl == "interpret"))


def gather_rows(src, idx, *, impl: str = "auto", **kw):
    impl = resolve_impl(impl)
    if impl == "xla":
        return _ref.gather_rows_ref(src, idx)
    return _gather_rows(src, idx, interpret=(impl == "interpret"), **kw)

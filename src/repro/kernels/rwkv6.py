"""Chunked WKV6 (RWKV-6 "Finch") Pallas kernel.

The recurrent state S (K x V per head) is the AMU's resident SPM working
set; token chunks of length ``c`` stream through VMEM.  Grid is
(B, H, T/c) with the chunk dimension sequential — S lives in VMEM
scratch across chunk steps, so HBM traffic is O(T) in the inputs and
O(1) in state (the whole point of a linear-recurrence kernel on far
memory: one stream in, one stream out, no S x S attention matrix).

Math (per head, log-decay w <= 0, bonus u):
  o_t = S_{t-1}^T r_t + (r_t . (u*k_t)) v_t
  S_t = diag(e^{w_t}) S_{t-1} + k_t v_t^T
Chunked: intra-chunk pairwise decay P[t,s] = e^{W_{t-1} - W_s} (s < t,
always <= 0 so exp never overflows), inter-chunk via the carried S.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wkv6"]


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, S, *, c: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _():
        S[...] = jnp.zeros_like(S)

    r = r_ref[0, 0].astype(jnp.float32)        # (c, K)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)        # (c, V)
    w = w_ref[0, 0].astype(jnp.float32)        # (c, K) log decay
    u = u_ref[0].astype(jnp.float32)           # (1, K)

    Wc = jnp.cumsum(w, axis=0)                 # inclusive
    Wprev = Wc - w                             # W_{t-1}

    # inter-chunk: o_t += (r_t * e^{W_{t-1}}) @ S_in
    o = jax.lax.dot(r * jnp.exp(Wprev), S[...])            # (c, V)

    # intra-chunk: att[t,s] = sum_k r_t e^{W_{t-1}-W_s} k_s  (s < t)
    # factor the pairwise tensor through the K dim in c x c tiles:
    # att = (r * e^{Wprev}) @ (k * e^{-Wc})^T is unstable; instead compute
    # per-pair exponents relative to the chunk via one (c, c, K) einsum —
    # c is small (<=64) so the tile fits VMEM.
    pair = Wprev[:, None, :] - Wc[None, :, :]              # (c, c, K)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
           > jax.lax.broadcasted_iota(jnp.int32, (c, c), 1))
    pdec = jnp.exp(jnp.minimum(pair, 0.0)) * tri[..., None]
    att = jnp.einsum("tk,tsk,sk->ts", r, pdec, k,
                     preferred_element_type=jnp.float32)
    o = o + jax.lax.dot(att, v)

    # bonus diagonal
    o = o + jnp.sum(r * (u * k), axis=-1, keepdims=True) * v

    # state update: S_out = e^{W_last} S + sum_s (k_s e^{W_last - W_s}) v_s^T
    Wl = Wc[-1:, :]                                        # (1, K)
    kdec = k * jnp.exp(Wl - Wc)                            # (c, K)
    S[...] = jnp.exp(Wl).T * S[...] + jax.lax.dot_general(
        kdec, v, (((0,), (0,)), ((), ())))                 # (K, V)

    o_ref[0, 0] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(
    r: jnp.ndarray,            # (B, T, H, K)
    k: jnp.ndarray,
    v: jnp.ndarray,            # (B, T, H, V)
    w: jnp.ndarray,            # (B, T, H, K) log decay (<= 0)
    u: jnp.ndarray,            # (H, K)
    *,
    chunk: int = 64,
    interpret: bool = True,
) -> jnp.ndarray:
    B, T, H, K = r.shape
    V = v.shape[-1]
    c = min(chunk, T)
    assert T % c == 0, (T, c)

    # kernel layout: (B, H, T, K)
    rT, kT, vT, wT = (a.transpose(0, 2, 1, 3) for a in (r, k, v, w))
    kernel = functools.partial(_wkv6_kernel, c=c)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, T // c),
        in_specs=[
            pl.BlockSpec((1, 1, c, K), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, c, K), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, c, V), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, c, K), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, K), lambda b, h, j: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, c, V), lambda b, h, j: (b, h, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, V), r.dtype),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(rT, kT, vT, wT, u)
    return out.transpose(0, 2, 1, 3)

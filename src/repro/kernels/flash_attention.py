"""Streaming flash attention (causal / sliding-window / GQA) in Pallas.

K/V stream through VMEM block-by-block — the AMU *stream* pattern, here
with compiler-managed pipelining (BlockSpec index maps double-buffer the
DMA automatically; contrast with the manual version in
``amu_matmul.py``).  Online softmax state (m, l, acc) lives in VMEM
scratch and is carried across the sequential KV grid dimension.

Layout: q (B, H, Sq, D); k/v (B, Hkv, Skv, D); out like q.
Block-sparsity: fully-masked KV blocks (outside the causal wedge or the
SWA window) are skipped with ``pl.when`` — the skipped blocks never even
issue their DMA on TPU (the index map still points at them, but Mosaic
elides dead loads within revisited blocks; the FLOP savings are what
matters for the roofline).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention", "paged_prefill_flash"]

NEG_INF = -1e30
_LANE = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
                  scale: float, causal: bool, window: int, bq: int, bkv: int,
                  kv_valid: int, q_offset: int):
    iq, ikv = pl.program_id(2), pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(ikv == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q_pos = q_offset + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kv_pos = ikv * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)

    # block-level liveness: skip blocks fully outside the mask
    first_q = q_offset + iq * bq
    last_q = first_q + bq - 1
    first_kv = ikv * bkv
    live = first_kv < kv_valid
    if causal:
        live &= first_kv <= last_q
    if window:
        live &= (first_kv + bkv - 1) > first_q - window

    @pl.when(live)
    def _():
        q = q_ref[0, 0].astype(jnp.float32) * scale         # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bkv, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bkv)
        mask = kv_pos < kv_valid
        if causal:
            mask &= q_pos >= kv_pos
        if window:
            mask &= kv_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_s[:, :1]                                 # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)                      # (bq, 1)
        l_s[:, :1] = l_s[:, :1] * corr + p.sum(-1, keepdims=True)
        m_s[:, :1] = m_new
        v = v_ref[0, 0].astype(jnp.float32)                 # (bkv, D)
        acc[...] = acc[...] * corr + jax.lax.dot(p, v)

    @pl.when(ikv == n_kv - 1)
    def _():
        l = jnp.maximum(l_s[:, :1], 1e-30)
        o_ref[0, 0] = (acc[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bkv", "q_offset", "kv_valid", "interpret"))
def flash_attention(
    q: jnp.ndarray,            # (B, H, Sq, D)
    k: jnp.ndarray,            # (B, Hkv, Skv, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    kv_valid: Optional[int] = None,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    g = H // Hkv
    scale = 1.0 / math.sqrt(D)
    kv_valid = Skv if kv_valid is None else kv_valid

    bq = min(bq, Sq)
    bkv = min(bkv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0, (Sq, bq, Skv, bkv)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bkv=bkv, kv_valid=kv_valid, q_offset=q_offset)
    grid = (B, H, Sq // bq, Skv // bkv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, i, j, g=g: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, i, j, g=g: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, _LANE), jnp.float32),
            pltpu.VMEM((bq, _LANE), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# paged prefill: flash-attend prompt chunks against page-table-gathered KV
# ---------------------------------------------------------------------------


def _paged_prefill_kernel(pt_ref, off_ref, len_ref, q_ref, k_ref, v_ref,
                          o_ref, acc, m_s, l_s, *, scale: float, page: int,
                          window: int, bq: int):
    b, iq, j = pl.program_id(0), pl.program_id(2), pl.program_id(3)
    n_pg = pl.num_programs(3)

    @pl.when(j == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    offset = off_ref[b]
    kv_valid = offset + len_ref[b]
    first_q = offset + iq * bq
    last_q = first_q + bq - 1
    first_kv = j * page

    # block liveness: this frame holds no position the row's queries may
    # attend (outside the causal wedge / SWA window, or past the row's
    # written extent) -> skip the whole tile
    live = (first_kv < kv_valid) & (first_kv <= last_q)
    if window:
        live &= (first_kv + page - 1) > first_q - window

    @pl.when(live)
    def _():
        q_pos = first_q + jax.lax.broadcasted_iota(jnp.int32, (bq, page), 0)
        kv_pos = first_kv + jax.lax.broadcasted_iota(jnp.int32, (bq, page), 1)
        q = q_ref[0, 0].astype(jnp.float32) * scale           # (bq, D)
        k = k_ref[0, :, 0].astype(jnp.float32)                # (page, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, page)
        mask = (q_pos >= kv_pos) & (kv_pos < kv_valid)
        if window:
            mask &= kv_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_s[:, :1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_s[:, :1] = l_s[:, :1] * corr + p.sum(-1, keepdims=True)
        m_s[:, :1] = m_new
        v = v_ref[0, :, 0].astype(jnp.float32)                # (page, D)
        acc[...] = acc[...] * corr + jax.lax.dot(p, v)

    @pl.when(j == n_pg - 1)
    def _():
        l = jnp.maximum(l_s[:, :1], 1e-30)
        o_ref[0, 0] = (acc[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bq", "interpret"))
def paged_prefill_flash(
    q: jnp.ndarray,            # (C, H, T, D) — one prompt chunk per row
    k_pages: jnp.ndarray,      # (N, page, Hkv, D) — the device page pool
    v_pages: jnp.ndarray,
    page_rows: jnp.ndarray,    # (C, pages_per_seq) int32 physical frame ids
    offset: jnp.ndarray,       # (C,) int32 absolute position of q[:, :, 0]
    lengths: jnp.ndarray,      # (C,) int32 valid tokens in each chunk row
    *,
    window: int = 0,
    bq: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Chunked-prefill flash attention reading KV through the page table.

    The paged-KV variant of :func:`flash_attention`: each grid row is one
    admitting sequence's prompt chunk, and the KV grid dimension streams
    that sequence's pool *frames* through VMEM — the page-table row is a
    scalar-prefetch operand whose dereference picks the frame each step
    DMAs, exactly like ``decode_attention.paged_decode_attention`` but
    with a (bq, page) score tile instead of one query token.  Per-row
    ``offset``/``lengths`` (also scalar-prefetched) shift the causal
    wedge to each row's absolute position, so one call serves chunk rows
    of different sequences at different prefill depths.  Frames past a
    row's written extent are skipped by block liveness and never even
    issue their DMA.
    """
    C, H, T, D = q.shape
    N, page, Hkv, _ = k_pages.shape
    g = H // Hkv
    pages_per_seq = page_rows.shape[1]
    bq = min(bq, T)
    pad_t = (-T) % bq
    if pad_t:
        # pad the chunk axis up to a block multiple; padded queries
        # produce don't-care rows that are sliced off below (callers
        # only read positions below each row's valid length anyway)
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
        T = T + pad_t

    kernel = functools.partial(_paged_prefill_kernel,
                               scale=1.0 / math.sqrt(D), page=page,
                               window=window, bq=bq)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(C, H, T // bq, pages_per_seq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D),
                         lambda b, h, i, j, pt, off, ln: (b, h, i, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, i, j, pt, off, ln, g=g:
                         (pt[b, j], 0, h // g, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, i, j, pt, off, ln, g=g:
                         (pt[b, j], 0, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, i, j, pt, off, ln: (b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, _LANE), jnp.float32),
            pltpu.VMEM((bq, _LANE), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(page_rows.astype(jnp.int32), offset.astype(jnp.int32),
      lengths.astype(jnp.int32), q, k_pages, v_pages)
    return out[:, :, :T - pad_t] if pad_t else out

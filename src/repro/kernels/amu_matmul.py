"""AMU matmul — the paper's programming model inside one Pallas kernel.

This is the flagship kernel: it does NOT use BlockSpec index-map
pipelining for its inputs.  Instead the operands live in HBM
(``memory_space=ANY``) and the kernel body itself plays the role of the
paper's software:

  * ``aload``  = ``pltpu.make_async_copy(hbm_slice, vmem_buf, sem).start()``
  * SPM        = double-buffered VMEM scratch (two slots per operand —
    the reconfigurable cache/SPM split from ``core/spm.py`` decides the
    tile shape),
  * ``getfin`` = ``copy.wait()`` on the slot's DMA semaphore,
  * event loop = issue tile ``k+1`` while the MXU consumes tile ``k``.

On real TPU hardware the DMA engines run concurrently with the MXU, so
the wait on slot ``(k+1) % 2`` returns long after the matmul on slot
``k % 2`` has been issued — compute/copy overlap, which is exactly the
paper's Fig-1 argument (keep many outstanding requests in flight so
far-memory latency never idles the core).  In ``interpret=True`` mode the
semantics (not the timing) are validated.

Grid: ``(M/bm, N/bn)``; the K loop is a ``fori_loop`` inside the kernel so
that the manual double-buffering is explicit rather than compiler-owned.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.spm import plan_matmul_blocks

__all__ = ["amu_matmul"]


def _amu_matmul_kernel(x_hbm, w_hbm, o_ref, xb, wb, acc, sem_x, sem_w,
                       *, bm: int, bk: int, bn: int, n_k: int):
    """x_hbm: (M,K) in ANY; w_hbm: (K,N) in ANY; o_ref: (bm,bn) VMEM block.

    xb/wb: (2, bm, bk) / (2, bk, bn) VMEM double buffers.
    sem_x/sem_w: DMA semaphore arrays, one per slot.
    """
    i, j = pl.program_id(0), pl.program_id(1)

    def issue(k, slot):
        cx = pltpu.make_async_copy(
            x_hbm.at[pl.ds(i * bm, bm), pl.ds(k * bk, bk)],
            xb.at[slot], sem_x.at[slot])
        cw = pltpu.make_async_copy(
            w_hbm.at[pl.ds(k * bk, bk), pl.ds(j * bn, bn)],
            wb.at[slot], sem_w.at[slot])
        cx.start()
        cw.start()

    def wait(k, slot):
        pltpu.make_async_copy(
            x_hbm.at[pl.ds(i * bm, bm), pl.ds(k * bk, bk)],
            xb.at[slot], sem_x.at[slot]).wait()
        pltpu.make_async_copy(
            w_hbm.at[pl.ds(k * bk, bk), pl.ds(j * bn, bn)],
            wb.at[slot], sem_w.at[slot]).wait()

    # aload tile 0 (and 1, if any) — fill the pipeline
    issue(0, 0)

    @pl.when(n_k > 1)
    def _():
        issue(1, 1)

    acc[...] = jnp.zeros_like(acc)

    def body(k, _):
        slot = jax.lax.rem(k, 2)
        # getfin: wait for tile k's DMA to land in SPM slot
        wait(k, slot)
        acc[...] += jnp.dot(xb[slot], wb[slot],
                            preferred_element_type=jnp.float32)
        # slot is consumed — keep the pipeline full: aload tile k+2 into it
        @pl.when(k + 2 < n_k)
        def _():
            issue(k + 2, slot)
        return ()

    jax.lax.fori_loop(0, n_k, body, (), unroll=False)
    o_ref[...] = acc[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def amu_matmul(
    x: jnp.ndarray,              # (M, K)
    w: jnp.ndarray,              # (K, N)
    *,
    bm: Optional[int] = None,
    bk: Optional[int] = None,
    bn: Optional[int] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    if bm is None or bk is None or bn is None:
        plan = plan_matmul_blocks(M, K, N, dtype_bytes=x.dtype.itemsize)
        bm = bm or min(plan.block_shapes["x"][0], M)
        bk = bk or min(plan.block_shapes["x"][1], K)
        bn = bn or min(plan.block_shapes["w"][1], N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, \
        f"dims ({M},{K},{N}) must tile by ({bm},{bk},{bn})"
    n_k = K // bk

    kernel = functools.partial(_amu_matmul_kernel, bm=bm, bk=bk, bn=bn,
                               n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),    # x stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),    # w stays in HBM
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, bm, bk), x.dtype),        # SPM slots for x
            pltpu.VMEM((2, bk, bn), w.dtype),        # SPM slots for w
            pltpu.VMEM((bm, bn), jnp.float32),       # accumulator
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(x, w)

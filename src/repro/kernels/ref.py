"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are swept against
(`tests/test_kernels_*.py` asserts allclose over shape/dtype grids).
They are deliberately naive — full materialisation, no chunking — so
they stay obviously correct.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["matmul_ref", "attention_ref", "decode_attention_ref",
           "wkv6_ref", "ssd_ref", "gather_rows_ref"]


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) \
        .astype(x.dtype)


def attention_ref(q, k, v, *, causal=True, window=0, q_offset=0):
    """Naive softmax attention with GQA.  q: (B,Sq,H,D); k/v: (B,Skv,Hkv,D)."""
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, g, D)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, k.astype(jnp.float32))
    s = s / math.sqrt(D)
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    kv_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= q_pos >= kv_pos
    if window:
        mask &= kv_pos > q_pos - window
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def decode_attention_ref(q, k, v, valid_len):
    """One-token attention.  q: (B,H,D); k/v: (B,S,Hkv,D); valid_len scalar."""
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, g, D) / math.sqrt(D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k.astype(jnp.float32))
    mask = jnp.arange(S)[None, None, None, :] < valid_len
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


def wkv6_ref(r, k, v, w, u):
    """Sequential WKV6 (same math as models.ssm.wkv6_sequential)."""
    from repro.models.ssm import wkv6_sequential
    return wkv6_sequential(r, k, v, w, u)


def ssd_ref(x, dt, A, B, C, D):
    """Sequential Mamba2 SSD (same math as models.ssm.ssd_sequential)."""
    from repro.models.ssm import ssd_sequential
    y, _ = ssd_sequential(x, dt, A, B, C, D)
    return y


def gather_rows_ref(src: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Row gather: out[i] = src[idx[i]].  src: (N, d); idx: (M,) int32."""
    return jnp.take(src, idx, axis=0)

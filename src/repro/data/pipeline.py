"""Synthetic LM data pipeline with AMU-backed asynchronous prefetch.

The pipeline produces deterministic, *learnable* token streams (affine
recurrences over the vocab with per-sequence parameters) so the e2e
training example shows a real loss curve, not noise.

:class:`PrefetchingLoader` is the paper's programming model applied to
input data: host->device batch transfers are ``aload``-ed ``depth``
batches ahead through an :class:`repro.core.AMU`, and the training loop
``getfin``s the next ready batch — input pipeline latency hides behind
compute exactly like far-memory latency hides behind the MXU.
"""

from __future__ import annotations

import math
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.amu import AMU, AccessConfig, DeviceTransferBackend, QoS

__all__ = ["SyntheticLM", "PrefetchingLoader", "make_loader"]


class SyntheticLM:
    """Deterministic learnable token stream.

    Each sequence follows ``x_{t+1} = (a * x_t + c) mod V`` with (a, c)
    drawn per sequence from a small pool — a next-token distribution a
    ~100M model learns within a few hundred steps.
    """

    def __init__(self, vocab: int, seq_len: int, batch: int, *,
                 seed: int = 0, start_step: int = 0, pool: int = 8,
                 extras: Optional[Dict[str, tuple]] = None):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = batch
        # the *task* (pattern pool) depends only on ``seed``; the stream
        # position advances with ``start_step`` so resume continues the
        # same task rather than re-rolling it.
        pool_rng = np.random.default_rng(seed)
        self.pool_a = pool_rng.integers(2, 7, pool)
        self.pool_c = pool_rng.integers(1, vocab - 1, pool)
        self.rng = np.random.default_rng(
            np.random.SeedSequence([seed, start_step]))
        self.extras = extras or {}
        self.step = start_step

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        B, S, V = self.batch, self.seq_len, self.vocab
        which = self.rng.integers(0, len(self.pool_a), B)
        a = self.pool_a[which][:, None]
        c = self.pool_c[which][:, None]
        x0 = self.rng.integers(0, V, (B, 1))
        toks = np.empty((B, S + 1), np.int64)
        toks[:, :1] = x0
        for t in range(S):
            toks[:, t + 1] = (a[:, 0] * toks[:, t] + c[:, 0]) % V
        batch = {
            "tokens": toks[:, :S].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        for name, (shape, dtype) in self.extras.items():
            batch[name] = self.rng.standard_normal(
                (B,) + tuple(shape)).astype(dtype)
        self.step += 1
        return batch


class PrefetchingLoader:
    """Wraps an iterator; keeps ``depth`` device transfers in flight."""

    def __init__(self, it: Iterator, *, depth: int = 2,
                 sharding=None, amu: Optional[AMU] = None):
        self.it = it
        self.depth = depth
        self.sharding = sharding
        self.amu = amu or AMU(backend=DeviceTransferBackend(),
                              max_outstanding=max(2, depth * 2),
                              default_config=AccessConfig(
                                  granularity_bytes=1 << 20,
                                  qos=QoS.STANDARD))
        self._queue = []                # rids in order

    def _put(self, host_batch):
        if self.sharding is not None:
            dev = jax.device_put(host_batch, self.sharding)
            # already dispatched asynchronously by jax; track as one request
            rid = self.amu.aload(np.zeros(1, np.uint8), nbytes=1)
            self.amu.wait(rid)
            self._queue.append(("ready", dev))
        else:
            rids = {k: self.amu.aload(v) for k, v in host_batch.items()}
            self._queue.append(("amu", rids))

    def _fill(self):
        while len(self._queue) < self.depth:
            try:
                self._put(next(self.it))
            except StopIteration:
                break

    def __iter__(self):
        return self

    def __next__(self):
        self._fill()
        if not self._queue:
            raise StopIteration
        kind, payload = self._queue.pop(0)
        self._fill()
        if kind == "ready":
            return payload
        out = {}
        for k, rid in payload.items():
            self.amu.wait(rid)
            out[k] = jnp.asarray(self.amu.result(rid))
        return out


def make_loader(cfg: ModelConfig, shape: ShapeConfig, *, seed: int = 0,
                start_step: int = 0, sharding=None,
                depth: int = 2) -> PrefetchingLoader:
    extras = {}
    if cfg.family == "encdec":
        extras["src_embeds"] = ((shape.seq_len, cfg.d_model), np.float32)
    it = SyntheticLM(cfg.vocab_size, shape.seq_len, shape.global_batch,
                     seed=seed, start_step=start_step, extras=extras)
    if cfg.mrope_sections:
        base = it

        class _WithPositions:
            def __iter__(self):
                return self

            def __next__(self):
                b = next(base)
                B, S = b["tokens"].shape
                b["positions"] = np.broadcast_to(
                    np.arange(S, dtype=np.int32), (3, B, S)).copy()
                return b

        it = _WithPositions()
    return PrefetchingLoader(it, depth=depth, sharding=sharding)

"""repro.data"""

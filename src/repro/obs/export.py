"""Exporters: Chrome-trace/Perfetto JSON timeline + flat metrics JSON.

:func:`to_chrome_trace` renders a :class:`~repro.obs.tracer.Tracer`'s
event list in the Chrome trace-event JSON format that Perfetto
(https://ui.perfetto.dev) loads directly:

  * each ``(pid, tid)`` track pair becomes a named process/thread via
    ``"M"`` metadata events,
  * spans are ``"X"`` complete events (``ts``/``dur`` in microseconds of
    *virtual* time — the shared engine clock),
  * instants are ``"i"`` (scope ``"t"``), counter samples are ``"C"``
    (one Perfetto area chart per counter name — the per-QoS
    window-occupancy tracks),
  * AMU transfer spans overlap heavily by design (that is the paper's
    whole point), and overlapping ``"X"`` events on one thread are not
    legal Chrome-trace nesting — so the exporter lane-packs each AMU
    track greedily into ``LATENCY``, ``LATENCY·2``, … sub-lanes, which
    doubles as a visual in-flight-depth readout.

Spans still open at export (requests alive when the run stopped) are
flushed closed at the current clock and tagged ``incomplete``.
"""

from __future__ import annotations

import heapq
import json
from typing import Any, Dict, List, Optional

from .metrics import MetricsRegistry
from .tracer import Tracer

__all__ = ["to_chrome_trace", "write_chrome_trace", "write_metrics"]

#: process names whose span tracks are lane-packed (overlap-by-design)
_PACKED_PIDS = frozenset({"amu"})


def _json_args(args: Optional[dict]) -> Dict[str, Any]:
    if not args:
        return {}
    out = {}
    for k, v in args.items():
        if isinstance(v, (bool, int, float, str)) or v is None:
            out[str(k)] = v
        else:
            out[str(k)] = str(v)
    return out


def _pack_lanes(spans: List[dict]) -> None:
    """Greedy interval-graph colouring: assign each overlapping span the
    lowest free lane; mutates each span dict with a ``_lane`` key."""
    free: List[int] = []         # released lane numbers (min-heap)
    busy: List[tuple] = []       # (end_ts, lane) min-heap
    n_lanes = 0
    for sp in sorted(spans, key=lambda s: (s["ts"], -s["dur"])):
        t0 = sp["ts"]
        while busy and busy[0][0] <= t0:
            _, lane = heapq.heappop(busy)
            heapq.heappush(free, lane)
        if free:
            lane = heapq.heappop(free)
        else:
            lane = n_lanes
            n_lanes += 1
        sp["_lane"] = lane
        heapq.heappush(busy, (t0 + sp["dur"], lane))


def to_chrome_trace(tracer: Tracer,
                    metrics: Optional[MetricsRegistry] = None) -> dict:
    """Render the tracer's events as a Chrome-trace JSON dict."""
    n_open = tracer.flush_open({"incomplete": True})

    raw = []
    for ph, pid, tid, name, ts, dv, args in tracer.events:
        ev = {"ph": ph, "pid": pid, "tid": tid, "name": name,
              "ts": ts * 1e6}
        if ph == "X":
            ev["dur"] = dv * 1e6
            ev["args"] = _json_args(args)
        elif ph == "i":
            ev["s"] = "t"
            ev["args"] = _json_args(args)
        else:  # "C"
            ev["args"] = {"value": dv}
        raw.append(ev)

    # lane-pack overlapping span tracks (AMU transfers)
    by_track: Dict[tuple, List[dict]] = {}
    for ev in raw:
        if ev["ph"] == "X" and ev["pid"] in _PACKED_PIDS:
            by_track.setdefault((ev["pid"], ev["tid"]), []).append(ev)
    for (pid, tid), spans in by_track.items():
        _pack_lanes(spans)
        for sp in spans:
            lane = sp.pop("_lane")
            if lane:
                sp["tid"] = f"{tid}·{lane + 1}"

    # map string pid/tid -> stable ints + metadata name events
    pids: Dict[str, int] = {}
    tids: Dict[tuple, int] = {}
    events: List[dict] = []
    for ev in raw:
        pname, tname = ev["pid"], ev["tid"]
        if pname not in pids:
            pids[pname] = len(pids) + 1
            events.append({"ph": "M", "name": "process_name",
                           "pid": pids[pname], "tid": 0,
                           "args": {"name": pname}})
        pid = pids[pname]
        key = (pname, tname)
        if key not in tids:
            tids[key] = len(tids) + 1
            events.append({"ph": "M", "name": "thread_name",
                           "pid": pid, "tid": tids[key],
                           "args": {"name": tname}})
        ev["pid"] = pid
        ev["tid"] = tids[key]
        events.append(ev)

    doc = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "generator": "repro.obs",
            "clock": "virtual",
            "clock_s": tracer.clock(),
            "open_spans_flushed": n_open,
        },
    }
    if metrics is not None:
        doc["otherData"]["metrics"] = metrics.snapshot()
    return doc


def write_chrome_trace(path: str, tracer: Tracer,
                       metrics: Optional[MetricsRegistry] = None) -> None:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(tracer, metrics), f)


def write_metrics(path: str, metrics: MetricsRegistry) -> None:
    with open(path, "w") as f:
        json.dump(metrics.snapshot(), f, indent=2, sort_keys=True)

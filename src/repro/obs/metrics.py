"""MetricsRegistry: counters, gauges, and log-bucketed histograms.

The paper's premise is that far-memory latency is *widely distributed*
and the AMU's job is hiding that distribution — which means the signals
that matter are distributions and tails (p95/p99), not means.  Before
this module every subsystem kept its own flat ``collections.Counter``
(``pager.stats``, ``engine.stats``, ``events.history``); those now live
as :class:`CounterView` windows onto one shared :class:`MetricsRegistry`
so a single flat-metrics export sees everything, while every existing
``stats["key"]`` / ``dict(stats)`` call site keeps working unchanged.

Histograms are log-bucketed: bucket ``i`` covers
``(floor * growth**(i-1), floor * growth**i]``, so memory is O(decades)
regardless of sample count and any percentile is reproducible to a
relative error of about ``growth - 1`` (the default 1.05 ⇒ ≤ ~5%,
checked against a numpy reference in ``tests/test_obs.py``).  ``min`` /
``max`` / ``sum`` / ``count`` are tracked exactly, so ``max`` — the
operative tail statistic — has no bucketing error.
"""

from __future__ import annotations

import math
from collections.abc import MutableMapping
from typing import Any, Dict, Optional

__all__ = ["CounterView", "Histogram", "MetricsRegistry"]


class CounterView(MutableMapping):
    """A ``collections.Counter``-compatible view over one registry group.

    Missing keys read as 0 (Counter semantics) but are not created;
    ``view[k] += 1`` works; keys may be any hashable (the event loop's
    history is keyed by :class:`~repro.paging.events.EventKind`).  The
    underlying dict is owned by the registry, so every increment lands
    in the shared export without the call site knowing the registry
    exists.
    """

    __slots__ = ("_data",)

    def __init__(self, data: Dict[Any, float]) -> None:
        self._data = data

    def __getitem__(self, key):
        return self._data.get(key, 0)

    def __setitem__(self, key, value):
        self._data[key] = value

    def __delitem__(self, key):
        del self._data[key]

    def __iter__(self):
        return iter(self._data)

    def __len__(self):
        return len(self._data)

    def __contains__(self, key):
        return key in self._data

    def get(self, key, default=0):
        return self._data.get(key, default)

    def __eq__(self, other):
        if isinstance(other, CounterView):
            return self._data == other._data
        if isinstance(other, dict):
            return self._data == dict(other)
        return NotImplemented

    __hash__ = None

    def __repr__(self):
        return f"CounterView({self._data!r})"


class Histogram:
    """Log-bucketed latency histogram with exact min/max/sum/count.

    ``observe`` is allocation-free on the hot path (one dict upsert);
    percentiles walk the sparse bucket dict only when asked.
    """

    __slots__ = ("name", "growth", "floor", "_log_g", "count", "total",
                 "vmin", "vmax", "buckets")

    def __init__(self, name: str = "", growth: float = 1.05,
                 floor: float = 1e-9) -> None:
        if growth <= 1.0:
            raise ValueError("histogram growth factor must be > 1")
        self.name = name
        self.growth = float(growth)
        self.floor = float(floor)
        self._log_g = math.log(self.growth)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.buckets: Dict[int, int] = {}

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if v <= self.floor:
            idx = 0
        else:
            idx = 1 + int(math.log(v / self.floor) / self._log_g)
        self.buckets[idx] = self.buckets.get(idx, 0) + 1

    def _bucket_value(self, idx: int) -> float:
        if idx <= 0:
            val = self.floor
        else:
            # geometric midpoint of (floor*g^(i-1), floor*g^i]
            val = self.floor * math.exp(self._log_g * (idx - 0.5))
        return min(max(val, self.vmin), self.vmax)

    def percentile(self, q: float) -> float:
        """Approximate ``numpy.percentile(samples, q)``: the value of the
        bucket containing the linear-interpolation rank, clamped to the
        exact observed min/max."""
        if self.count == 0:
            return 0.0
        rank = (q / 100.0) * (self.count - 1)
        if rank >= self.count - 1:
            return self.vmax          # the tail stat is exact, not bucketed
        seen = 0
        for idx in sorted(self.buckets):
            seen += self.buckets[idx]
            if seen - 1 >= rank:
                return self._bucket_value(idx)
        return self.vmax

    @property
    def p50(self) -> float:
        return self.percentile(50.0)

    @property
    def p95(self) -> float:
        return self.percentile(95.0)

    @property
    def p99(self) -> float:
        return self.percentile(99.0)

    @property
    def max(self) -> float:
        return self.vmax if self.count else 0.0

    @property
    def min(self) -> float:
        return self.vmin if self.count else 0.0

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> Dict[str, float]:
        return {"count": self.count, "sum": self.total, "mean": self.mean,
                "min": self.min, "max": self.max, "p50": self.p50,
                "p95": self.p95, "p99": self.p99}

    def __repr__(self):
        return (f"Histogram({self.name!r}, n={self.count}, "
                f"p50={self.p50:.3g}, p99={self.p99:.3g}, "
                f"max={self.max:.3g})")


def _export_key(key: Any) -> str:
    """Flatten a counter key for JSON export (EventKind → its name)."""
    if isinstance(key, str):
        return key
    return getattr(key, "name", None) or str(key)


class MetricsRegistry:
    """One process-wide sink for counters, gauges, and histograms.

    Subsystems request a named counter *group*
    (``registry.counters("pager")``) and get back a dict-compatible
    :class:`CounterView`; histograms and gauges are keyed by flat
    slash-separated names (``amu/latency_s/aload/LATENCY``).
    :meth:`snapshot` renders everything as one JSON-safe dict — the
    payload behind ``--metrics-out``.
    """

    def __init__(self) -> None:
        self._counters: Dict[str, Dict[Any, float]] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, Histogram] = {}

    def counters(self, group: str,
                 initial: Optional[Dict[Any, float]] = None) -> CounterView:
        data = self._counters.setdefault(group, {})
        if initial:
            for k, v in initial.items():
                data.setdefault(k, v)
        return CounterView(data)

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def histogram(self, name: str, *, growth: float = 1.05,
                  floor: float = 1e-9) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name, growth, floor)
        return h

    def observe(self, name: str, value: float) -> None:
        self.histogram(name).observe(value)

    def snapshot(self) -> Dict[str, Any]:
        return {
            "counters": {
                group: {_export_key(k): v for k, v in data.items()}
                for group, data in self._counters.items()},
            "gauges": dict(self.gauges),
            "histograms": {name: h.snapshot()
                           for name, h in self.histograms.items()},
        }

"""repro.obs — unified telemetry across AMU → pager → engine.

Zero-dependency observability riding the one shared
:class:`~repro.serve.config.VirtualClock`:

  * :class:`Tracer` — structured spans/instants/counter samples for
    every AMU transfer, pager action, page residency transition, and
    engine request lifecycle event (default-off-cheap: one branch),
  * :class:`MetricsRegistry` — counters, gauges, and log-bucketed
    :class:`Histogram` percentiles (p50/p95/p99/max); the subsystem
    ``stats`` Counters are now :class:`CounterView` windows onto it,
  * exporters — Chrome-trace/Perfetto JSON timelines
    (:func:`write_chrome_trace`) and flat metrics JSON
    (:func:`write_metrics`), the payloads behind
    ``launch/serve --trace-out/--metrics-out``.

``tools/trace_report.py`` consumes the timeline standalone: schema
validation, per-QoS queueing-delay breakdown, and an SLO attainment
report recomputed from trace events alone.
"""

from .metrics import CounterView, Histogram, MetricsRegistry
from .tracer import NULL_TRACER, Tracer
from .export import to_chrome_trace, write_chrome_trace, write_metrics

__all__ = [
    "CounterView", "Histogram", "MetricsRegistry",
    "NULL_TRACER", "Tracer",
    "to_chrome_trace", "write_chrome_trace", "write_metrics",
]

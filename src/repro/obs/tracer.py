"""Tracer: structured spans/instants/counter samples on one clock.

Every event carries a ``(pid, tid)`` track pair — process/thread names
in the Chrome-trace sense — and a timestamp from the *injected* clock,
which in this repo is the engine's single :class:`VirtualClock` (the
pager's simulated AMU backend advances in lockstep), so AMU transfer
spans, pager actions, and request lifecycle spans all land on one
shared, deterministic time axis.

Design constraints from the issue:

  * **default-off-cheap** — every method starts with one attribute test
    (``if not self.enabled: return``); hot call sites additionally guard
    with ``if tracer.enabled:`` before building an args dict, so a
    disabled tracer costs one branch and zero allocations,
  * **allocation-light when on** — events are plain tuples appended to
    one list; no per-event objects, no string formatting until export,
  * **well-formed spans** — ``begin`` returns a span id tracked in
    ``open_spans`` until ``end`` pops it, so tests (and the exporter)
    can assert every open span closes.

Event tuple layout: ``(ph, pid, tid, name, ts, dur_or_value, args)``
with ``ph`` one of ``"X"`` (complete span), ``"i"`` (instant), ``"C"``
(counter sample).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = ["Tracer", "NULL_TRACER"]

Event = Tuple[str, str, str, str, float, float, Optional[dict]]


def _zero_clock() -> float:
    return 0.0


class Tracer:
    __slots__ = ("enabled", "clock", "events", "open_spans", "_next_sid",
                 "_append", "_last_counter")

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 enabled: bool = True) -> None:
        self.enabled = enabled
        self.clock = clock if clock is not None else _zero_clock
        self.events: List[Event] = []
        # bound once: the hot emission paths run per simulated transfer,
        # so one attribute lookup per event is worth saving
        self._append = self.events.append
        #: (pid, name) -> last emitted counter value, for sample dedup
        self._last_counter: Dict[Tuple[str, str], float] = {}
        #: sid -> (pid, tid, name, t0, args) for spans begun but not ended
        self.open_spans: Dict[int, Tuple[str, str, str, float,
                                         Optional[dict]]] = {}
        self._next_sid = 1

    # -- emission -------------------------------------------------------------

    def instant(self, pid: str, tid: str, name: str,
                args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        self._append(("i", pid, tid, name, self.clock(), 0.0, args))

    def counter(self, pid: str, name: str, value: float) -> None:
        """One sample of a counter track (e.g. per-QoS window occupancy);
        rendered as a stepped area chart in Perfetto.  Samples equal to
        the track's previous value are dropped — a stepped chart renders
        identically, and periodic samplers (the pager polls
        ``free_frames`` every tick) stop flooding the trace."""
        if not self.enabled:
            return
        v = float(value)
        key = (pid, name)
        if self._last_counter.get(key) == v:
            return
        self._last_counter[key] = v
        self._append(("C", pid, name, name, self.clock(), v, None))

    def begin(self, pid: str, tid: str, name: str,
              args: Optional[dict] = None) -> int:
        """Open a span at ``clock()``; returns a span id for :meth:`end`
        (0 when disabled — ``end(0)`` is a no-op, so call sites need no
        branch)."""
        if not self.enabled:
            return 0
        sid = self._next_sid
        self._next_sid = sid + 1
        self.open_spans[sid] = (pid, tid, name, self.clock(), args)
        return sid

    def end(self, sid: int, args: Optional[dict] = None) -> None:
        if not sid:
            return
        ent = self.open_spans.pop(sid, None)
        if ent is None:
            return
        pid, tid, name, t0, a0 = ent
        if args:
            a0 = {**a0, **args} if a0 else dict(args)
        self._append(("X", pid, tid, name, t0,
                      max(0.0, self.clock() - t0), a0))

    def complete(self, pid: str, tid: str, name: str, t0: float,
                 t1: Optional[float] = None,
                 args: Optional[dict] = None) -> None:
        """Record a span whose start time is already known (e.g. an AMU
        request's ``issue_t`` at retire time) without open-span tracking."""
        if not self.enabled:
            return
        if t1 is None:
            t1 = self.clock()
        self._append(("X", pid, tid, name, t0,
                      max(0.0, t1 - t0), args))

    def flush_open(self, args: Optional[dict] = None) -> int:
        """Close any spans still open (e.g. requests in flight when the
        run stops); returns how many were force-closed."""
        dangling = list(self.open_spans)
        for sid in dangling:
            self.end(sid, args)
        return len(dangling)


#: Shared disabled tracer: instrumented code holds a tracer attribute
#: unconditionally and pays one `enabled` branch when telemetry is off.
NULL_TRACER = Tracer(enabled=False)

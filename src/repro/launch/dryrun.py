import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements in this file — jax
locks the device count at first initialisation, and the production mesh
needs 512 placeholder host devices.

For every architecture and its shape suite this script:
  1. builds the production mesh (single-pod 16x16 / multi-pod 2x16x16),
  2. builds abstract inputs (ShapeDtypeStruct — nothing is allocated),
  3. ``jit(step).lower(...).compile()`` with the sharding rules from
     ``repro.dist``,
  4. records ``memory_analysis()`` / ``cost_analysis()`` / collective
     bytes into a JSON report consumed by the roofline table.

Usage:
  python -m repro.launch.dryrun --arch phi4-mini-3.8b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out dryrun.json
  python -m repro.launch.dryrun --all --resume --out dryrun.json
"""

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import (ARCH_IDS, SHAPES, cell_is_runnable, get_config,
                           get_shape)
from repro.configs.base import TrainConfig
from repro.dist.steps import (decode_inputs, make_prefill_step,
                              make_serve_step, make_train_step, train_inputs,
                              abstract_params, abstract_opt_state)
from repro.launch.mesh import make_production_mesh
from repro.analysis.roofline import roofline_from_compiled


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               tcfg: TrainConfig = None, verbose: bool = True,
               optimized: bool = False):
    """Lower+compile one cell; returns the roofline report dict."""
    from repro.dist import act_sharding as acts
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if not cell_is_runnable(cfg, shape):
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped",
                "reason": "full-attention arch at 500k context (O(L^2)); "
                          "see DESIGN.md"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    tcfg = tcfg or TrainConfig(
        act_sharding="optimized" if optimized else "baseline")
    act_policy = acts.OPTIMIZED if optimized else None
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            fn, _ = make_train_step(cfg, tcfg, mesh, shape, donate=False)
            params = abstract_params(cfg)
            opt = abstract_opt_state(cfg)
            batch = train_inputs(cfg, shape)
            lowered = fn.lower(params, opt, batch)
        elif shape.kind == "prefill":
            fn, _ = make_prefill_step(cfg, mesh, shape, act_policy=act_policy)
            params = abstract_params(cfg)
            batch = train_inputs(cfg, shape)
            batch.pop("labels")
            batch["labels"] = jax.ShapeDtypeStruct(batch["tokens"].shape,
                                                   batch["tokens"].dtype)
            lowered = fn.lower(params, batch)
        else:  # decode
            fn, _ = make_serve_step(cfg, mesh, shape, donate=False,
                                    act_policy=act_policy)
            params = abstract_params(cfg)
            cache, tokens = decode_inputs(cfg, shape)
            lowered = fn.lower(params, cache, tokens)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis()
    try:
        mem = compiled.memory_analysis()
    except Exception:
        mem = None
    hlo = compiled.as_text()
    report = roofline_from_compiled(
        arch=arch, shape_name=shape_name, shape=shape, cfg=cfg,
        mesh_name="multi" if multi_pod else "single",
        n_devices=mesh.size, cost=cost, hlo_text=hlo, memory_stats=mem)
    row = json.loads(report.to_json())
    row.update(status="ok", lower_s=round(t_lower, 1),
               compile_s=round(t_compile, 1))
    if mem is not None:
        row["memory_analysis"] = {
            "argument_size": mem.argument_size_in_bytes,
            "output_size": mem.output_size_in_bytes,
            "temp_size": mem.temp_size_in_bytes,
        }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x "
              f"{'multi' if multi_pod else 'single'}: OK "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s, "
              f"bottleneck={row['bottleneck']}, "
              f"t_comp={row['t_compute']*1e3:.1f}ms "
              f"t_mem={row['t_memory']*1e3:.1f}ms "
              f"t_coll={row['t_collective']*1e3:.1f}ms)")
        if mem is not None:
            print(f"         memory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
                  f"out={mem.output_size_in_bytes/2**30:.2f}GiB "
                  f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB (per device)")
        sys.stdout.flush()
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape name")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true", help="every runnable cell")
    ap.add_argument("--out", default=None, help="JSON output path (appended "
                    "incrementally; resumable)")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already present in --out")
    ap.add_argument("--opt", action="store_true",
                    help="use the optimized activation-sharding/precision "
                         "policy (beyond-paper perf path)")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else [s.name for s in SHAPES]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    done = {}
    out_path = Path(args.out) if args.out else None
    if out_path and out_path.exists():
        for line in out_path.read_text().splitlines():
            if line.strip():
                r = json.loads(line)
                done[(r["arch"], r["shape"], r["mesh"])] = r

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                key = (arch, shape_name, "multi" if multi else "single")
                if args.resume and key in done and \
                        done[key].get("status") in ("ok", "skipped"):
                    continue
                try:
                    row = lower_cell(arch, shape_name, multi_pod=multi,
                                     optimized=args.opt)
                except Exception as e:
                    traceback.print_exc()
                    row = {"arch": arch, "shape": shape_name,
                           "mesh": key[2], "status": "FAILED",
                           "error": f"{type(e).__name__}: {e}"}
                    failures.append(key)
                if out_path:
                    with out_path.open("a") as f:
                        f.write(json.dumps(row) + "\n")
    if failures:
        print(f"[dryrun] FAILURES: {failures}")
        sys.exit(1)
    print("[dryrun] all requested cells passed")


if __name__ == "__main__":
    main()

"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state.  The dry-run entry point
(``launch/dryrun.py``) sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
before any jax import; everything here just consumes whatever devices
exist.

Production target: TPU v5e pods — 256 chips/pod arranged (data=16,
model=16); multi-pod adds a leading ``pod`` axis (outer data parallelism
over DCN).  ICI links serve the intra-pod axes.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

__all__ = ["make_mesh_compat", "make_production_mesh", "make_test_mesh", "HW"]


def make_mesh_compat(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """``jax.make_mesh`` across jax versions: newer jax wants explicit
    ``axis_types=(AxisType.Auto, ...)`` for GSPMD-propagated axes; older
    jax (<= 0.4.x) has neither the enum nor the kwarg."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes)


class HW:
    """TPU v5e hardware constants used by the roofline analysis."""

    PEAK_FLOPS_BF16 = 197e12        # per chip
    HBM_BW = 819e9                  # bytes/s per chip
    ICI_BW = 50e9                   # bytes/s per link (~per axis direction)
    DCN_BW = 6.25e9                 # bytes/s per host NIC (50 Gbit)
    HBM_BYTES = 16 * 1024 ** 3      # 16 GiB per chip
    VMEM_BYTES = 128 * 1024 * 1024


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """(data=16, model=16) single pod; (pod=2, data=16, model=16) multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_test_mesh(shape: Tuple[int, ...] = (2, 4),
                   axes: Tuple[str, ...] = ("data", "model")) -> Mesh:
    """Small mesh for CPU tests (requires the forced device count)."""
    n = math.prod(shape)
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"need {n} devices; set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={n} before importing jax")
    return make_mesh_compat(shape, axes)

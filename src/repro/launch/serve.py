"""Serving driver: continuous-batching engine over synthetic requests.

  PYTHONPATH=src python -m repro.launch.serve \
      --arch phi4-mini-3.8b --smoke --requests 16 --max-new 12 \
      --chunk-tokens 16

Every ``--`` engine flag below is auto-generated from the
:class:`repro.serve.config.EngineConfig` dataclass fields (one flag per
knob, help text included), so the CLI cannot drift from the config API
— including ``--role`` (fused / prefill / decode).  Driver-level
extras:

  * ``--workload`` replaces the uniform synthetic requests with the
    production traffic model (:mod:`repro.serve.workload`): bursty
    diurnal arrivals, lognormal prompts, Zipf outputs, and an
    interactive/batch tier split with per-request TTFT/TPOT SLOs,
  * ``--slo`` is shorthand for ``--policy slo`` — goodput scheduling
    (EDF chunk order, batch shedding, deadline-aware preemption onto
    the pager's QoS windows); combine with ``--workload`` to see the
    per-tier attainment report,
  * ``--disagg`` runs the disaggregated walkthrough in one process: a
    PREFILL and a DECODE engine over ONE shared far tier, driven by
    :func:`repro.serve.disagg.run_disaggregated` (prefill graduates
    each request at its first token and BULK-parks its pages; decode
    adopts it through the resume machinery),
  * ``--role prefill --handoff-spool d.pkl`` runs the prefill half
    alone and spools records *plus their tier entries* to a file;
    ``--role decode --handoff-spool d.pkl`` adopts that spool in a
    separate process — the two-process version of ``--disagg``,
  * ``--dense`` / ``--kernel-impl`` A/B the paged decode path against
    the dense per-slot cache and the kernel backends,
  * ``--trace-out t.json`` writes a Perfetto-loadable timeline of the
    run (AMU transfer spans, pager actions, per-QoS window occupancy,
    request lifecycles — one virtual clock); ``--metrics-out m.json``
    dumps every counter/histogram.  See ``tools/trace_report.py``.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models.model import init_params
from repro.serve.config import add_config_args, config_from_args
from repro.serve.disagg import (make_shared_tier, run_disaggregated,
                                spool_load, spool_save, tier_pager_factory)
from repro.serve.engine import Engine
from repro.serve.workload import WorkloadSpec, generate


def _submit_requests(eng, args, cfg, econf, rng) -> None:
    """Queue the synthetic or workload-model requests on ``eng``."""
    shared = rng.integers(0, cfg.vocab_size, args.shared_prefix)
    if args.workload:
        spec = WorkloadSpec(rate=args.workload_rate,
                            max_prompt=max(4, econf.max_len // 2))
        for wr in generate(args.requests, spec, seed=args.seed):
            plen = min(wr.prompt_len, econf.max_len - wr.output_len - 1)
            prompt = np.concatenate(
                [shared, rng.integers(0, cfg.vocab_size, max(1, plen))])
            kwargs = {}
            if cfg.family == "encdec":
                kwargs["src_embeds"] = rng.standard_normal(
                    (len(prompt), cfg.d_model)).astype(np.float32)
            eng.submit(prompt, max_new_tokens=wr.output_len,
                       tier=wr.tier, ttft_slo=wr.ttft_slo,
                       tpot_slo=wr.tpot_slo, arrival_t=wr.arrival_t,
                       **kwargs)
    else:
        for _ in range(args.requests):
            plen = int(rng.integers(4, min(32, econf.max_len // 2)))
            prompt = np.concatenate(
                [shared, rng.integers(0, cfg.vocab_size, plen)])
            kwargs = {}
            if cfg.family == "encdec":
                kwargs["src_embeds"] = rng.standard_normal(
                    (plen, cfg.d_model)).astype(np.float32)
            eng.submit(prompt, max_new_tokens=args.max_new, **kwargs)


def _report(eng, econf, out, wall) -> None:
    total_new = sum(len(v) for v in out.values())
    lat = [r.done_t - r.submitted_t for r in eng.finished.values()]
    ttft = [r.first_token_t - r.submitted_t for r in eng.finished.values()]
    print(f"[serve] {len(out)} requests, {total_new} tokens in {wall:.2f}s "
          f"({total_new / wall:.1f} tok/s)")
    print(f"[serve] decode steps {eng.stats['steps']} "
          f"(batch occupancy "
          f"{total_new / max(1, eng.stats['steps'] * econf.max_batch):.2f})")
    if lat:
        print(f"[serve] mean TTFT {np.mean(ttft)*1e3:.0f} ms, "
              f"mean latency {np.mean(lat)*1e3:.0f} ms")
    if eng.paging:
        print(f"[serve] page pool {eng.page_pool.n_pages} x "
              f"{eng.page_size} tok: preemptions {eng.stats['preemptions']}, "
              f"resumes {eng.stats['resumes']}, pager {dict(eng.pager.stats)}")
    if eng.chunking:
        print(f"[serve] chunked prefill: {eng.stats['chunks']} chunks of "
              f"<= {eng.chunk_tokens} tok across "
              f"{eng.stats['mixed_steps']} mixed steps "
              f"({eng.stats['prefills']} dense-prefill fallbacks)")
    if eng.speculating:
        s = eng.stats
        mean_k = s["accepted"] / max(1, s["spec_steps"])
        rate = s["accepted"] / max(1, s["drafted"])
        print(f"[serve] speculation k={eng.speculate_k}: "
              f"{s['spec_steps']} verify steps, "
              f"{s['drafted']} drafted / {s['accepted']} accepted "
              f"({rate:.0%}), mean accepted-K {mean_k:.2f}")
    if eng.prefix is not None:
        print(f"[serve] prefix cache: {eng.stats['prefix_hits']} page hits "
              f"({eng.stats['prefix_far_hits']} far), "
              f"{eng.stats['prefix_tokens_saved']} prefill tokens saved, "
              f"{eng.prefix.stats['interned']} pages interned")


def _role_config(econf, role: str, factory, board=None):
    """The fused CLI config re-targeted at one disaggregated role."""
    return dataclasses.replace(
        econf, role=role, handoff=board,
        paging=dataclasses.replace(econf.paging, pager_factory=factory))


def _run_disagg(args, econf, cfg, params, rng):
    """In-process PREFILL + DECODE walkthrough over one shared tier."""
    tier = make_shared_tier()
    factory = tier_pager_factory(tier)
    pre = Engine(cfg, params, _role_config(econf, "prefill", factory))
    dec = Engine(cfg, params, _role_config(econf, "decode", factory,
                                           board=pre.handoff))
    _submit_requests(pre, args, cfg, econf, rng)
    t0 = time.time()
    out = run_disaggregated(pre, dec)
    wall = time.time() - t0
    total_new = sum(len(v) for v in out.values())
    print(f"[serve] disaggregated: {len(out)} requests, {total_new} "
          f"tokens in {wall:.2f}s ({total_new / wall:.1f} tok/s)")
    print(f"[serve] prefill: {pre.stats['handoffs']} handoffs, "
          f"{pre.stats['chunks']} chunks, "
          f"pager {dict(pre.pager.stats)}")
    print(f"[serve] decode:  {dec.stats['handoffs']} adoptions, "
          f"{dec.stats['resumes']} resumes, "
          f"{dec.stats['steps']} steps, pager {dict(dec.pager.stats)}")
    print(f"[serve] shared tier: {dict(tier.stats)}")
    return out


def _run_role(args, econf, cfg, params, rng):
    """One disaggregated half in this process, handing off via a spool
    file (``--role prefill`` writes it, ``--role decode`` adopts it)."""
    if not args.handoff_spool:
        raise SystemExit(
            "--role prefill/decode needs --handoff-spool PATH (or use "
            "--disagg to run both halves in one process)")
    tier = make_shared_tier()
    factory = tier_pager_factory(tier)
    eng = Engine(cfg, params, _role_config(econf, econf.role, factory))
    t0 = time.time()
    if econf.role == "prefill":
        _submit_requests(eng, args, cfg, econf, rng)
        eng.run()
        recs = eng.handoff.poll()
        spool_save(args.handoff_spool, recs, tier)
        wall = time.time() - t0
        print(f"[serve] prefill: {len(recs)} handoff records "
              f"(+ tier entries) spooled to {args.handoff_spool} "
              f"in {wall:.2f}s")
        print(f"[serve] prefill pager {dict(eng.pager.stats)}")
        return {rec.rid: list(rec.generated) for rec in recs}
    recs = spool_load(args.handoff_spool, tier)
    for rec in recs:
        eng.admit_handoff(rec)
    out = eng.run()
    wall = time.time() - t0
    print(f"[serve] decode: adopted {len(recs)} records from "
          f"{args.handoff_spool}")
    _report(eng, econf, out, wall)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12,
                    help="new tokens per request (uniform mode)")
    ap.add_argument("--dense", action="store_true",
                    help="force the dense per-slot KV cache (A/B "
                         "reference for the paged decode path)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many identical prefix tokens to "
                         "every synthetic prompt (system-prompt traffic "
                         "model, makes --prefix-cache visible)")
    ap.add_argument("--workload", action="store_true",
                    help="draw requests from the production traffic "
                         "model (bursty/diurnal arrivals, heavy-tailed "
                         "lengths, interactive/batch tiers with SLOs)")
    ap.add_argument("--workload-rate", type=float, default=200.0,
                    help="mean arrival rate for --workload "
                         "(requests per virtual second)")
    ap.add_argument("--slo", action="store_true",
                    help="shorthand for --policy slo (goodput "
                         "scheduling; pairs with --workload)")
    ap.add_argument("--disagg", action="store_true",
                    help="run the disaggregated walkthrough: a PREFILL "
                         "and a DECODE engine over one shared far tier "
                         "in this process (see --role/--handoff-spool "
                         "for the two-process version)")
    ap.add_argument("--handoff-spool", default=None, metavar="PATH",
                    help="with --role prefill: write handoff records + "
                         "tier entries here after the run; with --role "
                         "decode: adopt them from here")
    ap.add_argument("--seed", type=int, default=0)
    add_config_args(ap)     # one --flag per EngineConfig field
    args = ap.parse_args(argv)

    overrides = {}
    if args.dense:
        overrides["paging_enabled"] = False
    if args.slo:
        overrides["scheduler_policy"] = "slo"
    econf = config_from_args(args, **overrides)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    if args.disagg:
        return _run_disagg(args, econf, cfg, params, rng)
    if econf.role != "fused":
        return _run_role(args, econf, cfg, params, rng)

    eng = Engine(cfg, params, econf)
    t0 = time.time()
    _submit_requests(eng, args, cfg, econf, rng)
    out = eng.run()
    wall = time.time() - t0

    _report(eng, econf, out, wall)
    if econf.paging.offload_finished:
        print(f"[serve] far-tier AMU stats: {dict(eng.far_tier.amu.stats)}")
    if args.workload or args.slo:
        rep = eng.slo_report()
        for tier in ("interactive", "batch"):
            tr = rep[tier]
            print(f"[serve] {tier}: {tr['n']} reqs, "
                  f"attainment {tr['attainment']:.2f}, "
                  f"goodput {tr['goodput']:.1f} tok/s (virtual), "
                  f"ttft p95 {tr['ttft_p95']*1e3:.1f} ms")
        print(f"[serve] scheduler: policy={econf.scheduler.policy} "
              f"shed={eng.stats['shed_admissions']} "
              f"deadline_misses={eng.stats['deadline_misses']}")
    # eng.run() already wrote the files (EngineConfig.obs); just say where
    if econf.obs.trace_out:
        print(f"[serve] trace written to {econf.obs.trace_out} "
              "(load in https://ui.perfetto.dev, or run "
              "tools/trace_report.py on it)")
    if econf.obs.metrics_out:
        print(f"[serve] metrics written to {econf.obs.metrics_out}")
    return out


if __name__ == "__main__":
    main()

"""Serving driver: continuous-batching engine over synthetic requests.

  PYTHONPATH=src python -m repro.launch.serve \
      --arch phi4-mini-3.8b --smoke --requests 16 --max-new 12 \
      --chunk-tokens 16

With ``--chunk-tokens`` admission goes through the chunk queue: prompts
are prefilled in chunks directly on the paged pool layout, fused with
every running slot's decode token in one mixed step (no dense-prefill
bubble).  ``--dense`` / ``--kernel-impl`` A/B the paged decode path
against the dense per-slot cache and the kernel backends.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models.model import init_params
from repro.serve.engine import Engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--offload-finished", action="store_true",
                    help="park finished KV in the host far tier (AMU)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page granularity in token positions")
    ap.add_argument("--device-pages", type=int, default=None,
                    help="device page pool size; below max_batch * "
                         "pages_per_seq the engine oversubscribes and "
                         "preempts (default: no oversubscription)")
    ap.add_argument("--dense", action="store_true",
                    help="force the dense per-slot KV cache (A/B "
                         "reference for the paged decode path)")
    ap.add_argument("--kernel-impl", default="auto",
                    choices=("auto", "pallas", "interpret", "xla"),
                    help="paged-attention backend (auto: Pallas on TPU, "
                         "XLA gather elsewhere)")
    ap.add_argument("--chunk-tokens", type=int, default=0,
                    help="chunked paged prefill: prompt chunk size in "
                         "tokens; 0 = legacy whole-prompt dense prefill "
                         "at admission")
    ap.add_argument("--chunk-slots", type=int, default=2,
                    help="max admitting slots whose chunks fuse into one "
                         "mixed prefill+decode step")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="content-addressed cross-request prefix sharing: "
                         "full prompt pages are interned by rolling hash "
                         "and later requests skip prefill chunks whose "
                         "pages hit (requires --chunk-tokens; dense/moe "
                         "global-attention families)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many identical prefix tokens to "
                         "every synthetic prompt (system-prompt traffic "
                         "model, makes --prefix-cache visible)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = Engine(cfg, params, max_batch=args.max_batch, max_len=args.max_len,
                 offload_finished=args.offload_finished,
                 page_size=args.page_size, device_pages=args.device_pages,
                 paging=not args.dense, kernel_impl=args.kernel_impl,
                 chunk_tokens=args.chunk_tokens or None,
                 chunk_slots=args.chunk_slots,
                 prefix_cache=args.prefix_cache)

    rng = np.random.default_rng(args.seed)
    shared = rng.integers(0, cfg.vocab_size, args.shared_prefix)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(4, min(32, args.max_len // 2)))
        prompt = np.concatenate(
            [shared, rng.integers(0, cfg.vocab_size, plen)])
        kwargs = {}
        if cfg.family == "encdec":
            kwargs["src_embeds"] = rng.standard_normal(
                (plen, cfg.d_model)).astype(np.float32)
        eng.submit(prompt, max_new_tokens=args.max_new, **kwargs)
    out = eng.run()
    wall = time.time() - t0

    total_new = sum(len(v) for v in out.values())
    lat = [r.done_t - r.submitted_t for r in eng.finished.values()]
    ttft = [r.first_token_t - r.submitted_t for r in eng.finished.values()]
    print(f"[serve] {len(out)} requests, {total_new} tokens in {wall:.2f}s "
          f"({total_new / wall:.1f} tok/s)")
    print(f"[serve] decode steps {eng.stats['steps']} "
          f"(batch occupancy {total_new / max(1, eng.stats['steps'] * args.max_batch):.2f})")
    print(f"[serve] mean TTFT {np.mean(ttft)*1e3:.0f} ms, "
          f"mean latency {np.mean(lat)*1e3:.0f} ms")
    if eng.paging:
        print(f"[serve] page pool {eng.page_pool.n_pages} x "
              f"{eng.page_size} tok: preemptions {eng.stats['preemptions']}, "
              f"resumes {eng.stats['resumes']}, pager {dict(eng.pager.stats)}")
    if eng.chunking:
        print(f"[serve] chunked prefill: {eng.stats['chunks']} chunks of "
              f"<= {eng.chunk_tokens} tok across "
              f"{eng.stats['mixed_steps']} mixed steps "
              f"({eng.stats['prefills']} dense-prefill fallbacks)")
    if eng.prefix is not None:
        print(f"[serve] prefix cache: {eng.stats['prefix_hits']} page hits "
              f"({eng.stats['prefix_far_hits']} far), "
              f"{eng.stats['prefix_tokens_saved']} prefill tokens saved, "
              f"{eng.prefix.stats['interned']} pages interned")
    if args.offload_finished:
        print(f"[serve] far-tier AMU stats: {dict(eng.far_tier.amu.stats)}")
    return out


if __name__ == "__main__":
    main()

"""repro.launch"""

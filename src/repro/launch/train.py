"""End-to-end training driver.

Wires every substrate together: config -> mesh -> sharded init -> AMU
prefetching data loader -> pjit train step -> checkpoints (async, atomic,
resumable) -> fault tolerance (heartbeat, straggler detection, retry
with restore).

CPU example (the e2e deliverable — ~100M params, loss visibly drops):

  PYTHONPATH=src python -m repro.launch.train \
      --arch phi4-mini-3.8b --smoke --steps 200 --batch 8 --seq 128

Production shape (on a real pod): drop ``--smoke``, add ``--data-axis 16
--model-axis 16``.
"""

from __future__ import annotations

import argparse
import dataclasses
import math
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import checkpoint as ckpt_mod
from repro.checkpoint.checkpoint import (latest_step, prune, restore, save,
                                         wait_pending)
from repro.configs import get_config, get_smoke
from repro.configs.base import ShapeConfig, TrainConfig
from repro.data.pipeline import make_loader
from repro.dist.steps import make_train_step
from repro.models.model import init_params
from repro.optim.adamw import adamw_init
from repro.runtime.fault_tolerance import (Heartbeat, StragglerDetector)


def build_mesh(data_axis: int, model_axis: int):
    from repro.launch.mesh import make_mesh_compat
    n = data_axis * model_axis
    devs = jax.devices()
    if len(devs) < n:
        raise SystemExit(f"need {n} devices, have {len(devs)} "
                         f"(set --xla_force_host_platform_device_count)")
    return make_mesh_compat((data_axis, model_axis), ("data", "model"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="block",
                    choices=["none", "block", "dots"])
    ap.add_argument("--data-axis", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="inject one failure (fault-tolerance demo)")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    tcfg = TrainConfig(lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(1, args.steps // 10),
                       microbatches=args.microbatches, remat=args.remat,
                       seed=args.seed)
    mesh = build_mesh(args.data_axis, args.model_axis)
    print(f"[train] arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"batch={args.batch}x{args.seq}")

    step_fn, specs = make_train_step(cfg, tcfg, mesh, shape)
    pshard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                    specs["params"])
    oshard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                    specs["opt"])
    bshard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                    specs["batch"])

    with mesh:
        init = jax.jit(lambda k: init_params(cfg, k), out_shardings=pshard)
        params = init(jax.random.PRNGKey(args.seed))
        opt = jax.jit(adamw_init, out_shardings=oshard)(params)

    start = 0
    if args.ckpt_dir and args.resume and latest_step(args.ckpt_dir) is not None:
        (params, opt), meta = restore(
            args.ckpt_dir, target=(params, opt),
            shardings=(pshard, oshard))
        start = meta.get("step", latest_step(args.ckpt_dir))
        print(f"[train] resumed from step {start}")

    loader = make_loader(cfg, shape, seed=args.seed, start_step=start,
                         sharding=None)
    hb = Heartbeat(timeout_s=600.0)
    stragglers = StragglerDetector(threshold=2.5)
    losses = []
    t_start = time.time()
    failed_once = False

    step = start
    for batch in loader:
        if step >= args.steps:
            break
        batch = {k: jax.device_put(jnp.asarray(v), bshard[k])
                 for k, v in batch.items()}
        t0 = time.time()
        if args.fail_at_step == step and not failed_once:
            failed_once = True
            print(f"[train] injecting failure at step {step}")
            if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
                (params, opt), meta = restore(
                    args.ckpt_dir, target=(params, opt),
                    shardings=(pshard, oshard))
                step = meta.get("step", 0)
                print(f"[train] recovered from checkpoint at step {step}")
                continue
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.time() - t0
        rep = stragglers.record(dt)
        if rep is not None:
            print(f"[train] straggler step {rep.step}: {rep.ratio:.1f}x median")
        hb.beat()
        step += 1
        if step % args.log_every == 0:
            tok_s = shape.tokens / dt
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:7.1f} ms/step "
                  f"({tok_s/1e3:.1f}k tok/s)")
        if args.ckpt_dir and step % args.ckpt_every == 0:
            save(args.ckpt_dir, step, (params, opt),
                 metadata={"step": step, "loss": loss}, async_=True)
            prune(args.ckpt_dir, keep=3)
    wait_pending()
    if args.ckpt_dir:
        save(args.ckpt_dir, step, (params, opt),
             metadata={"step": step, "loss": losses[-1] if losses else None})
    wall = time.time() - t_start
    print(f"[train] done: {step - start} steps in {wall:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"stragglers {stragglers.straggler_fraction:.1%}")
    return losses


if __name__ == "__main__":
    main()

"""Disaggregated prefill/decode over ONE shared far-memory tier.

The paper's AMU exists to hide widely-distributed far-memory latency in
disaggregated data centers; this module points the serving engine's
park/resume machinery *across* engines instead of within one.  A
PREFILL-role engine (``EngineRole.PREFILL``) graduates every sequence
at its first token: the finished prompt pages BULK-park into the shared
:class:`~repro.core.offload.FarMemoryTier` together with the aux
residue (the ordinary ``offload_finished`` machinery), and a
:class:`HandoffRecord` is published on a :class:`HandoffBoard`.  A
DECODE-role engine admits the record
(:meth:`~repro.serve.engine.Engine.admit_handoff`): the aux entry is
LATENCY-fetched through the pager's fault-safe
:meth:`~repro.paging.Pager.fetch_keys` helper, the pages register as
PARKED page-table entries, and the request rides the ordinary resume
path into a decode slot — prefix cache and SLO tiers preserved on both
sides.

**Handoff-record invariants** (what the property tests pin down):

  * a record is published only *after* every page astore and the aux
    entry have been issued against the tier — the tier is the single
    source of truth; the record carries identity + SLO contract only,
  * tier entries are discarded only after every transfer verifiably
    landed: the aux entry inside ``fetch_keys(discard_after=True)``
    (a fault raises first, homes intact, so admission retries), the
    page entries at decode-side request completion,
  * rids are globally unique across the pair: the decode engine bumps
    its own rid counter past every handed-off rid,
  * a record whose request already completed at its first token
    (``rec.done``) never enters the decode loop — the decode engine
    finishes it on admission and clears its tier entries.

**Topology** (why three AMUs): each engine's pager owns a private AMU —
a pager forwards completions it does not recognise to *the tier*, not
to other pagers, so two pagers sharing one completion queue would
misroute each other's transfers.  The shared tier gets its own AMU for
the traffic it models itself (aux offload/fetch).  All three ride
simulated backends on virtual time.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Dict, List, Optional

import numpy as np

from repro.core.amu import AMU, SimBackend
from repro.core.offload import FarMemoryTier
from repro.paging import Pager, PagingError
from repro.serve.config import Tier

if TYPE_CHECKING:                         # pragma: no cover - typing only
    from repro.serve.engine import Engine

__all__ = ["HandoffRecord", "HandoffBoard", "make_shared_tier",
           "tier_pager_factory", "run_disaggregated",
           "spool_save", "spool_load"]


@dataclass
class HandoffRecord:
    """Everything a DECODE-role engine needs to adopt a prefilled
    request — *except* the KV and aux state, which live in the shared
    far tier under ``(rid, logical)`` / ``(rid, "aux")`` keys exactly as
    ``offload_finished`` parks them.  The record is deliberately tiny
    (identity, SLO contract, first token): the tier is the data plane,
    the board is the control plane."""

    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    eos_id: Optional[int]
    n_tokens: int                        # prefilled positions in the tier
    n_pages: int                         # page entries under (rid, logical)
    generated: List[int] = field(default_factory=list)   # the first token
    token_ts: List[float] = field(default_factory=list)
    tier: Tier = Tier.INTERACTIVE
    ttft_slo: Optional[float] = None
    tpot_slo: Optional[float] = None
    arrival_t: float = 0.0
    submitted_t: float = 0.0
    first_token_t: float = 0.0
    done: bool = False                   # done under fused semantics already
    src_len: int = 0                     # encdec: true encoder length


class HandoffBoard:
    """The control-plane queue between a PREFILL and a DECODE engine.

    In-process it is a plain FIFO (publish/poll); the launch driver's
    ``--handoff-spool`` flag serialises records through a directory so
    the two engines can live in separate processes.  Counters make the
    publish/consume balance checkable by the engines' invariants."""

    def __init__(self) -> None:
        self._recs: List[HandoffRecord] = []
        self.published = 0
        self.consumed = 0

    def publish(self, rec: HandoffRecord) -> None:
        self._recs.append(rec)
        self.published += 1

    def poll(self) -> List[HandoffRecord]:
        """Drain every pending record (FIFO order)."""
        recs, self._recs = self._recs, []
        self.consumed += len(recs)
        return recs

    def __len__(self) -> int:
        return len(self._recs)


# -- shared-tier wiring -------------------------------------------------------

def make_shared_tier(*, base_latency: float = 1e-6,
                     bandwidth: float = 10e9) -> FarMemoryTier:
    """ONE far tier for a PREFILL/DECODE pair, on its own simulated AMU
    (see the module docstring for why the tier cannot share a pager's
    completion queue)."""
    return FarMemoryTier(AMU(SimBackend(base_latency=base_latency,
                                        bandwidth=bandwidth)))


def tier_pager_factory(tier: FarMemoryTier, *, base_latency: float = 1e-6,
                       bandwidth: float = 10e9, **pager_kw):
    """A ``PagingConfig.pager_factory`` whose pagers park into / fetch
    from the given shared ``tier`` — each pager still owns a private
    simulated AMU for its page traffic.  Extra kwargs (QoS window
    sizes, granularity) pass through to :class:`~repro.paging.Pager`.

    Example::

        tier = make_shared_tier()
        mk = tier_pager_factory(tier)
        pre = Engine(cfg, params, EngineConfig(role="prefill",
                     paging=PagingConfig(pager_factory=mk, ...), ...))
        dec = Engine(cfg, params, EngineConfig(role="decode",
                     handoff=pre.handoff,
                     paging=PagingConfig(pager_factory=mk, ...), ...))
    """
    def factory(pool, table, *, page_nbytes: int) -> Pager:
        amu = AMU(SimBackend(base_latency=base_latency,
                             bandwidth=bandwidth))
        return Pager(pool, table, amu, page_nbytes=page_nbytes,
                     tier=tier, **pager_kw)
    return factory


# -- the disaggregated serving loop ------------------------------------------

def run_disaggregated(prefill: "Engine", decode: "Engine",
                      max_steps: int = 10_000) -> Dict[int, List[int]]:
    """Drive a PREFILL/DECODE engine pair to completion.

    Each iteration interleaves one serving step of each engine (so
    decode overlaps prefill exactly as two racks would run
    concurrently), then drains the handoff board into the decode
    engine's admission queue.  Returns the decode engine's outputs —
    ``{rid: tokens}`` with the prefill-side first token included, so
    the mapping is directly comparable against a fused engine's
    :meth:`~repro.serve.engine.Engine.run`.
    """
    from repro.serve.config import EngineRole
    if prefill.role is not EngineRole.PREFILL or \
            decode.role is not EngineRole.DECODE:
        raise PagingError(
            f"run_disaggregated needs a (PREFILL, DECODE) pair; got "
            f"({prefill.role.value}, {decode.role.value})")
    if prefill.far_tier is not decode.far_tier:
        raise PagingError("the two engines must share one FarMemoryTier "
                          "(build both pagers with tier_pager_factory)")
    board = prefill.handoff
    for _ in range(max_steps):
        if not prefill.drained:
            prefill.step_once()
        # the tier's own AMU retires the aux offload astores prefill
        # just issued (neither pager polls this queue — see topology)
        prefill.far_tier.poll()
        for rec in board.poll():
            decode.admit_handoff(rec)
        if not decode.drained:
            decode.step_once()
        if prefill.drained and decode.drained and not len(board):
            break
    if prefill.drained:
        prefill.check_invariants()
    if decode.drained:
        decode.check_invariants()
    return {r.rid: r.generated for r in decode.finished.values()}


# -- process-separated handoff (launch driver's --handoff-spool) --------------

def spool_save(path: str, recs: List[HandoffRecord],
               tier: FarMemoryTier) -> None:
    """Serialise handoff records *plus their tier entries* into ``path``
    for a separate decode process.  In-process the shared tier is the
    data plane and only records cross the board; across processes the
    spool stands in for the disaggregated memory pool, so each record's
    ``(rid, logical)`` pages and ``(rid, "aux")`` residue travel with
    it."""
    import pickle
    entries: Dict[Any, Any] = {}
    for rec in recs:
        keys = [(rec.rid, logical) for logical in range(rec.n_pages)]
        keys.append((rec.rid, "aux"))
        for key in keys:
            if key in tier:
                entries[key] = (tier.home(key), tier.tokens_of(key))
    with open(path, "wb") as f:
        pickle.dump({"recs": recs, "entries": entries}, f)


def spool_load(path: str, tier: FarMemoryTier) -> List[HandoffRecord]:
    """Load a spool into ``tier`` (entries installed as home copies via
    ``put`` — the transfer they rode is the spool itself) and return the
    records ready for :meth:`~repro.serve.engine.Engine.admit_handoff`."""
    import pickle
    with open(path, "rb") as f:
        blob = pickle.load(f)
    for key, (home, tokens) in blob["entries"].items():
        tier.put(key, home, tokens=tokens)
    return blob["recs"]

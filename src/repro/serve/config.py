"""Engine configuration — the grouped, frozen construction API.

``Engine.__init__`` grew one keyword argument per PR until the flat
signature hit 18 knobs with the SLO scheduler about to push it past 25.
This module is the redesign: one frozen :class:`EngineConfig` dataclass
with grouped sub-configs —

  * :class:`PagingConfig`    — the device page pool + far tier knobs,
  * :class:`ChunkingConfig`  — chunk-queue admission + prefix sharing,
  * :class:`SchedulerConfig` — scheduling policy, virtual clock, and the
    per-request SLO defaults the SLO-aware scheduler consumes,

— and the machinery that keeps every consumer in lockstep with it:

  * ``Engine(cfg, params, EngineConfig(...))`` is the construction path;
    the old flat kwargs are accepted for one release through
    :func:`engine_config_from_kwargs` (DeprecationWarning + translate),
  * ``launch/serve`` *auto-generates* its ``--`` flags from these
    dataclass fields (:func:`add_config_args` /
    :func:`config_from_args`), so the CLI cannot drift from the API,
  * :class:`VirtualClock` is the one injected time source every request
    timestamp goes through — admission, first token, per-token,
    completion — so SLO measurement is deterministic in tests and sims
    (the engine advances it by ``step_dt`` per tick in lockstep with
    the pager's simulated AMU backend).

Example::

    from repro.serve import Engine, EngineConfig, PagingConfig

    eng = Engine(cfg, params, EngineConfig(
        max_batch=4, max_len=256,
        paging=PagingConfig(page_size=16, device_pages=48),
        chunking=ChunkingConfig(chunk_tokens=32),
        scheduler=SchedulerConfig(policy="slo", ttft_slo=0.05)))
"""

from __future__ import annotations

import argparse
import dataclasses
import enum
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple

from repro.paging import WatermarkPolicy

__all__ = [
    "Tier", "EngineRole", "VirtualClock", "PagingConfig",
    "ChunkingConfig", "SchedulerConfig", "SpeculationConfig", "ObsConfig",
    "EngineConfig",
    "engine_config_from_kwargs", "add_config_args", "config_from_args",
]


class Tier(enum.IntEnum):
    """Request priority tier — the production traffic split the SLO
    scheduler maps onto the paper's QoS classes (interactive traffic
    rides LATENCY-QoS far-memory fetches, batch rides BULK/STANDARD)."""

    INTERACTIVE = 0     # tight TTFT/TPOT SLOs; chat-style traffic
    BATCH = 1           # loose SLOs; shed first under overload


class EngineRole(str, enum.Enum):
    """Which half of the serving pipeline this engine runs.

    ``FUSED`` (default) is the classic single-engine pipeline — prefill
    and decode share one mesh and one device pool; bit-identical to the
    pre-role engine.  Under disaggregation (``docs/ARCHITECTURE.md``)
    a ``PREFILL`` engine graduates every request at its first token —
    the finished prompt pages BULK-park into the *shared*
    :class:`~repro.core.offload.FarMemoryTier` and a
    :class:`~repro.serve.disagg.HandoffRecord` is published — and a
    ``DECODE`` engine adopts records via
    :meth:`~repro.serve.engine.Engine.admit_handoff`, LATENCY-fetching
    the parked state through the ordinary resume machinery.  The str
    values double as the auto-generated ``--role`` CLI choices."""

    FUSED = "fused"
    PREFILL = "prefill"
    DECODE = "decode"


class VirtualClock:
    """Deterministic injected clock: ``now`` advances only via
    :meth:`advance`.  The engine advances it by ``step_dt`` per event
    tick, in lockstep with the pager's simulated AMU backend, so every
    request timestamp (arrival, first token, per-token, completion)
    lives on one reproducible time axis.  Pass ``time.monotonic`` as
    ``SchedulerConfig.clock`` to get wall-clock telemetry instead."""

    def __init__(self, start: float = 0.0) -> None:
        self.now = float(start)

    def advance(self, dt: float) -> float:
        self.now += dt
        return self.now

    def __call__(self) -> float:
        return self.now


def _f(default, help_: str, *, cli: bool = True, choices=None, **kw):
    """Field with CLI metadata (help string, generation opt-out)."""
    md = {"help": help_, "cli": cli}
    if choices is not None:
        md["choices"] = choices
    if isinstance(default, (list, dict, set)):
        return field(default_factory=lambda: default, metadata=md)
    return field(default=default, metadata=md, **kw)


@dataclass(frozen=True)
class PagingConfig:
    """Device page pool + far tier: the near/far KV hierarchy knobs."""

    enabled: Optional[bool] = _f(
        None, "paged KV (None: auto — paged when the family has "
        "attention KV); False forces the dense per-slot cache", cli=False)
    page_size: int = _f(16, "KV page granularity in token positions")
    device_pages: Optional[int] = _f(
        None, "device page pool size; below max_batch * pages_per_seq "
        "the engine oversubscribes and preempts")
    hot_tail_pages: int = _f(
        1, "pages of a preempted sequence's hot tail kept pooled")
    offload_finished: bool = _f(
        False, "park finished KV in the host far tier (AMU)")
    watermark: Optional[WatermarkPolicy] = _f(
        None, "free-page watermark policy object", cli=False)
    pager_factory: Optional[Callable] = _f(
        None, "custom Pager factory (tests: simulated-latency AMU)",
        cli=False)


@dataclass(frozen=True)
class ChunkingConfig:
    """Chunk-queue admission (chunked paged prefill) + prefix sharing."""

    chunk_tokens: Optional[int] = _f(
        None, "chunked paged prefill: prompt chunk size in tokens; "
        "unset = legacy whole-prompt dense prefill at admission")
    chunk_slots: int = _f(
        2, "max admitting slots whose chunks fuse into one mixed "
        "prefill+decode step")
    prefix_cache: bool = _f(
        False, "content-addressed cross-request prefix sharing "
        "(requires chunk_tokens; dense/moe global-attention families)")


@dataclass(frozen=True)
class SpeculationConfig:
    """Draft-free self-speculative decode (prompt-lookup verify-K).

    With ``speculate_k > 0`` the paged engine drafts up to K tokens per
    slot from the slot's own committed history
    (:class:`~repro.serve.speculate.NgramProposer`) and scores them all
    in one jitted verify step; greedy acceptance keeps the emitted
    stream token-exact with single-step decode, so this is purely a
    throughput knob.  Requires the paged dense/moe global-attention
    engine (same gate as prefix sharing)."""

    speculate_k: int = _f(
        0, "speculative decode: max drafted tokens per slot per step "
        "(0 = off; K drafts verify in one multi-query step)")
    speculate_ngram: int = _f(
        3, "prompt-lookup n-gram length the proposer matches on")
    proposer_factory: Optional[Callable] = _f(
        None, "custom draft proposer factory (tests: oracle/adversarial "
        "proposers); None = NgramProposer(speculate_ngram, speculate_k)",
        cli=False)


@dataclass(frozen=True)
class SchedulerConfig:
    """Scheduling policy + the SLO knobs the goodput scheduler consumes.

    ``policy="watermark"`` is the PR-4 scheduler: FIFO admission,
    newest-admitted-first preemption, admit-order chunk selection —
    utilization-maximizing, SLO-blind.  ``policy="slo"`` makes every
    one of those decisions deadline-aware: admission sheds batch-tier
    load first, preemption evicts the slot whose SLO is already blown
    or furthest from its deadline, chunk selection runs earliest
    TTFT deadline first, and the priority tier maps onto the pager's
    QoS windows (interactive fetches ride LATENCY, batch parks ride
    BULK) — §2.2 MACR QoS applied at request granularity."""

    policy: str = _f("watermark", "scheduling policy",
                     choices=("watermark", "slo"))
    step_dt: float = _f(
        1e-3, "virtual seconds one engine tick advances the clock "
        "(and the pager's simulated AMU backend)")
    ttft_slo: Optional[float] = _f(
        None, "default time-to-first-token SLO (virtual s) stamped on "
        "requests submitted without one")
    tpot_slo: Optional[float] = _f(
        None, "default time-per-output-token SLO (virtual s) stamped "
        "on requests submitted without one")
    batch_headroom: int = _f(
        2, "extra free pages (beyond the low watermark) a BATCH-tier "
        "admission must leave — the load-shedding margin")
    clock: Optional[Callable[[], float]] = _f(
        None, "injected clock; None = engine-owned VirtualClock "
        "advanced step_dt per tick (deterministic telemetry)", cli=False)


@dataclass(frozen=True)
class ObsConfig:
    """Telemetry (:mod:`repro.obs`): the tracer rides the engine's one
    :class:`VirtualClock`, so AMU transfer spans, pager actions, and
    request lifecycle tracks share a single deterministic time axis.
    Tracing is off by default and costs one branch per call site when
    off; ``trace_out``/``metrics_out`` imply enabling it and write the
    Perfetto-loadable timeline / flat metrics JSON when ``run()``
    returns."""

    trace: bool = _f(
        False, "enable span/instant tracing even without --trace-out "
        "(events stay in memory on engine.tracer)")
    trace_out: Optional[str] = _f(
        None, "write a Chrome-trace/Perfetto JSON timeline here after "
        "run() (implies tracing on)")
    metrics_out: Optional[str] = _f(
        None, "write the flat metrics JSON (counters + gauges + "
        "histogram percentiles) here after run()")

    @property
    def tracing(self) -> bool:
        return bool(self.trace or self.trace_out)


@dataclass(frozen=True)
class EngineConfig:
    """Everything ``Engine.__init__`` takes besides the model + params."""

    max_batch: int = _f(4, "decode slots (fixed compiled batch)")
    max_len: int = _f(256, "per-sequence token capacity")
    prefill_buckets: Tuple[int, ...] = _f(
        (32, 64, 128, 256), "dense-prefill padding buckets "
        "(comma-separated on the CLI)")
    greedy: bool = _f(True, "greedy sampling", cli=False)
    kernel_impl: str = _f(
        "auto", "paged-attention backend",
        choices=("auto", "pallas", "interpret", "xla"))
    mesh: Any = _f(None, "jax device mesh for the sharded step",
                   cli=False)
    role: str = _f(
        "fused", "engine role: fused single-engine pipeline, or one "
        "half of a disaggregated prefill/decode pair over a shared "
        "far tier", choices=("fused", "prefill", "decode"))
    handoff: Any = _f(
        None, "HandoffBoard shared between a PREFILL and a DECODE "
        "engine (a PREFILL engine creates its own when None)",
        cli=False)
    paging: PagingConfig = field(default_factory=PagingConfig,
                                 metadata={"cli": True})
    chunking: ChunkingConfig = field(default_factory=ChunkingConfig,
                                     metadata={"cli": True})
    scheduler: SchedulerConfig = field(default_factory=SchedulerConfig,
                                       metadata={"cli": True})
    speculation: SpeculationConfig = field(
        default_factory=SpeculationConfig, metadata={"cli": True})
    obs: ObsConfig = field(default_factory=ObsConfig,
                           metadata={"cli": True})


# -- legacy flat-kwarg shim ---------------------------------------------------

#: old Engine.__init__ kwarg -> (sub-config attr | None, field name)
_LEGACY_MAP = {
    "max_batch": (None, "max_batch"),
    "max_len": (None, "max_len"),
    "prefill_buckets": (None, "prefill_buckets"),
    "greedy": (None, "greedy"),
    "mesh": (None, "mesh"),
    "kernel_impl": (None, "kernel_impl"),
    "paging": ("paging", "enabled"),
    "page_size": ("paging", "page_size"),
    "device_pages": ("paging", "device_pages"),
    "hot_tail_pages": ("paging", "hot_tail_pages"),
    "offload_finished": ("paging", "offload_finished"),
    "watermark": ("paging", "watermark"),
    "pager_factory": ("paging", "pager_factory"),
    "chunk_tokens": ("chunking", "chunk_tokens"),
    "chunk_slots": ("chunking", "chunk_slots"),
    "prefix_cache": ("chunking", "prefix_cache"),
    "step_dt": ("scheduler", "step_dt"),
    "clock": ("scheduler", "clock"),
}


def engine_config_from_kwargs(base: Optional[EngineConfig] = None,
                              **kwargs) -> EngineConfig:
    """Translate the pre-EngineConfig flat kwargs (one DeprecationWarning
    per construction); unknown names raise TypeError like any bad kwarg."""
    unknown = set(kwargs) - set(_LEGACY_MAP)
    if unknown:
        raise TypeError(
            f"Engine() got unexpected keyword arguments {sorted(unknown)}; "
            "see repro.serve.config.EngineConfig for the supported fields")
    warnings.warn(
        "flat Engine(**kwargs) construction is deprecated; build an "
        "EngineConfig (repro.serve.config) instead: "
        "Engine(cfg, params, EngineConfig(...))",
        DeprecationWarning, stacklevel=3)
    cfg = base or EngineConfig()
    top: dict = {}
    subs: dict = {"paging": {}, "chunking": {}, "scheduler": {}}
    for name, value in kwargs.items():
        group, fname = _LEGACY_MAP[name]
        if group is None:
            top[fname] = value
        else:
            subs[group][fname] = value
    for group, vals in subs.items():
        if vals:
            top[group] = dataclasses.replace(getattr(cfg, group), **vals)
    return dataclasses.replace(cfg, **top)


# -- CLI auto-generation ------------------------------------------------------
# launch/serve builds its --flags from the dataclass fields above, so a
# new knob lands on the CLI (with its help string) the moment it lands
# in the config — the API and the CLI cannot drift.

_GROUPS = ("paging", "chunking", "scheduler", "speculation", "obs")


def _cli_fields(dc_type):
    for fld in dataclasses.fields(dc_type):
        md = fld.metadata
        if not md.get("cli", False):
            continue
        if fld.name in _GROUPS:
            continue
        yield fld


def _scalar_type(fld):
    """CLI parse type for a field (Optional[X] unwraps to X)."""
    t = fld.type
    for base in ("int", "float", "str", "bool"):
        if t == base or t.startswith(f"Optional[{base}]"):
            return {"int": int, "float": float,
                    "str": str, "bool": bool}[base]
    if "Tuple[int" in t:
        return lambda s: tuple(int(x) for x in s.split(","))
    raise TypeError(f"field {fld.name}: no CLI mapping for type {t!r}")


def _default_of(fld):
    if fld.default is not dataclasses.MISSING:
        return fld.default
    return fld.default_factory()       # pragma: no cover - no such field


def add_config_args(parser: argparse.ArgumentParser) -> None:
    """Add one ``--flag`` per CLI-visible :class:`EngineConfig` field
    (top level + every sub-config; names are unique by construction)."""
    seen = set()
    for dc in (EngineConfig, PagingConfig, ChunkingConfig,
               SchedulerConfig, SpeculationConfig, ObsConfig):
        for fld in _cli_fields(dc):
            if fld.name in seen:
                raise TypeError(
                    f"duplicate CLI field name {fld.name!r} across "
                    "EngineConfig sub-configs")
            seen.add(fld.name)
            flag = "--" + fld.name.replace("_", "-")
            typ = _scalar_type(fld)
            default = _default_of(fld)
            help_ = fld.metadata.get("help", "")
            if typ is bool:
                parser.add_argument(flag, action="store_true",
                                    default=bool(default), help=help_)
            elif "Tuple" in fld.type:
                parser.add_argument(
                    flag, type=typ,
                    default=default, metavar="N,N,...",
                    help=help_ + f" (default {','.join(map(str, default))})")
            else:
                kw = {}
                if fld.metadata.get("choices"):
                    kw["choices"] = fld.metadata["choices"]
                parser.add_argument(flag, type=typ, default=default,
                                    help=help_ +
                                    (f" (default {default})"
                                     if default is not None else ""),
                                    **kw)


def config_from_args(args: argparse.Namespace, **overrides) -> EngineConfig:
    """Rebuild the nested :class:`EngineConfig` from parsed auto-generated
    flags; ``overrides`` paths like ``paging_enabled=False`` win last."""
    def build(dc_type):
        vals = {}
        for fld in _cli_fields(dc_type):
            if hasattr(args, fld.name):
                vals[fld.name] = getattr(args, fld.name)
        return vals

    paging = PagingConfig(**build(PagingConfig))
    chunking = ChunkingConfig(**build(ChunkingConfig))
    scheduler = SchedulerConfig(**build(SchedulerConfig))
    speculation = SpeculationConfig(**build(SpeculationConfig))
    obs = ObsConfig(**build(ObsConfig))
    cfg = EngineConfig(paging=paging, chunking=chunking,
                       scheduler=scheduler, speculation=speculation,
                       obs=obs, **build(EngineConfig))
    for path, value in overrides.items():
        group, _, fname = path.partition("_")
        if group in _GROUPS and fname:
            sub = dataclasses.replace(getattr(cfg, group), **{fname: value})
            cfg = dataclasses.replace(cfg, **{group: sub})
        else:
            cfg = dataclasses.replace(cfg, **{path: value})
    return cfg

"""Serving-side KV management: slot pool + host far-tier via the AMU.

The device cache is the model's stacked ``Cache`` (L x B_slots x ...).
This module adds what a serving deployment needs around it:

  * :class:`SlotPool` — fixed decode slots, alloc/free,
  * slot extract/insert — move one sequence's cache state between the
    batched device cache and a standalone per-sequence tree,
  * :class:`KVOffloadTier` — park preempted/finished sequences' KV in
    host memory (``astore``) and bring them back with LATENCY-QoS
    ``aload`` when rescheduled: the paper's far-memory tier applied to
    KV paging.  Granularity is one sequence's whole KV (the AMU's
    variable-granularity knob: one big request instead of thousands of
    cache lines).
"""

from __future__ import annotations

from typing import Any, Dict, Hashable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.amu import AMU, AccessConfig, QoS
from repro.core.offload import FarMemoryTier
from repro.models.model import Cache

__all__ = ["SlotPool", "extract_slot", "insert_slot", "KVOffloadTier"]


class SlotPool:
    def __init__(self, n_slots: int):
        self.free: List[int] = list(range(n_slots))
        self.n_slots = n_slots

    def alloc(self) -> Optional[int]:
        return self.free.pop(0) if self.free else None

    def release(self, slot: int) -> None:
        assert 0 <= slot < self.n_slots and slot not in self.free
        self.free.append(slot)
        self.free.sort()

    @property
    def n_free(self) -> int:
        return len(self.free)


def _is_batched_axis1(leaf, n_slots: int) -> bool:
    return leaf.ndim >= 2 and leaf.shape[1] == n_slots


def _is_batched_axis0(leaf, n_slots: int) -> bool:
    return leaf.ndim >= 1 and leaf.shape[0] == n_slots


def extract_slot(cache: Cache, slot: int, n_slots: int):
    """Pull one sequence's state out of the batched cache (keeps dims)."""
    def ex(leaf):
        if _is_batched_axis1(leaf, n_slots):
            return leaf[:, slot:slot + 1]
        if _is_batched_axis0(leaf, n_slots):
            return leaf[slot:slot + 1]
        return leaf
    return jax.tree_util.tree_map(ex, cache)


def insert_slot(cache: Cache, single, slot: int, n_slots: int) -> Cache:
    """Write a single-sequence cache tree (batch dim 1) into ``slot``."""
    def ins(dst, src):
        if _is_batched_axis1(dst, n_slots):
            return jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), slot, axis=1)
        if _is_batched_axis0(dst, n_slots):
            return jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), slot, axis=0)
        return dst
    return jax.tree_util.tree_map(ins, cache, single)


class KVOffloadTier:
    """Host-memory parking lot for per-sequence cache states."""

    def __init__(self, amu: Optional[AMU] = None):
        self.tier = FarMemoryTier(amu or AMU(max_outstanding=32),
                                  fetch_qos=QoS.LATENCY)
        self.parked: Dict[Hashable, Any] = {}

    def park(self, key: Hashable, single_cache) -> None:
        """astore a sequence's cache to the far tier (non-blocking)."""
        host = jax.tree_util.tree_map(np.asarray, single_cache)
        self.parked[key] = jax.tree_util.tree_structure(host)
        for i, leaf in enumerate(jax.tree_util.tree_leaves(host)):
            self.tier.offload((key, i), leaf)

    def prefetch(self, key: Hashable) -> None:
        """Begin aload of every leaf (call when the scheduler plans to
        resume ``key`` — latency hides behind the current decode step)."""
        i = 0
        while (key, i) in dict.fromkeys(self.tier.keys()):
            self.tier.prefetch((key, i))
            i += 1

    def fetch(self, key: Hashable):
        """Blocking: reassemble the parked cache tree."""
        treedef = self.parked.pop(key)
        leaves = []
        i = 0
        while True:
            try:
                leaves.append(self.tier.get((key, i)))
            except KeyError:
                break
            i += 1
        return jax.tree_util.tree_unflatten(treedef, leaves)

"""Serving-side KV management: slot pool, page split/join helpers.

On the hot path the engine's device cache is the *paged*
:class:`~repro.models.model.PagedCache` (pool frames + page tables) and
the KV never leaves its frames; this module is the bookkeeping around
it and the surviving dense paths:

  * :class:`SlotPool` — fixed decode slots, heap-backed alloc/free,
  * :func:`extract_aux_slot` / :func:`insert_aux_slot` — the *non-KV*
    park payload (SSM state, cross-attn KV, positions): the only
    per-sequence state that still moves densely, because it is tiny,
  * :func:`extract_slot` / :func:`insert_slot` — whole-slot dense
    moves; alive only on the ``paging=False`` fallback engine (never on
    admit/preempt/resume),
  * :func:`split_kv_pages` / :func:`join_kv_pages` — carve a
    single-sequence cache into ``repro.paging`` page-granularity far-
    tier payloads (and back, bit-exact): the transfer unit the engine's
    pager moves, replacing the seed's one-request-per-whole-sequence
    pattern the paper argues against (§1).

Finished-sequence offload lives in the engine itself now: finished KV
parks page-by-page through the pager into THE single
:class:`~repro.core.offload.FarMemoryTier` (the sequence-granularity
``KVOffloadTier`` side store this module used to carry is gone), and
``Engine.fetch_finished`` reassembles it with overlapped LATENCY
aloads.
"""

from __future__ import annotations

import heapq
from typing import Any, Dict, Hashable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.amu import AMUError
from repro.models.model import Cache
from repro.paging.page_table import pages_for

__all__ = ["SlotPool", "extract_slot", "insert_slot", "extract_aux_slot",
           "insert_aux_slot", "split_kv_pages", "join_kv_pages"]


class SlotPool:
    """Fixed decode slots.  The free list is a min-heap so alloc/release
    are O(log n) (the seed's sort-per-free was O(n log n) per release,
    O(n² log n) across a drain) and ids hand out lowest-first.

    Example::

        pool = SlotPool(4)
        slot = pool.alloc()        # -> 0 (lowest first)
        pool.release(slot)
        pool.release(slot)         # raises AMUError (double release)
    """

    def __init__(self, n_slots: int):
        self.free: List[int] = list(range(n_slots))
        heapq.heapify(self.free)
        self._is_free = [True] * n_slots
        self.n_slots = n_slots

    def alloc(self) -> Optional[int]:
        if not self.free:
            return None
        slot = heapq.heappop(self.free)
        self._is_free[slot] = False
        return slot

    def release(self, slot: int) -> None:
        if not 0 <= slot < self.n_slots:
            raise AMUError(f"release of invalid slot {slot} "
                           f"(pool has {self.n_slots})")
        if self._is_free[slot]:
            raise AMUError(f"double release of slot {slot}")
        self._is_free[slot] = True
        heapq.heappush(self.free, slot)

    @property
    def n_free(self) -> int:
        return len(self.free)


def _is_batched_axis1(leaf, n_slots: int) -> bool:
    return leaf.ndim >= 2 and leaf.shape[1] == n_slots


def _is_batched_axis0(leaf, n_slots: int) -> bool:
    return leaf.ndim >= 1 and leaf.shape[0] == n_slots


def extract_slot(cache: Cache, slot: int, n_slots: int):
    """Pull one sequence's state out of the batched cache (keeps dims)."""
    def ex(leaf):
        if _is_batched_axis1(leaf, n_slots):
            return leaf[:, slot:slot + 1]
        if _is_batched_axis0(leaf, n_slots):
            return leaf[slot:slot + 1]
        return leaf
    return jax.tree_util.tree_map(ex, cache)


def insert_slot(cache: Cache, single, slot: int, n_slots: int) -> Cache:
    """Write a single-sequence cache tree (batch dim 1) into ``slot``."""
    def ins(dst, src):
        if _is_batched_axis1(dst, n_slots):
            return jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), slot, axis=1)
        if _is_batched_axis0(dst, n_slots):
            return jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), slot, axis=0)
        return dst
    return jax.tree_util.tree_map(ins, cache, single)


def extract_aux_slot(cache, slot: int, n_slots: int) -> Dict[str, Any]:
    """Pull one sequence's *non-KV* state (ssm, cross, pos) to the host.

    The paged engine's park payload: under the pool layout the KV never
    leaves its page frames, so preemption only carries this tiny
    remainder (plus per-page far-tier transfers) — nothing dense is
    ever re-materialised.
    """
    def ex(leaf):
        if _is_batched_axis1(leaf, n_slots):
            return np.asarray(leaf[:, slot:slot + 1])
        if _is_batched_axis0(leaf, n_slots):
            return np.asarray(leaf[slot:slot + 1])
        return np.asarray(leaf)
    return {
        "ssm": jax.tree_util.tree_map(ex, cache.ssm),
        "cross": jax.tree_util.tree_map(ex, cache.cross),
        "pos": np.asarray(cache.pos[slot:slot + 1]),
    }


def insert_aux_slot(cache, aux: Dict[str, Any], slot: int, n_slots: int):
    """Write an :func:`extract_aux_slot` payload back into ``slot``."""
    def ins(dst, src):
        src = jnp.asarray(src)
        if _is_batched_axis1(dst, n_slots):
            return jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), slot, axis=1)
        if _is_batched_axis0(dst, n_slots):
            return jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), slot, axis=0)
        return dst
    return cache._replace(
        ssm=jax.tree_util.tree_map(ins, cache.ssm, aux["ssm"]),
        cross=jax.tree_util.tree_map(ins, cache.cross, aux["cross"]),
        pos=jax.lax.dynamic_update_slice_in_dim(
            cache.pos, jnp.asarray(aux["pos"]).astype(cache.pos.dtype),
            slot, axis=0))


def split_kv_pages(single: Cache, page_size: int, n_tokens: int
                   ) -> Tuple[Cache, List[Dict[str, np.ndarray]]]:
    """Carve a single-sequence cache into (residue, KV pages).

    Page ``i`` holds token positions ``[i*page_size, (i+1)*page_size)``
    of the stacked k/v — shape ``(L, 1, page_size, Hkv, D)`` each — as
    host numpy (the far-tier representation).  The residue is the cache
    tree with k/v zeroed out: SSM state, cross-attn KV, positions and
    ring metadata, all tiny relative to the KV and parked whole.

    ``n_tokens`` is clamped to the KV token axis (SWA ring buffers hold
    at most ``window`` positions regardless of absolute position).
    """
    k, v = single.kv["k"], single.kv["v"]
    valid = min(n_tokens, int(k.shape[2]))
    n_pages = pages_for(valid, page_size)
    k_np = np.asarray(k)
    v_np = np.asarray(v)
    pages = []
    for i in range(n_pages):
        # clamp the last page to ``valid`` — clamping to the cache
        # capacity instead silently shipped up to a page of stale tail
        # content to the far tier whenever valid % page_size != 0
        lo, hi = i * page_size, min((i + 1) * page_size, valid)
        pages.append({"k": k_np[:, :, lo:hi].copy(),
                      "v": v_np[:, :, lo:hi].copy()})
    residue = single._replace(kv=dict(
        single.kv, k=np.zeros_like(k_np[:, :, :0]),
        v=np.zeros_like(v_np[:, :, :0])))
    residue = jax.tree_util.tree_map(np.asarray, residue)
    return residue, pages


def join_kv_pages(residue: Cache, pages: List[Dict[str, np.ndarray]],
                  token_capacity: int) -> Cache:
    """Inverse of :func:`split_kv_pages`: reassemble the single-sequence
    cache with its KV materialised from pages into a ``token_capacity``-
    long buffer (positions past the last page stay zero — never
    attended, exactly as after prefill)."""
    L, B, _, Hkv, D = residue.kv["k"].shape
    kdt = residue.kv["k"].dtype
    total = sum(pg["k"].shape[2] for pg in pages)
    if total > token_capacity:
        raise AMUError(f"pages hold {total} tokens > capacity {token_capacity}")
    k = np.zeros((L, B, token_capacity, Hkv, D), kdt)
    v = np.zeros((L, B, token_capacity, Hkv, D), residue.kv["v"].dtype)
    off = 0
    for pg in pages:
        n = pg["k"].shape[2]
        k[:, :, off:off + n] = pg["k"]
        v[:, :, off:off + n] = pg["v"]
        off += n
    return residue._replace(kv=dict(residue.kv, k=k, v=v))

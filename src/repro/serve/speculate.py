"""Draft-free self-speculative proposal: prompt-lookup n-gram drafting.

The decode roofline says a paged decode step is bandwidth-bound — the
page fetches and weight streams dominate, the per-token FLOPs are
noise.  Verifying K drafted tokens through the multi-query kernel
therefore rides the SAME page traffic as one decode step (the paper's
amortise-per-access-overhead lever at the serving layer).  All that is
missing is a source of drafts that costs no extra model: this module
drafts from the sequence's own history ("prompt lookup"): if the last
``n`` committed tokens also occur earlier in the prompt + generation,
the tokens that followed that earlier occurrence are a cheap guess at
what greedy decode emits next.  Repetitive traffic (templated prompts,
quoting, code) accepts most drafts; adversarial traffic rejects at
position 0 and degenerates to ordinary decode — correctness never
depends on acceptance, only throughput does.

N-grams are content-addressed exactly like ``paging.prefix_cache``
pages: a blake2b digest of the int32 token ids (the same rolling-hash
machinery, at n-gram instead of page granularity), so the per-request
index is a flat ``digest -> end position`` dict that grows
incrementally as tokens commit — no rescan of the resident pages, and
a collision-free match for any realistic vocabulary.

The proposer is deliberately host-side and stateful-per-request: the
engine calls :meth:`NgramProposer.propose` with the slot's committed
history before each speculative step and :meth:`NgramProposer.drop`
when the request finishes or is evicted.  History is append-only
(rejected drafts are never committed), so index entries never go
stale — a parked/resumed request keeps its index.
"""

from __future__ import annotations

import hashlib
from typing import Dict, Hashable, List, Sequence

import numpy as np

__all__ = ["NgramProposer", "ngram_key"]


def ngram_key(tokens: Sequence[int]) -> bytes:
    """Content address of one n-gram — the ``prefix_cache.page_hashes``
    digest (blake2b-16 over int32 ids) applied at n-gram granularity."""
    arr = np.ascontiguousarray(tokens, dtype=np.int32)
    return hashlib.blake2b(arr.tobytes(), digest_size=16).digest()


class _Index:
    """Incremental n-gram index over one request's committed history."""

    __slots__ = ("upto", "last")

    def __init__(self) -> None:
        self.upto = 0              # history length already indexed
        self.last: Dict[bytes, int] = {}   # digest -> latest end position


class NgramProposer:
    """Prompt-lookup drafting: propose up to ``k`` tokens per slot by
    matching the history's trailing ``n``-gram against its most recent
    earlier occurrence.

    >>> p = NgramProposer(n=2, k=3)
    >>> p.propose("r", [5, 6, 7, 8, 5, 6])      # ...5,6 seen before -> 7,8,5
    [7, 8, 5]
    >>> p.propose("r", [1, 2, 3, 4, 5, 6])      # no earlier 5,6
    []
    """

    def __init__(self, n: int = 3, k: int = 4) -> None:
        if n < 1 or k < 1:
            raise ValueError(f"NgramProposer needs n >= 1, k >= 1 "
                             f"(got n={n}, k={k})")
        self.n = int(n)
        self.k = int(k)
        self._idx: Dict[Hashable, _Index] = {}

    def propose(self, rid: Hashable, history: Sequence[int]) -> List[int]:
        """Draft up to ``k`` tokens following ``history``.

        ``history`` must be the slot's full committed context (prompt +
        generated) and append-only across calls for the same ``rid``.
        Returns ``[]`` when the trailing n-gram has no earlier
        occurrence (or history is shorter than ``n``) — the engine then
        runs this slot as plain decode.
        """
        n = self.n
        hist = list(history)
        L = len(hist)
        idx = self._idx.setdefault(rid, _Index())
        # index every n-gram ending at positions (n .. L-1]; the one
        # ending at L is looked up first, then indexed, so a match is
        # always a strictly earlier occurrence
        for end in range(max(n, idx.upto + 1), L):
            idx.last[ngram_key(hist[end - n:end])] = end
        idx.upto = max(idx.upto, L - 1 if L else 0)
        if L < n:
            return []
        key = ngram_key(hist[L - n:])
        match = idx.last.get(key)
        idx.last[key] = L
        idx.upto = L
        if match is None:
            return []
        return hist[match:match + self.k]

    def drop(self, rid: Hashable) -> None:
        """Forget a finished/evicted request's index."""
        self._idx.pop(rid, None)

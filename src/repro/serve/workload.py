"""Production traffic model for the serving engine.

Serving papers (and the AMU follow-up's massive-parallelism argument)
agree on the shape of real inference traffic, and none of it looks like
the uniform back-to-back submissions our tests generate:

  * **bursty arrivals** — requests cluster; a Poisson process is too
    smooth.  We draw interarrival gaps from a Gamma distribution with
    shape < 1 (coefficient of variation > 1), the standard burstiness
    knob: the same mean rate arrives as quiet stretches punctuated by
    pile-ups that stress admission and the pager's balance loop.
  * **diurnal modulation** — the mean rate itself swings sinusoidally
    over a "day", so a sweep crosses under- and over-provisioned
    regimes in one trace.
  * **heavy-tailed lengths** — prompt lengths are lognormal (most
    prompts short, a fat tail of huge ones), output lengths Zipf-like
    (many 1–10 token answers, occasional essays).  Tails are what make
    fixed-slot scheduling hard: one essay pins pages for thousands of
    ticks.
  * **priority tiers** — interactive (chat) traffic with tight
    TTFT/TPOT SLOs mixed with batch (summarisation, eval) traffic that
    only cares about completion.  The scheduler maps the tier onto the
    pager's QoS windows.

:func:`generate` returns a list of :class:`WorkloadRequest` sorted by
arrival time, deterministically from a seed — the same trace feeds the
engine, the ``simulate_slo_schedule`` virtual-clock model, and the
benchmark sweep, so their numbers are comparable.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.serve.config import Tier

__all__ = ["WorkloadRequest", "WorkloadSpec", "generate"]


@dataclass(frozen=True)
class WorkloadRequest:
    """One arrival in the trace (everything the engine's ``submit``
    needs, plus the ground-truth SLOs attainment is judged against)."""

    rid: int
    arrival_t: float            # virtual seconds from trace start
    prompt_len: int
    output_len: int
    tier: Tier
    ttft_slo: Optional[float]   # None: unconstrained (batch completion)
    tpot_slo: Optional[float]


@dataclass(frozen=True)
class WorkloadSpec:
    """Knobs of the traffic model (defaults give a chat-heavy mix).

    ``rate`` is the *mean* arrival rate (req per virtual second);
    ``burstiness`` > 1 raises the interarrival coefficient of
    variation (Gamma shape = 1/burstiness²); ``diurnal_amp`` in [0, 1)
    scales the sinusoidal swing of the rate over ``diurnal_period``.
    Prompt lengths are lognormal(``prompt_median``, ``prompt_sigma``)
    clipped to [1, ``max_prompt``]; output lengths are Zipf(``zipf_a``)
    shifted to a minimum of ``min_output`` and clipped to
    ``max_output``.  ``interactive_frac`` of requests are INTERACTIVE
    with (``ttft_slo``, ``tpot_slo``); the rest are BATCH with the
    (looser, possibly None) ``batch_ttft_slo``/``batch_tpot_slo``.
    """

    rate: float = 200.0
    burstiness: float = 2.0
    diurnal_amp: float = 0.5
    diurnal_period: float = 2.0
    prompt_median: float = 24.0
    prompt_sigma: float = 0.7
    max_prompt: int = 192
    zipf_a: float = 1.8
    min_output: int = 2
    max_output: int = 48
    interactive_frac: float = 0.5
    ttft_slo: float = 0.020
    tpot_slo: float = 0.004
    batch_ttft_slo: Optional[float] = None
    batch_tpot_slo: Optional[float] = None


def generate(n: int, spec: WorkloadSpec = WorkloadSpec(),
             seed: int = 0) -> List[WorkloadRequest]:
    """Draw ``n`` arrivals from the traffic model (sorted by time).

    Example::

        trace = generate(64, WorkloadSpec(rate=500.0), seed=1)
        for wr in trace:
            eng.submit(np.arange(wr.prompt_len),
                       max_new_tokens=wr.output_len, tier=wr.tier,
                       ttft_slo=wr.ttft_slo, tpot_slo=wr.tpot_slo,
                       arrival_t=wr.arrival_t)
    """
    if n <= 0:
        return []
    rng = np.random.default_rng(seed)

    # bursty interarrivals: Gamma with mean 1/rate, CV = burstiness
    cv2 = max(1e-6, float(spec.burstiness)) ** 2
    shape = 1.0 / cv2
    gaps = rng.gamma(shape, cv2 / spec.rate, size=n)
    t = np.cumsum(gaps)

    # diurnal modulation by time-warping: where the sinusoidal rate is
    # high, time compresses (arrivals bunch); where low, it stretches.
    if spec.diurnal_amp:
        a = min(0.95, max(0.0, float(spec.diurnal_amp)))
        w = 2 * np.pi / spec.diurnal_period
        # inverse of the integrated rate  Λ(t) = t - (a/w) cos-term
        t = t - (a / w) * np.sin(w * t)

    plen = np.exp(rng.normal(np.log(spec.prompt_median),
                             spec.prompt_sigma, size=n))
    plen = np.clip(plen.round().astype(int), 1, spec.max_prompt)

    out = spec.min_output - 1 + rng.zipf(spec.zipf_a, size=n)
    out = np.clip(out, spec.min_output, spec.max_output)

    inter = rng.random(n) < spec.interactive_frac

    reqs = []
    for i in range(n):
        if inter[i]:
            tier, ttft, tpot = Tier.INTERACTIVE, spec.ttft_slo, spec.tpot_slo
        else:
            tier, ttft, tpot = (Tier.BATCH, spec.batch_ttft_slo,
                                spec.batch_tpot_slo)
        reqs.append(WorkloadRequest(
            rid=i, arrival_t=float(t[i]), prompt_len=int(plen[i]),
            output_len=int(out[i]), tier=tier,
            ttft_slo=ttft, tpot_slo=tpot))
    reqs.sort(key=lambda r: r.arrival_t)
    return reqs

"""The scheduling-policy role component: every discretionary decision.

Queue order, extra admission gating, victim choice, chunk order, and
the QoS class each request's far-memory traffic rides all come through
one :class:`SchedulerPolicy` object (``engine.sched``) — the base class
is the utilisation-maximising watermark scheduler, and
:class:`SLOScheduler` is the goodput scheduler that maps priority
tiers onto the pager's QoS windows.  Both are role-agnostic: a
PREFILL-role engine uses the same EDF chunk ordering and shedding
rules for its admission/chunk queue, and a DECODE-role engine uses the
same victim choice and QoS mapping for its resume traffic — the policy
layer is what stays constant across the fused/disaggregated split.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, List

from repro.core.amu import QoS
from repro.serve.config import Tier
from repro.serve.request import Request

if TYPE_CHECKING:                         # pragma: no cover - typing only
    from repro.serve.engine import Engine

__all__ = ["SchedulerPolicy", "SLOScheduler", "SCHEDULERS"]


class SchedulerPolicy:
    """The scheduling-policy layer: every discretionary decision the
    engine makes — queue order, extra admission gating, victim choice,
    chunk order, and the QoS class each request's far-memory traffic
    rides — comes through one of these objects (``engine.sched``).

    This base class IS the watermark scheduler (``policy="watermark"``):
    FIFO admission, newest-admitted-first preemption, admission-order
    chunk selection, LATENCY fetches / BULK parks for everyone.  It
    maximises utilisation and is SLO-blind — the exact PR-4/PR-5
    behaviour, bit-for-bit.
    """

    name = "watermark"

    def __init__(self, engine: "Engine"):
        self.eng = engine

    def order_queue(self, queue: List[Request], now: float) -> None:
        """Reorder the admission queue in place (base: FIFO — resumes
        were pushed to the head by preemption and stay there)."""

    def may_admit(self, req: Request, need: int) -> bool:
        """Extra admission gate on top of the free-page watermark
        (base: none)."""
        return True

    def pick_victim(self, victims: List[Request], now: float) -> Request:
        """Choose the preemption victim (base: newest admitted)."""
        return max(victims, key=lambda r: r.admit_seq)

    def chunk_order(self, reqs) -> List[Request]:
        """Order admitting slots for chunk selection (base: admission
        order)."""
        return sorted(reqs, key=lambda r: r.admit_seq)

    def fetch_qos(self, req: Request) -> QoS:
        """QoS class for this request's resume prefetches."""
        return QoS.LATENCY

    def store_qos(self, req: Request) -> QoS:
        """QoS class for this request's preemption writebacks."""
        return QoS.BULK

    def on_submit(self, req: Request) -> None:
        """Hook at submission (base: nothing to arm)."""


class SLOScheduler(SchedulerPolicy):
    """Goodput scheduling (``policy="slo"``): admission, preemption and
    chunk selection maximise *SLO attainment* instead of utilisation,
    and the request's priority tier maps onto the pager's QoS windows —
    the paper's §2.2 MACR QoS applied at request granularity:

      * **queue order** — arrived requests first, INTERACTIVE tier
        before BATCH, earliest deadline first within a tier (EDF);
        parked requests of a tier resume before its fresh admissions
        (their pages are already paid for),
      * **admission shedding** — a BATCH request must leave
        ``batch_headroom`` free pages beyond the low watermark, and
        never admits while an interactive resume is still in flight:
        under overload, batch-tier load is shed first,
      * **preemption** — the victim is a BATCH slot when one exists,
        preferring one whose SLO is *already blown* (evicting it costs
        nothing that isn't lost) and otherwise the one *furthest from
        its next deadline* (most slack to absorb a park/resume
        round-trip),
      * **QoS mapping** — interactive resumes/prefetches ride LATENCY
        aloads and interactive parks STANDARD astores; batch resumes
        ride STANDARD and batch parks BULK — so an interactive
        request's far-memory traffic is never queued behind a batch
        request's in the AMU windows,
      * **deadlines as events** — each submission arms its TTFT
        deadline in a :class:`~repro.paging.DeadlineQueue`; ticks pop
        due deadlines and post ``DEADLINE`` events (§2.3.2: passing
        time is a scheduling event like an arriving page).
    """

    name = "slo"

    def next_deadline(self, req: Request, now: float) -> float:
        """The next instant this request's SLO contract can be missed:
        its TTFT deadline before the first token, then each successive
        token's TPOT budget.  inf when unconstrained."""
        if not req.token_ts:
            if req.ttft_slo is None:
                return float("inf")
            return req.arrival_t + req.ttft_slo
        if req.tpot_slo is None:
            return float("inf")
        return req.token_ts[-1] + req.tpot_slo

    def slack(self, req: Request, now: float) -> float:
        return self.next_deadline(req, now) - now

    def blown(self, req: Request, now: float) -> bool:
        return self.next_deadline(req, now) < now

    def order_queue(self, queue: List[Request], now: float) -> None:
        queue.sort(key=lambda r: (
            r.arrival_t > now,           # future arrivals wait their turn
            int(r.tier),                 # INTERACTIVE before BATCH
            not r.parked,                # resumes before fresh admissions
            self.next_deadline(r, now),  # EDF within the tier
            r.rid))

    def may_admit(self, req: Request, need: int) -> bool:
        eng = self.eng
        if req.tier is not Tier.BATCH or not eng.paging:
            return True
        if not (eng.active or eng.prefilling or eng._resuming):
            return True                  # idle system: nothing to shed for
        if any(r.tier is Tier.INTERACTIVE
               for r in eng._resuming.values()):
            return False                 # interactive resume owns the bus
        headroom = eng.sched_cfg.batch_headroom
        return eng.page_pool.n_free - need >= eng.policy.low + headroom

    def pick_victim(self, victims: List[Request], now: float) -> Request:
        return min(victims, key=lambda r: (
            r.tier is not Tier.BATCH,    # shed batch tier first
            not self.blown(r, now),      # a blown SLO loses nothing more
            -self.slack(r, now),         # then: most slack to spare
            -r.admit_seq))

    def chunk_order(self, reqs) -> List[Request]:
        now = self.eng.clock()
        return sorted(reqs, key=lambda r: (self.next_deadline(r, now),
                                           r.admit_seq))

    def fetch_qos(self, req: Request) -> QoS:
        return QoS.LATENCY if req.tier is Tier.INTERACTIVE else QoS.STANDARD

    def store_qos(self, req: Request) -> QoS:
        return QoS.STANDARD if req.tier is Tier.INTERACTIVE else QoS.BULK

    def on_submit(self, req: Request) -> None:
        if req.ttft_slo is not None:
            self.eng.deadlines.schedule(req.arrival_t + req.ttft_slo,
                                        req.rid)


SCHEDULERS = {"watermark": SchedulerPolicy, "slo": SLOScheduler}

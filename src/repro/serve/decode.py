"""The decode-loop role component: the step and the finish path.

:class:`DecodeMixin` owns chunk-vs-decode work selection, the jitted
mixed/decode step dispatch, prefill graduation, and the request finish
path — including the one hook that differentiates the engine roles:
:meth:`_role_done`.  A FUSED or DECODE engine finishes a request when
its token budget (or EOS) says so; a PREFILL engine finishes it at its
*first token* — graduation — at which point the ordinary
``offload_finished`` park plus a published handoff record hand the
request to the decode side.  Everything else in the loop is shared.
The mixin assumes the host class provides the engine state surface —
``serve/engine.py`` assembles it.
"""

from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.amu import QoS
from repro.paging import EventKind, PagingError
from repro.serve.config import EngineRole, Tier
from repro.serve.request import Request

__all__ = ["DecodeMixin"]


class DecodeMixin:
    """Decode loop + finish path (see the module docstring).  Mixed
    into :class:`~repro.serve.engine.Engine`."""

    # -- the role hook ---------------------------------------------------------
    def _role_done(self, req: Request) -> bool:
        """Is this request finished *for this engine's role*?  FUSED and
        DECODE: the request's own budget/EOS (``req.done``).  PREFILL:
        any first token — prefill's job ends at graduation; whether the
        request is done under fused semantics travels on the handoff
        record (``rec.done``) for the decode side to honour."""
        if self.role is EngineRole.PREFILL:
            return bool(req.generated)
        return req.done

    # -- chunk-queue scheduling (chunked paged prefill) ------------------------
    def _select_chunks(self) -> List:
        """Pick chunk-vs-decode work for this step.

        A chunk for the oldest admitting slots runs fused with the
        decode step when (a) the LATENCY aload window has room — resume
        traffic saturating the per-QoS window (§2.2 MACR) means parked
        pages are mid-flight and chunk compute would only delay their
        landing — and (b) the chunk's pages fit the pool without
        preempting anyone (free-page-watermark occupancy; chunk growth,
        like decode growth, is a continuation and so is exempt from the
        admission low watermark)."""
        if not self.prefilling:
            return []
        if self._resuming and not self.pager.windows.has_room(QoS.LATENCY):
            return []
        picks: List = []
        t_exact = None
        exact = self.cfg.family == "hybrid"    # pad tokens corrupt SSM state
        for req in self.sched.chunk_order(self.prefilling.values()):
            if len(picks) >= self.chunk_slots:
                break
            start = req.prefill_pos
            end = min(req.target_len, start + self.chunk_tokens)
            if exact and t_exact is not None and end - start != t_exact:
                continue                   # exact-shape batch: next step
            need = self.page_table.pages_needed(req.rid, end)
            if need and not self._make_room(need, frozenset({req.rid}),
                                            preempt=False):
                continue                   # pool tight: decode-only step
            if exact and t_exact is None:
                t_exact = end - start      # pin shape only once a row fits
            self._alloc_pinned(req, end)
            picks.append((req, start, end))
        return picks

    def _force_chunk(self) -> List:
        """Nothing decodable and no chunk fit the pool politely: force
        the oldest admitting slot's chunk through, preempting (parking
        another half-prefilled victim) if that is what it takes — the
        loop must always progress."""
        req = min(self.prefilling.values(), key=lambda r: r.admit_seq)
        end = min(req.target_len, req.prefill_pos + self.chunk_tokens)
        need = self.page_table.pages_needed(req.rid, end)
        if need and not self._make_room(need, frozenset({req.rid}),
                                        preempt=True):
            raise PagingError(
                f"chunked prefill of request {req.rid} cannot progress: "
                f"pool of {self.page_pool.n_pages} pages exhausted")
        self._alloc_pinned(req, end)
        return [(req, req.prefill_pos, end)]

    def _build_chunk(self, picks) -> Dict[str, Any]:
        """Assemble the mixed step's chunk operand (C = ``chunk_slots``
        rows, unused rows inert with length 0 / trash page rows)."""
        C = self.chunk_slots
        if self.cfg.family == "hybrid":
            T = picks[0][2] - picks[0][1]  # exact shapes (no pad tokens)
        else:
            T = self.chunk_tokens
        tokens = np.zeros((C, T), np.int32)
        offset = np.zeros((C,), np.int32)
        length = np.zeros((C,), np.int32)
        slots = np.zeros((C,), np.int32)
        src_len = np.zeros((C,), np.int32)
        rows = np.full((C, self.pages_per_seq), self.trash_frame, np.int32)
        for i, (req, start, end) in enumerate(picks):
            n = end - start
            tokens[i, :n] = req.prompt[start:end]
            offset[i] = start
            length[i] = n
            slots[i] = req.slot
            src_len[i] = req.src_len
            rows[i] = req.chunk_rows
        chunk = {"tokens": jnp.asarray(tokens),
                 "offset": jnp.asarray(offset),
                 "length": jnp.asarray(length),
                 "page_rows": jnp.asarray(rows)}
        if self.cfg.family == "encdec":
            chunk["slots"] = jnp.asarray(slots)
            chunk["src_len"] = jnp.asarray(src_len)
        if self.cfg.family == "hybrid":
            trees = [r.chunk_ssm for r, _, _ in picks]
            trees += [self._zero_chunk_ssm] * (C - len(picks))
            chunk["ssm"] = jax.tree_util.tree_map(
                lambda *xs: jnp.asarray(np.concatenate(xs, axis=1)), *trees)
        return chunk

    def _finish_chunks(self, picks, chunk_logits, carry) -> None:
        """Advance every picked request past its chunk; rows that just
        covered their prompt's last token graduate to the decode batch
        (their first sampled token is the chunk's last-valid logits)."""
        tr = self.tracer
        for i, (req, start, end) in enumerate(picks):
            req.prefill_pos = end
            if tr.enabled:
                tr.instant("requests", f"req{req.rid}", "chunk",
                           {"start": start, "end": end,
                            "target": req.target_len})
            if self.cfg.family == "hybrid":
                req.chunk_ssm = jax.tree_util.tree_map(
                    lambda a: np.asarray(a[:, i:i + 1]), carry)
            if end >= req.target_len:
                self._finalize_prefill(req, chunk_logits[i])

    def _finalize_prefill(self, req: Request, logits_row) -> None:
        """Graduate a fully-prefilled request into the decode batch: the
        device page-table row flips from the trash frame to the real
        frames (one host-mirror write — the KV is already in its pool
        frames), pos and any SSM carry land in the cache, and the first
        token comes from the final chunk's logits at the prompt's last
        valid position — matching the dense path's ``last_pos`` exactly."""
        slot = req.slot
        self._pt_np[slot] = req.chunk_rows
        self._pt_dirty = True
        pos_row = jnp.asarray([req.target_len], jnp.int32)
        cache = self.cache
        new_pos = jax.lax.dynamic_update_slice_in_dim(
            cache.pos, pos_row.astype(cache.pos.dtype), slot, axis=0)
        ssm = cache.ssm
        if self.cfg.family == "hybrid":
            ssm = jax.tree_util.tree_map(
                lambda dst, src: jax.lax.dynamic_update_slice_in_dim(
                    dst, jnp.asarray(src).astype(dst.dtype), slot, axis=1),
                ssm, req.chunk_ssm)
            req.chunk_ssm = None
        self.cache = cache._replace(pos=new_pos, ssm=ssm)
        req.chunk_rows = None
        del self.prefilling[slot]
        if self.prefix is not None:
            # donate the prompt's full pages to the prefix cache: future
            # requests with the same prefix share these frames instead
            # of re-running their chunks
            self.prefix.intern(req.prompt, req.rid, self._read_frame)
        first = int(np.argmax(np.asarray(logits_row)))
        req.generated.append(first)
        req.first_token_t = self.clock()
        req.token_ts.append(req.first_token_t)
        self.active[slot] = req
        self._obs_phase(req, "decode")
        if self.tracer.enabled:
            self.tracer.instant(
                "requests", f"req{req.rid}", "first_token",
                {"ttft_s": req.first_token_t - req.arrival_t})
        self._finish_if_done(req)

    def _propose_drafts(self) -> Dict[int, int]:
        """Ask the proposer for a draft per active slot (speculation on).

        Returns rid -> draft length; the drafted tokens themselves land
        in ``self._draft_toks``.  Drafts are capped to the slot's token
        head-room and the request's remaining budget (a draft past the
        budget could never commit — the bonus token uses the last unit),
        and trimmed at the first drafted EOS.  An empty dict means this
        step runs the plain single-token path."""
        drafts: Dict[int, int] = {}
        self._draft_toks: Dict[int, List[int]] = {}
        pos_np = np.asarray(self.cache.pos)
        if any(int(pos_np[slot]) + 1 > self.slot_tokens
               for slot in self.active):
            # a slot at full capacity writes its token at the clamped
            # last row in decode_step but would scatter to the trash
            # frame in verify_step — fall back to the plain path for
            # the whole batch this step
            return drafts
        for slot, req in self.active.items():
            pos = int(pos_np[slot])
            room = self.slot_tokens - pos - 1
            budget = req.max_new_tokens - len(req.generated) - 1
            cap = min(self.speculate_k, room, budget)
            if cap <= 0:
                continue
            history = req.prompt.tolist() + req.generated
            draft = list(self.proposer.propose(req.rid, history))[:cap]
            if req.eos_id is not None and req.eos_id in draft:
                draft = draft[:draft.index(req.eos_id) + 1]
            if draft:
                drafts[req.rid] = len(draft)
                self._draft_toks[req.rid] = draft
        return drafts

    def _step(self) -> None:
        drafts = self._propose_drafts() \
            if self.speculating and self.active else {}
        if self.paging:
            # draft-aware growth: a speculating slot pins frames for its
            # whole write window [pos, pos + 1 + draft); entries clamp
            # in place when the pool cannot cover the full draft
            self._ensure_growth(drafts or None)
        picks = self._select_chunks() if self.chunking else []
        if self.chunking and not picks and not self.active and \
                self.prefilling and not self._resuming:
            picks = self._force_chunk()
        if not self.active and not picks:
            return
        if drafts:
            # growth/chunk allocation may have preempted a drafting slot
            # (its draft dies with the park) or clamped a draft to zero
            live = {req.rid for req in self.active.values()}
            drafts = {r: n for r, n in drafts.items() if r in live and n > 0}
        if self.paging and self._pt_dirty:
            # refresh the device page-table rows from the host mirror
            # (skipped on steady-state steps with no scheduling events)
            kv = self.cache.kv
            self.cache = self.cache._replace(
                kv=dict(kv, page_table=jnp.asarray(self._pt_np)))
            self._pt_dirty = False
        if drafts:
            self._spec_step(drafts, picks)
            return
        toks = np.zeros((self.max_batch, 1), np.int32)
        for slot, req in self.active.items():
            toks[slot, 0] = req.generated[-1]
        if picks:
            chunk = self._build_chunk(picks)
            logits, chunk_logits, carry, self.cache = self._mixed(
                self.params, self.cache, jnp.asarray(toks), chunk)
            self.stats["mixed_steps"] += 1
            self.stats["chunks"] += len(picks)
        else:
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(toks))
        self.stats["steps"] += 1
        if self.active:
            logits = np.asarray(logits)
            t_now = self.clock()
            tr = self.tracer
            for slot, req in list(self.active.items()):
                nxt = int(np.argmax(logits[slot]))
                req.generated.append(nxt)
                req.token_ts.append(t_now)
                if tr.enabled:
                    tr.instant("requests", f"req{req.rid}", "token",
                               {"n": len(req.generated)})
                self._finish_if_done(req)
        if picks:
            self._finish_chunks(picks, np.asarray(chunk_logits), carry)

    def _spec_step(self, drafts: Dict[int, int], picks: List) -> None:
        """One speculative verify-K step: score every slot's draft in a
        single jitted program, then accept/rollback host-side.

        Acceptance is the standard greedy-speculation rule: the longest
        draft prefix that matches the verify logits' argmax commits,
        plus one *bonus* token from the first non-matching row — so a
        fully-rejected draft still commits one token (exactly the plain
        step's), and the emitted stream is token-identical to
        single-step greedy decode by construction.  Rollback is
        host-only: the verify step never advances ``pos``, the engine
        writes ``pos + appended`` back and
        :meth:`~repro.paging.PageTable.rewind_tokens` drops any page
        left holding only the rejected tail (whose K/V beyond the new
        ``pos`` is dead — masked by every future read, overwritten by
        future appends, and excluded from a later park's freshness tag
        because parks derive valid tokens from ``pos``)."""
        S = self.speculate_k + 1
        toks = np.zeros((self.max_batch, S), np.int32)
        length = np.zeros((self.max_batch,), np.int32)
        per_slot: Dict[int, List[int]] = {}
        for slot, req in self.active.items():
            d = self._draft_toks.get(req.rid, [])[:drafts.get(req.rid, 0)]
            per_slot[slot] = d
            toks[slot, 0] = req.generated[-1]
            toks[slot, 1:1 + len(d)] = d
            length[slot] = 1 + len(d)
        if picks:
            chunk = self._build_chunk(picks)
            logits, chunk_logits, carry, self.cache = self._mixed_verify(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(length), chunk)
            self.stats["mixed_steps"] += 1
            self.stats["chunks"] += len(picks)
        else:
            logits, self.cache = self._verify(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(length))
        self.stats["steps"] += 1
        self.stats["spec_steps"] += 1
        logits = np.asarray(logits)
        t_now = self.clock()
        tr = self.tracer
        pos_np = np.array(self.cache.pos)
        step_drafted = step_accepted = 0
        for slot, req in list(self.active.items()):
            d = per_slot[slot]
            m = len(d)
            start = int(pos_np[slot])
            greedy = np.argmax(logits[slot, :m + 1], axis=-1)
            acc = 0
            while acc < m and d[acc] == int(greedy[acc]):
                acc += 1
            appended = 0
            for t in d[:acc] + [int(greedy[acc])]:
                req.generated.append(int(t))
                req.token_ts.append(t_now)
                appended += 1
                if tr.enabled:
                    tr.instant("requests", f"req{req.rid}", "token",
                               {"n": len(req.generated)})
                if self._role_done(req):
                    break
            committed = min(acc, appended)   # drafts actually appended
            step_drafted += m
            step_accepted += committed
            # positions are host-owned across a verify step: advance by
            # what committed, and drop pages holding only rejected tail
            pos_np[slot] = start + appended
            released = self.page_table.rewind_tokens(req.rid,
                                                     start + appended)
            if released:
                keep = self.page_table.n_pages(req.rid)
                self._pt_np[slot, keep:] = self.trash_frame
                self._pt_dirty = True
        # write rewound positions back BEFORE finishing slots: finish
        # may offload the request's KV, and offload reads cache.pos
        self.cache = self.cache._replace(pos=jnp.asarray(pos_np))
        for req in list(self.active.values()):
            self._finish_if_done(req)
        self.stats["drafted"] += step_drafted
        self.stats["accepted"] += step_accepted
        self.stats["rejected"] += step_drafted - step_accepted
        if tr.enabled:
            tr.instant("engine", "spec", "verify",
                       {"drafted": step_drafted,
                        "accepted": step_accepted,
                        "rejected": step_drafted - step_accepted})
            tr.counter("engine", "spec_drafted", self.stats["drafted"])
            tr.counter("engine", "spec_accepted", self.stats["accepted"])
            tr.counter("engine", "spec_rejected", self.stats["rejected"])
        if picks:
            self._finish_chunks(picks, np.asarray(chunk_logits), carry)

    def _finish_if_done(self, req: Request) -> None:
        if not self._role_done(req):
            return
        if self.speculating:
            self.proposer.drop(req.rid)
        slot = req.slot
        if slot is not None and slot in self.active:
            del self.active[slot]
        if slot is not None:
            if self.offload_finished:
                self._offload_finished(req)
                if self.role is EngineRole.PREFILL:
                    # graduation: pages + aux are in the shared tier,
                    # publish the control-plane record the decode-role
                    # engine admits by
                    self._publish_handoff(req)
            if self.paging:
                self._pt_np[slot] = self.trash_frame
                self._pt_dirty = True
            self.pool.release(slot)
        req.done_t = self.clock()
        self.finished[req.rid] = req
        self.stats["slo_attained" if req.slo_attained()
                   else "slo_missed"] += 1
        if req.token_ts:
            tier = req.tier.name
            self.metrics.observe(f"engine/ttft_s/{tier}", req.ttft)
            if len(req.token_ts) > 1:
                self.metrics.observe(f"engine/tpot_s/{tier}", req.tpot)
        if self.tracer.enabled:
            self._obs_phase(req, None)       # close the lifecycle track
            # everything trace_report needs to rebuild slo_report() from
            # the trace alone rides on this one instant
            self.tracer.instant(
                "requests", f"req{req.rid}", "finish",
                {"tier": req.tier.name, "arrival": req.arrival_t,
                 "first_token": req.first_token_t, "done": req.done_t,
                 "n_new": len(req.generated),
                 "n_preempts": req.n_preempts,
                 "ttft_slo": req.ttft_slo, "tpot_slo": req.tpot_slo,
                 "attained": bool(req.slo_attained())})
        self.events.post(EventKind.COMPLETE, req.rid)
        self.events.drain()

    # -- SLO telemetry --------------------------------------------------------
    def slo_report(self) -> Dict[str, Any]:
        """Per-tier SLO attainment over the finished requests.

        All numbers live on the engine's one clock (virtual seconds by
        default).  *Goodput* is the serving-paper definition: tokens
        generated by requests that met every SLO they carry — work that
        arrived uselessly late counts for nothing.  Example::

            eng.run()
            rep = eng.slo_report()
            rep["interactive"]["goodput"]      # SLO-attaining tok/s
            rep["interactive"]["ttft_p95"]
        """
        elapsed = max(self.clock(), 1e-12)
        out: Dict[str, Any] = {"elapsed": elapsed}
        for tier in Tier:
            reqs = [r for r in self.finished.values() if r.tier is tier]
            ttfts = sorted(r.ttft for r in reqs if r.token_ts)
            good = [r for r in reqs if r.slo_attained()]
            good_tokens = sum(len(r.generated) for r in good)
            out[tier.name.lower()] = {
                "n": len(reqs),
                "attained": len(good),
                "attainment": len(good) / len(reqs) if reqs else 1.0,
                "good_tokens": good_tokens,
                "goodput": good_tokens / elapsed,
                "ttft_p50": (float(np.percentile(ttfts, 50))
                             if ttfts else 0.0),
                "ttft_p95": (float(np.percentile(ttfts, 95))
                             if ttfts else 0.0),
                "ttft_p99": (float(np.percentile(ttfts, 99))
                             if ttfts else 0.0),
            }
        return out

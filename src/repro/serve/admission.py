"""The admission role component: how requests enter compute.

:class:`AdmissionMixin` owns everything between the queue and a live
slot — dense whole-prompt prefill, chunk-queue admission with the mixed
prefill/decode scheduling, prefix-cache mapping, watermark/SLO
admission gating, and (new with disaggregation) :meth:`admit_handoff`,
the DECODE-role entry point that adopts a PREFILL-role engine's
graduated request straight from the shared far tier.  A handoff
admission is deliberately *not* a new code path: it rebuilds the
request parked (pages registered PARKED against the shared tier's
entries, aux residue fetched fault-safe) and lets the ordinary resume
machinery in :class:`~repro.serve.transfer.TransferMixin` slot it in.
The mixin assumes the host class provides the engine state surface —
``serve/engine.py`` assembles it.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.amu import QoS
from repro.models.model import encode_cross, prefill
from repro.paging import EventKind, PageState, PagingError, pages_for
from repro.serve.config import EngineRole, Tier
from repro.serve.disagg import HandoffRecord
from repro.serve.kv_cache import insert_aux_slot, insert_slot
from repro.serve.request import Request
from repro.serve.transfer import _scatter_seq_pages

__all__ = ["AdmissionMixin"]


class AdmissionMixin:
    """Admission + chunk-queue scheduling (see the module docstring).
    Mixed into :class:`~repro.serve.engine.Engine`."""

    # -- prefill --------------------------------------------------------------
    def _bucket(self, plen: int) -> int:
        # SSM/hybrid state is corrupted by pad tokens, so exact lengths
        # there; attention families pad to the next bucket (cache entries
        # beyond plen are never attended: pos starts at plen).
        if self.cfg.family in ("ssm", "hybrid"):
            return plen
        for b in self.buckets:
            if plen <= b:
                return b
        return self.max_len

    def _prefill_one(self, req: Request):
        plen = len(req.prompt)
        bucket = self._bucket(plen)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = req.prompt
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "encdec":
            se = req.src_embeds
            if se is None:
                se = np.zeros((bucket, self.cfg.d_model), np.float32)
            src = np.zeros((1, bucket, self.cfg.d_model), np.float32)
            src[0, :se.shape[0]] = se[:bucket]
            batch["src_embeds"] = jnp.asarray(src)
        if self.cfg.mrope_sections:
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(bucket, dtype=jnp.int32), (3, 1, bucket))
        key = (bucket, self.cfg.family)
        if key not in self._prefills:
            cfg = self.cfg
            self._prefills[key] = jax.jit(
                lambda p, b, lp: prefill(p, cfg, b, max_len=self.max_len,
                                         last_pos=lp))
        # logits come from the prompt's true last token (plen - 1), never
        # from the padded bucket tail — the first sampled token must not
        # depend on pad embeddings, and the chunked-prefill path (which
        # never materialises the pad tail) must agree with this one
        logits, single = self._prefills[key](
            self.params, batch, jnp.asarray([plen - 1], jnp.int32))
        self.stats["prefills"] += 1
        # true position is plen (ignore pad tail): set pos = plen
        single = single._replace(pos=jnp.full((1,), plen, jnp.int32))
        return logits, single

    def _install_sequence(self, req: Request, single) -> None:
        """Admission on the paged layout: scatter the prefilled KV pages
        into their pool frames and install the slot's page-table row +
        aux state.  No dense batched KV exists to insert into."""
        slot = req.slot
        kv = self.cache.kv
        # only the prompt's pages — exactly the frames _alloc_pinned just
        # mapped; the bucket tail beyond them is zeros, never attended
        n_pg = pages_for(min(len(req.prompt), self.slot_tokens),
                         self.page_size)
        frames = jnp.asarray(self._pt_np[slot, :n_pg])
        kp, vp = _scatter_seq_pages(
            kv["k_pages"], kv["v_pages"],
            single.kv["k"], single.kv["v"], frames, n_pg)
        cache = self.cache._replace(kv=dict(kv, k_pages=kp, v_pages=vp))
        aux = {"ssm": single.ssm, "cross": single.cross, "pos": single.pos}
        self.cache = insert_aux_slot(cache, aux, slot, self.max_batch)

    def _install_cross(self, req: Request) -> None:
        """Enc-dec chunk-queue admission: run the encoder once and park
        its cross-attention KV in the slot's rows of ``cache.cross`` —
        every later prompt chunk and decode token reads it from there
        (the decode path never writes cross state, so the rows survive
        the whole prefill).  The projections are the exact ones dense
        prefill computes, so chunked and dense agree bit-for-bit."""
        plen = len(req.prompt)
        bucket = self._bucket(plen)
        se = req.src_embeds
        if se is None:
            se = np.zeros((bucket, self.cfg.d_model), np.float32)
        src = np.zeros((1, bucket, self.cfg.d_model), np.float32)
        src[0, :se.shape[0]] = se[:bucket]
        key = ("cross", bucket)
        if key not in self._prefills:
            cfg = self.cfg
            self._prefills[key] = jax.jit(
                lambda p, s: encode_cross(p, cfg, s))
        cross = self._prefills[key](self.params, jnp.asarray(src))
        slot = req.slot
        new_cross = {}
        for name, dst in self.cache.cross.items():
            src_rows = cross[name]
            # slot axis by leaf name: k/v are (L, B, Ssrc, ...), enc_out
            # is (B, Ssrc, d) — a shape heuristic misfires when Ssrc
            # happens to equal max_batch
            axis = 1 if name in ("k", "v") else 0
            new_cross[name] = jax.lax.dynamic_update_slice_in_dim(
                dst, src_rows.astype(dst.dtype), slot, axis=axis)
        self.cache = self.cache._replace(cross=new_cross)
        req.src_len = bucket

    # -- scheduling ------------------------------------------------------------
    def _chunkable(self, req: Request) -> bool:
        """Chunk-queue admission requires the whole prompt to fit the
        slot's token capacity (an SWA ring that wraps mid-prompt would
        rewrite pages the chunk path still attends); longer prompts fall
        back to the legacy dense-prefill admission."""
        return (self.chunking and len(req.prompt) > 0
                and len(req.prompt) <= self.slot_tokens)

    def _admit_prefix(self, req: Request, hits: List[int]) -> bool:
        """Map prefix-cache hits onto the request's fresh page-table row.

        Device-resident hits are refcount-shared in place (zero traffic,
        zero compute); hits whose shared page lives only in the far tier
        make the request start *parked* — it rides the ordinary resume
        machinery (LATENCY prefetch of a private copy, including the
        resume-while-ARRIVING paths) before its first chunk.  Either
        way ``prefill_pos`` starts past the shared prefix, so those
        chunks are simply never queued.  Returns True on the far route.
        """
        self.page_table.register(req.rid)
        req.target_len = len(req.prompt)
        far = False
        for l in hits:
            key = self.prefix.far_key(l)
            if self.prefix.entry_state(l) is PageState.RESIDENT:
                phys = self.prefix.entry_phys(l)
                logical = self.page_table.append_shared(req.rid, phys)
                self.page_pool.touch(phys)
            else:
                far = True
                logical = self.page_table.append_parked(req.rid)
                self.stats["prefix_far_hits"] += 1
            # far alias (no copy: same host payload) so this mapping can
            # always park clean and a far hit fetches through the pager
            self.pager.store_far(req.rid, logical, self.far_tier.home(key),
                                 tokens=self.page_size)
        req.prefill_pos = len(hits) * self.page_size
        self.stats["prefix_hits"] += len(hits)
        self.stats["prefix_tokens_saved"] += req.prefill_pos
        if far:
            req.parked = True
        return far

    def _admit(self) -> None:
        if self.paging:
            self._try_finish_resumes()
        now = self.clock()
        self.sched.order_queue(self.queue, now)
        while self.queue:
            req = self.queue[0]
            if req.arrival_t > now:
                break                 # trace replay: not in the system yet
            if req.parked:                                # preempted: resume
                if req.rid in self._resuming or not self._start_resume(req):
                    break
                self.queue.pop(0)
                self._try_finish_resumes()
                continue
            if not self.pool.n_free:
                break
            hits: List[int] = []
            if self.paging:
                need = pages_for(min(len(req.prompt), self.slot_tokens),
                                 self.page_size)
                if self.prefix is not None and self._chunkable(req) \
                        and req.rid not in self.page_table.sequences():
                    hits = self.prefix.match(req.prompt)
                    # device-resident hits take no new frames
                    need -= sum(
                        1 for l in hits
                        if self.prefix.entry_state(l) is PageState.RESIDENT)
                if not self.sched.may_admit(req, need):
                    # SLO load shedding: the highest-priority admissible
                    # request is batch-tier and the pool is too tight to
                    # take it without risking interactive deadlines
                    self.stats["shed_admissions"] += 1
                    if self.tracer.enabled:
                        self.tracer.instant(
                            "engine", "sched", "shed",
                            {"rid": req.rid, "tier": req.tier.name,
                             "need_pages": need,
                             "free": self.page_pool.n_free})
                    break
                if not self.policy.can_admit(self.page_pool, need) and \
                        not self._make_room(need + self.policy.low,
                                            frozenset(), preempt=False):
                    break
            if hits and self._admit_prefix(req, hits):
                # far-tier hits: request left at the queue head, parked;
                # the next iteration routes it through _start_resume
                continue
            self.queue.pop(0)
            slot = self.pool.alloc()
            req.slot = slot
            if self._chunkable(req):
                # chunk-queue admission: install bookkeeping only — the
                # prompt is computed chunk-by-chunk by the mixed step,
                # interleaved with every running slot's decode
                if req.rid not in self.page_table.sequences():
                    self.page_table.register(req.rid)
                req.target_len = len(req.prompt)
                req.chunk_rows = np.full((self.pages_per_seq,),
                                         self.trash_frame, np.int32)
                # prefix hits already mapped: pin them for the slot and
                # point the chunk row at the shared frames
                for logical in range(self.page_table.n_pages(req.rid)):
                    self.page_table.pin_page(req.rid, logical)
                    req.chunk_rows[logical] = \
                        self.page_table.entry(req.rid, logical).phys
                if self.cfg.family == "hybrid":
                    req.chunk_ssm = jax.tree_util.tree_map(
                        np.copy, self._zero_chunk_ssm)
                if self.cfg.family == "encdec":
                    self._install_cross(req)
                req.admit_seq = next(self._admits)
                self.prefilling[slot] = req
                self.stats["admitted"] += 1
                self._obs_phase(req, "prefill")
                self.events.post(EventKind.ADMIT, req.rid)
                continue
            logits, single = self._prefill_one(req)
            if self.paging:
                self.page_table.register(req.rid)
                self._alloc_pinned(req,
                                   min(len(req.prompt), self.slot_tokens))
                self._install_sequence(req, single)
            else:
                self.cache = insert_slot(self.cache, single, slot,
                                         self.max_batch)
            req.admit_seq = next(self._admits)
            first = int(np.argmax(np.asarray(logits)[0]))
            req.generated.append(first)
            req.first_token_t = self.clock()
            req.token_ts.append(req.first_token_t)
            self.active[slot] = req
            self.stats["admitted"] += 1
            self._obs_phase(req, "decode")
            if self.tracer.enabled:
                self.tracer.instant(
                    "requests", f"req{req.rid}", "first_token",
                    {"ttft_s": req.first_token_t - req.arrival_t})
            self.events.post(EventKind.ADMIT, req.rid)
            self._finish_if_done(req)

    # -- cross-engine handoff admission (DECODE role) --------------------------
    def admit_handoff(self, rec: HandoffRecord,
                      arrival_t: Optional[float] = None) -> int:
        """Adopt a PREFILL-role engine's graduated request from the
        shared far tier.

        The aux residue rides the pager's fault-safe overlapped fetch
        (:meth:`~repro.paging.Pager.fetch_keys` — a mid-transfer AMU
        fault raises with the tier entry intact, so the caller simply
        retries), the prompt pages register as PARKED page-table
        entries against the tier's ``(rid, logical)`` homes, and the
        request joins the queue *parked*: the ordinary resume machinery
        LATENCY-prefetches the pages and slots it into the decode batch
        — no handoff-specific transfer path exists to get wrong.  A
        record already done under fused semantics (one-token request or
        first-token EOS) finishes immediately and clears its tier
        entries.  Returns the adopted rid (unchanged from the prefill
        side; the local rid counter jumps past it)."""
        if self.role is not EngineRole.DECODE:
            raise PagingError(
                f"admit_handoff requires EngineRole.DECODE; this engine "
                f"is {self.role.value!r}")
        rid = rec.rid
        if rid in self.finished or rid in self.page_table.sequences():
            raise PagingError(f"handoff rid {rid} already known here")
        # handed-off rids stay globally unique: local submissions must
        # never collide with them
        self._next_rid = max(self._next_rid, rid + 1)
        self.far_tier.poll()             # retire the tier-AMU offloads
        now = self.clock()
        req = Request(
            rid=rid, prompt=np.asarray(rec.prompt, np.int32),
            max_new_tokens=rec.max_new_tokens, eos_id=rec.eos_id,
            tier=Tier(rec.tier), ttft_slo=rec.ttft_slo,
            tpot_slo=rec.tpot_slo,
            # both engines' virtual clocks share an origin, so the
            # prefill-side arrival/first-token instants stay meaningful
            # for SLO attainment measured on this side
            arrival_t=rec.arrival_t if arrival_t is None else arrival_t,
            submitted_t=rec.submitted_t, src_len=rec.src_len)
        req.generated = list(rec.generated)
        req.token_ts = list(rec.token_ts)
        req.first_token_t = rec.first_token_t
        if rec.done:
            # one-token / first-token-EOS request: nothing to decode;
            # every transfer already landed, so the tier entries may go
            self.far_tier.discard_seq(rid)
            req.done_t = now
            self.finished[rid] = req
            self.stats["handoffs"] += 1
            self.stats["slo_attained" if req.slo_attained()
                       else "slo_missed"] += 1
            return rid
        # aux residue: fault-safe overlapped fetch, discarded only after
        # the payload verifiably landed (fault ⇒ raise, entry intact,
        # caller retries with no local state to unwind — nothing below
        # this line has happened yet, including the handoff counter)
        meta = self.pager.fetch_keys([(rid, "aux")],
                                     discard_after=True)[(rid, "aux")]
        self.stats["handoffs"] += 1
        self.page_table.register_parked(rid, meta["pages"])
        req.parked = True
        req.residue = meta["aux"]
        self.queue.append(req)
        self.sched.on_submit(req)
        return rid

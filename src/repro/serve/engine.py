"""Continuous-batching serving engine over the paged KV subsystem.

The scheduler is the paper's *event-driven model* (§2.3.2) applied to
requests instead of cache lines: decode steps are the event loop's
ticks; pager ``getfin`` completions post PAGE_ARRIVED events; admission
and preemption decisions come from *free-page watermarks* over the
device page pool (``repro.paging``) instead of free-slot counts.  This
is what lets the engine admit more concurrent sequences than device
memory can hold:

  * each sequence's KV is accounted in fixed-size pages of a shared
    :class:`~repro.paging.PagePool`; active slots pin their pages,
  * when growth (or a new admission) exceeds the pool, a victim is
    *preempted*: only its **cold** pages are written back (BULK-QoS
    ``astore``; pages whose far-tier copy is still current move for
    free), while the hot tail stays cached on-device,
  * rescheduling prefetches the parked pages **hot tail first** with
    LATENCY-QoS ``aload`` that overlaps the current decode step; the
    sequence re-enters a slot the moment its residency bits are all set
    — no re-prefill, bit-exact resume.

Decode computes **directly on the paged layout**: the device cache is a
:class:`~repro.models.model.PagedCache` whose k/v live in the pool's
page frames, and the serve step's attention reads them through the
per-slot page table (:func:`~repro.models.attention.
paged_decode_attention_block` — the Pallas scalar-prefetch gather on
TPU).  Preemption parks cold pages without ever extracting a dense
slot; resume is a page-table patch plus a LATENCY prefetch.  The
admit/preempt/resume hot path performs **zero dense KV
re-materialisation** — ``extract_slot``/``insert_slot`` survive only on
the non-paged fallback, exactly the round-trip the AMU papers argue
against eliminating elsewhere.

**The storage layer is an explicit two-tier hierarchy**: the device
page pool (near tier) over ONE host
:class:`~repro.core.offload.FarMemoryTier` behind the pager.  Every
cold page is a page-granularity resident of that tier — preempted
pages via BULK writeback (or for free when the far copy's valid-token
tag is current), watermark-evicted pages via the pager's LRU
``balance`` loop that runs every tick the free-frame count sits under
the low watermark, and *finished* sequences' KV via the same shed
path (``offload_finished``; ``fetch_finished`` reassembles with
overlapped LATENCY aloads, discarding entries only after every
transfer verifiably landed).  There is no sequence-granularity side
store.

**Cross-request prefix sharing** (``prefix_cache=True``) sits on top:
full prompt pages are content-addressed by a rolling token-id hash
(:mod:`repro.paging.prefix_cache`) and interned at prefill
graduation; a later request whose prompt starts with the same tokens
maps its page-table rows onto the shared frames — refcounted + COW on
a device hit, one LATENCY page fetch on a far-tier hit — and its
prefill simply starts past them (``prefill_pos``), so a system prompt
shared by thousands of users costs one prefill.  Only the partial
boundary page and the unseen tail are computed; outputs stay
token-exact with the dense engine.

**Prefill is chunked and continuously batched** (``chunk_tokens``): the
last dense-KV hold-out — admit-then-scatter whole-prompt prefill — is
replaced by a *chunk queue*.  Admission installs a slot and page-table
bookkeeping only; the prompt is then computed in chunks **on the pool
layout** (:func:`~repro.models.model.prefill_chunk` scatters each
chunk's K/V straight into its mapped frames while flash-attending the
pool-resident prefix), fused with every running slot's decode token in
one jitted mixed step (:func:`~repro.dist.steps.make_mixed_step`).  The
scheduler picks chunk-vs-decode work off free-page watermarks and the
pager's LATENCY-window occupancy, and preemption can cancel a
half-prefilled sequence by parking its completed chunks — the prompt
remainder re-enters the chunk queue on resume.  A new request therefore
never serialises a dense-prefill bubble in front of running decodes:
the request-level massive parallelism the follow-up AMU paper
(2404.11044) targets.  With ``chunk_tokens=None`` (default) admission
falls back to the legacy whole-prompt dense prefill; both paths are
token-exact with a dense non-paged run.

Decode itself is mesh-sharded: the step function comes from
``repro.dist.steps.make_serve_step`` (TP-sharded params, paged-cache
PartitionSpecs) bound to the engine's mesh — a 1×1 mesh by default, the
production (data, model) mesh when one is passed in.  Decode runs with
a *fixed* batch of ``max_batch`` slots (one compiled program); per-slot
positions make the mixed-depth batch correct, and empty slots decode
garbage into a reserved *trash frame* that no live sequence maps — the
standard fixed-shape trade on TPU, made safe at page granularity.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.amu import QoS
from repro.dist.steps import make_mixed_step, make_serve_step
from repro.launch.mesh import make_mesh_compat
from repro.models import ssm as ssm_mod
from repro.models.model import (Cache, PagedCache, encode_cross, init_cache,
                                init_paged_cache, prefill)
from repro.obs import (MetricsRegistry, Tracer, to_chrome_trace,
                       write_chrome_trace, write_metrics)
from repro.paging import (NOT_MAPPED, DeadlineQueue, EventKind, EventLoop,
                          PagePool, PageState, PageTable, Pager, PagingError,
                          PrefixCache, WatermarkPolicy, pages_for)
from repro.serve.config import (EngineConfig, Tier, VirtualClock,
                                engine_config_from_kwargs)
from repro.serve.kv_cache import (SlotPool, extract_aux_slot,
                                  insert_aux_slot, insert_slot,
                                  join_kv_pages)

__all__ = ["Request", "Engine", "SchedulerPolicy", "SLOScheduler"]


@dataclass
class Request:
    """One submitted generation request and its full lifecycle state.

    A request moves through admit → (chunked prefill) → decode →
    park/resume (any number of times, from either phase) → finish; see
    ``docs/ARCHITECTURE.md`` for the lifecycle diagram.  Example::

        rid = engine.submit(np.arange(7), max_new_tokens=4)
        tokens = engine.run()[rid]
    """

    rid: int
    prompt: np.ndarray                  # (plen,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    src_embeds: Optional[np.ndarray] = None   # encdec frontend stub
    # SLO contract (production traffic model; see repro.serve.workload):
    tier: Tier = Tier.INTERACTIVE
    ttft_slo: Optional[float] = None    # time-to-first-token budget
    tpot_slo: Optional[float] = None    # mean time-per-output-token budget
    arrival_t: float = 0.0              # when the request enters the system
    # filled by the engine:
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    submitted_t: float = 0.0
    first_token_t: float = 0.0
    done_t: float = 0.0
    token_ts: List[float] = field(default_factory=list)  # one per token
    # paging state (set when the request has been preempted):
    parked: bool = False                # preempted, waiting to resume
    residue: Any = None                 # non-KV aux payload while parked
    n_preempts: int = 0
    admit_seq: int = -1                 # admission order (preemption priority)
    # chunked-prefill state (chunk-queue admission path):
    prefill_pos: int = 0                # prompt tokens already prefilled
    target_len: int = 0                 # tokens the chunk path must cover
    chunk_rows: Any = None              # host page-table row while prefilling
    chunk_ssm: Any = None               # hybrid: SSM carry between chunks
    src_len: int = 0                    # encdec: true encoder length

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return bool(self.generated and self.eos_id is not None
                    and self.generated[-1] == self.eos_id)

    @property
    def mid_prefill(self) -> bool:
        """True while the prompt is only partially chunk-prefilled."""
        return self.target_len > 0 and self.prefill_pos < self.target_len

    # -- SLO telemetry (all timestamps on the engine's one clock) ----------
    @property
    def ttft(self) -> float:
        """Time to first token (inf until one exists)."""
        if not self.token_ts:
            return float("inf")
        return self.token_ts[0] - self.arrival_t

    @property
    def tpot(self) -> float:
        """Mean time per output token after the first (0 for 1 token)."""
        if len(self.token_ts) < 2:
            return 0.0
        return ((self.token_ts[-1] - self.token_ts[0])
                / (len(self.token_ts) - 1))

    def slo_attained(self) -> bool:
        """Did this request meet every SLO it carries?  A request with
        no SLOs trivially attains (batch completion traffic)."""
        if self.ttft_slo is not None and self.ttft > self.ttft_slo:
            return False
        if self.tpot_slo is not None and self.tpot > self.tpot_slo:
            return False
        return True


# -- jitted pool-frame scatters (module level: one compile per shape) ---------

@partial(jax.jit, donate_argnums=(0, 1), static_argnums=(5,))
def _scatter_seq_pages(k_pages, v_pages, k_single, v_single, frames,
                       n_pg: int):
    """Write one sequence's dense prefill KV into its pool frames.

    ``k_single``/``v_single``: (L, 1, S, Hkv, D) from prefill — S is the
    prefill *bucket*, at most the slot capacity; only the leading
    ``n_pg`` pages (the prompt's — the exact frames admission just
    mapped) are scattered, the tail zero-padded up to a page multiple.
    The pool arrays are donated: the update aliases in place instead of
    copying the whole pool per admission."""
    L, _, S, Hkv, D = k_single.shape
    page = k_pages.shape[2]
    take = min(n_pg * page, S)
    k_single = k_single[:, :, :take]
    v_single = v_single[:, :, :take]
    pad = n_pg * page - take
    if pad:
        widths = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
        k_single = jnp.pad(k_single, widths)
        v_single = jnp.pad(v_single, widths)
    ks = k_single[:, 0].reshape(L, n_pg, page, Hkv, D)
    vs = v_single[:, 0].reshape(L, n_pg, page, Hkv, D)
    k_pages = k_pages.at[:, frames].set(ks.astype(k_pages.dtype))
    v_pages = v_pages.at[:, frames].set(vs.astype(v_pages.dtype))
    return k_pages, v_pages


@partial(jax.jit, donate_argnums=(0, 1))
def _scatter_one_page(k_pages, v_pages, k_data, v_data, phys):
    """Land one far-tier page payload (L, page, Hkv, D) in frame ``phys``
    (pool arrays donated: an in-place page write, not a pool copy)."""
    k_pages = k_pages.at[:, phys].set(k_data.astype(k_pages.dtype))
    v_pages = v_pages.at[:, phys].set(v_data.astype(v_pages.dtype))
    return k_pages, v_pages


@partial(jax.jit, donate_argnums=(0, 1))
def _copy_frame(k_pages, v_pages, src, dst):
    """Device-side page copy (COW break: a sharer about to write a
    prefix-shared frame gets a private duplicate first)."""
    k_pages = k_pages.at[:, dst].set(k_pages[:, src])
    v_pages = v_pages.at[:, dst].set(v_pages[:, src])
    return k_pages, v_pages


class SchedulerPolicy:
    """The scheduling-policy layer: every discretionary decision the
    engine makes — queue order, extra admission gating, victim choice,
    chunk order, and the QoS class each request's far-memory traffic
    rides — comes through one of these objects (``engine.sched``).

    This base class IS the watermark scheduler (``policy="watermark"``):
    FIFO admission, newest-admitted-first preemption, admission-order
    chunk selection, LATENCY fetches / BULK parks for everyone.  It
    maximises utilisation and is SLO-blind — the exact PR-4/PR-5
    behaviour, bit-for-bit.
    """

    name = "watermark"

    def __init__(self, engine: "Engine"):
        self.eng = engine

    def order_queue(self, queue: List[Request], now: float) -> None:
        """Reorder the admission queue in place (base: FIFO — resumes
        were pushed to the head by preemption and stay there)."""

    def may_admit(self, req: Request, need: int) -> bool:
        """Extra admission gate on top of the free-page watermark
        (base: none)."""
        return True

    def pick_victim(self, victims: List[Request], now: float) -> Request:
        """Choose the preemption victim (base: newest admitted)."""
        return max(victims, key=lambda r: r.admit_seq)

    def chunk_order(self, reqs) -> List[Request]:
        """Order admitting slots for chunk selection (base: admission
        order)."""
        return sorted(reqs, key=lambda r: r.admit_seq)

    def fetch_qos(self, req: Request) -> QoS:
        """QoS class for this request's resume prefetches."""
        return QoS.LATENCY

    def store_qos(self, req: Request) -> QoS:
        """QoS class for this request's preemption writebacks."""
        return QoS.BULK

    def on_submit(self, req: Request) -> None:
        """Hook at submission (base: nothing to arm)."""


class SLOScheduler(SchedulerPolicy):
    """Goodput scheduling (``policy="slo"``): admission, preemption and
    chunk selection maximise *SLO attainment* instead of utilisation,
    and the request's priority tier maps onto the pager's QoS windows —
    the paper's §2.2 MACR QoS applied at request granularity:

      * **queue order** — arrived requests first, INTERACTIVE tier
        before BATCH, earliest deadline first within a tier (EDF);
        parked requests of a tier resume before its fresh admissions
        (their pages are already paid for),
      * **admission shedding** — a BATCH request must leave
        ``batch_headroom`` free pages beyond the low watermark, and
        never admits while an interactive resume is still in flight:
        under overload, batch-tier load is shed first,
      * **preemption** — the victim is a BATCH slot when one exists,
        preferring one whose SLO is *already blown* (evicting it costs
        nothing that isn't lost) and otherwise the one *furthest from
        its next deadline* (most slack to absorb a park/resume
        round-trip),
      * **QoS mapping** — interactive resumes/prefetches ride LATENCY
        aloads and interactive parks STANDARD astores; batch resumes
        ride STANDARD and batch parks BULK — so an interactive
        request's far-memory traffic is never queued behind a batch
        request's in the AMU windows,
      * **deadlines as events** — each submission arms its TTFT
        deadline in a :class:`~repro.paging.DeadlineQueue`; ticks pop
        due deadlines and post ``DEADLINE`` events (§2.3.2: passing
        time is a scheduling event like an arriving page).
    """

    name = "slo"

    def next_deadline(self, req: Request, now: float) -> float:
        """The next instant this request's SLO contract can be missed:
        its TTFT deadline before the first token, then each successive
        token's TPOT budget.  inf when unconstrained."""
        if not req.token_ts:
            if req.ttft_slo is None:
                return float("inf")
            return req.arrival_t + req.ttft_slo
        if req.tpot_slo is None:
            return float("inf")
        return req.token_ts[-1] + req.tpot_slo

    def slack(self, req: Request, now: float) -> float:
        return self.next_deadline(req, now) - now

    def blown(self, req: Request, now: float) -> bool:
        return self.next_deadline(req, now) < now

    def order_queue(self, queue: List[Request], now: float) -> None:
        queue.sort(key=lambda r: (
            r.arrival_t > now,           # future arrivals wait their turn
            int(r.tier),                 # INTERACTIVE before BATCH
            not r.parked,                # resumes before fresh admissions
            self.next_deadline(r, now),  # EDF within the tier
            r.rid))

    def may_admit(self, req: Request, need: int) -> bool:
        eng = self.eng
        if req.tier is not Tier.BATCH or not eng.paging:
            return True
        if not (eng.active or eng.prefilling or eng._resuming):
            return True                  # idle system: nothing to shed for
        if any(r.tier is Tier.INTERACTIVE
               for r in eng._resuming.values()):
            return False                 # interactive resume owns the bus
        headroom = eng.sched_cfg.batch_headroom
        return eng.page_pool.n_free - need >= eng.policy.low + headroom

    def pick_victim(self, victims: List[Request], now: float) -> Request:
        return min(victims, key=lambda r: (
            r.tier is not Tier.BATCH,    # shed batch tier first
            not self.blown(r, now),      # a blown SLO loses nothing more
            -self.slack(r, now),         # then: most slack to spare
            -r.admit_seq))

    def chunk_order(self, reqs) -> List[Request]:
        now = self.eng.clock()
        return sorted(reqs, key=lambda r: (self.next_deadline(r, now),
                                           r.admit_seq))

    def fetch_qos(self, req: Request) -> QoS:
        return QoS.LATENCY if req.tier is Tier.INTERACTIVE else QoS.STANDARD

    def store_qos(self, req: Request) -> QoS:
        return QoS.STANDARD if req.tier is Tier.INTERACTIVE else QoS.BULK

    def on_submit(self, req: Request) -> None:
        if req.ttft_slo is not None:
            self.eng.deadlines.schedule(req.arrival_t + req.ttft_slo,
                                        req.rid)


_SCHEDULERS = {"watermark": SchedulerPolicy, "slo": SLOScheduler}


class Engine:
    """Continuous-batching serving engine on the paged far-memory KV.

    The module docstring describes the design; operationally::

        eng = Engine(cfg, params, EngineConfig(
            max_batch=4, max_len=256,
            paging=PagingConfig(page_size=16,
                                device_pages=48),   # oversubscribed
            chunking=ChunkingConfig(chunk_tokens=32)))  # chunked prefill
        for p in prompts:
            eng.submit(p, max_new_tokens=16)
        outputs = eng.run()                           # {rid: tokens}

    Construction takes one frozen :class:`~repro.serve.config.
    EngineConfig` (the documented path; the pre-config flat kwargs are
    still accepted for one release with a DeprecationWarning).  Knobs:
    ``paging.device_pages`` below ``max_batch * pages_per_seq``
    oversubscribes the pool (watermark admission + preemption, §2.3.2);
    ``chunking.chunk_tokens`` switches admission to the chunk queue
    (mixed prefill/decode steps); ``chunking.prefix_cache=True`` adds
    cross-request prefix sharing on top of it (content-addressed prompt
    pages; dense/moe global-attention families);
    ``paging.offload_finished`` parks finished sequences' pages in the
    far tier for later :meth:`fetch_finished` reuse;
    ``paging.enabled=False`` is the dense A/B reference;
    ``kernel_impl`` selects the paged-attention backend
    (``auto``/``pallas``/``interpret``/``xla``);
    ``paging.pager_factory`` injects a custom
    :class:`~repro.paging.Pager` (tests use a simulated-latency AMU
    backend); ``scheduler.policy="slo"`` switches scheduling from
    utilisation to goodput (see :class:`SLOScheduler`).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        config: Optional[EngineConfig] = None,
        **legacy_kwargs,
    ):
        if legacy_kwargs:
            config = engine_config_from_kwargs(config, **legacy_kwargs)
        ec = config or EngineConfig()
        pg, ck, sc = ec.paging, ec.chunking, ec.scheduler
        max_batch, max_len = ec.max_batch, ec.max_len
        self.config = ec
        self.sched_cfg = sc
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.buckets = tuple(sorted(b for b in ec.prefill_buckets
                                    if b <= max_len)) or (max_len,)
        self.greedy = ec.greedy
        # ONE clock for every request timestamp (arrival, first token,
        # per-token, completion).  Default: an engine-owned VirtualClock
        # advanced by step_dt per tick, in lockstep with the pager's
        # simulated AMU — deterministic SLO measurement.  Injecting
        # e.g. time.monotonic opts into wall-clock telemetry.
        self.clock = sc.clock if sc.clock is not None else VirtualClock()
        self._own_clock = sc.clock is None
        # -- unified telemetry: one registry + one tracer on THE clock ------
        # (repro.obs; ec.obs.tracing turns span emission on — default off,
        # in which case every instrumented site costs one branch)
        self.metrics = MetricsRegistry()
        self.tracer = Tracer(clock=self.clock, enabled=ec.obs.tracing)
        self._phase_span: Dict[int, int] = {}    # rid -> open lifecycle sid
        self._obs_started: set = set()           # rids with a queued span
        self.pool = SlotPool(max_batch)
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}     # slot -> request
        self.finished: Dict[int, Request] = {}
        self.offload_finished = pg.offload_finished
        self._ids = itertools.count()
        self._admits = itertools.count()

        # -- page-granularity KV residency over a fixed device pool --------
        # (decided before the decode step is built: the step consumes the
        # paged layout directly when the family has attention KV)
        page_size = pg.page_size
        shapes = jax.eval_shape(lambda: init_cache(cfg, max_batch, max_len))
        kv_shapes = shapes.kv if isinstance(shapes.kv, dict) else {}
        self.paging = ("k" in kv_shapes) if pg.enabled is None else \
            (pg.enabled and "k" in kv_shapes)
        self.page_size = page_size
        self.step_dt = sc.step_dt
        self.hot_tail_pages = max(0, pg.hot_tail_pages)
        self._resuming: Dict[int, Request] = {}
        if self.paging:
            k = kv_shapes["k"]
            self.slot_tokens = int(k.shape[2])       # ring size for SWA
            if self.slot_tokens % page_size:
                raise PagingError(
                    f"page_size {page_size} must divide the per-sequence "
                    f"token capacity {self.slot_tokens}")
            self.pages_per_seq = self.slot_tokens // page_size
            n_pages = pg.device_pages if pg.device_pages is not None \
                else max_batch * self.pages_per_seq
            page_nbytes = int(2 * k.shape[0] * page_size * k.shape[3]
                              * k.shape[4] * k.dtype.itemsize)
            self.page_pool = PagePool(n_pages, page_size)
            self.page_table = PageTable(self.page_pool)
            if pg.pager_factory is not None:
                self.pager = pg.pager_factory(self.page_pool,
                                              self.page_table,
                                              page_nbytes=page_nbytes)
            else:
                self.pager = Pager(self.page_pool, self.page_table,
                                   page_nbytes=page_nbytes)
            if self.pager.read_frame is None:    # keep a factory's hook
                self.pager.read_frame = self._read_frame
            # adopt the pager (factory-built or not) into the engine's
            # registry + tracer: its ad-hoc stats migrate into the
            # "pager" counter group and its AMU/page-table emit spans on
            # the engine clock
            self.pager.bind_obs(self.metrics, self.tracer)
            # THE far tier: one FarMemoryTier behind the pager holds
            # every cold page — preempted, watermark-evicted, finished —
            # plus finished sequences' aux residues and the prefix
            # cache's shared page homes
            self.far_tier = self.pager.tier
            # device frames: pool frames + one trash frame at the end
            self.trash_frame = n_pages
            self.cache: Any = init_paged_cache(
                cfg, max_batch, max_len, n_frames=n_pages + 1,
                page_size=page_size)
            self._pt_np = np.full((max_batch, self.pages_per_seq),
                                  self.trash_frame, np.int32)
            self._pt_dirty = True
        else:
            self.slot_tokens = 0
            self.page_pool = self.page_table = self.pager = None
            self.far_tier = None
            self.cache = init_cache(cfg, max_batch, max_len)
        if self.offload_finished and not self.paging:
            raise PagingError(
                "offload_finished requires the paged engine: finished KV "
                "is parked page-by-page through the pager's far tier")
        self.policy = pg.watermark or WatermarkPolicy(low=0, critical=0)
        # the scheduling-policy layer: every discretionary decision
        # (queue order, victim, chunk order, per-request QoS) goes
        # through self.sched — see SchedulerPolicy / SLOScheduler
        if sc.policy not in _SCHEDULERS:
            raise PagingError(
                f"unknown scheduler policy {sc.policy!r}; "
                f"expected one of {sorted(_SCHEDULERS)}")
        self.sched = _SCHEDULERS[sc.policy](self)
        self.deadlines = DeadlineQueue()

        # -- mesh-sharded decode step (dist.steps, not a raw jit) ----------
        self.mesh = ec.mesh if ec.mesh is not None else \
            make_mesh_compat((1, 1), ("data", "model"))
        shape = ShapeConfig("serve_engine", max_len, max_batch, "decode")
        # cache donated: the step aliases the pool frames in place —
        # no per-token copy of the KV pool (self.cache is rebound to the
        # step's output immediately, so the donation is safe)
        self._decode, self._decode_specs = make_serve_step(
            cfg, self.mesh, shape, donate=True, paged=self.paging,
            kernel_impl=ec.kernel_impl)
        self._prefills: Dict[Any, Any] = {}

        # -- chunk-queue admission (chunked paged prefill) ------------------
        # admission installs page-table rows only; prompts are then fed
        # through the mixed step in chunks that interleave with decode
        self.chunk_tokens = int(ck.chunk_tokens) if ck.chunk_tokens else 0
        self.chunk_slots = max(1, int(ck.chunk_slots))
        self.chunking = bool(self.chunk_tokens) and self.paging
        self.prefilling: Dict[int, Request] = {}     # slot -> admitting req
        if self.chunking:
            self._mixed, self._mixed_specs = make_mixed_step(
                cfg, self.mesh, shape, donate=True,
                kernel_impl=ec.kernel_impl)
            if cfg.family == "hybrid":
                s = ssm_mod.mamba2_state_init(cfg, 1)
                self._zero_chunk_ssm = jax.tree_util.tree_map(
                    lambda a: np.zeros((cfg.num_layers,) + a.shape,
                                       np.asarray(a).dtype), s)

        # -- cross-request prefix sharing (content-addressed prompt pages)
        # full prompt pages are interned by rolling token-id hash at
        # prefill graduation; later requests map their page-table rows
        # onto the shared frames (device hit) or fetch a private copy
        # with a LATENCY aload (far hit) and skip those prefill chunks.
        # Supported where the shared KV is position- and content-exact
        # for every sharer: global-attention dense/moe (append-only KV,
        # absolute rope; SWA ring wrap rewrites pages in place, and
        # hybrid/encdec carry non-KV per-request prefix state).
        self.prefix: Optional[PrefixCache] = None
        if ck.prefix_cache:
            if not self.chunking:
                raise PagingError(
                    "prefix_cache requires chunked paged admission "
                    "(chunk_tokens > 0 on the paged engine)")
            if cfg.family not in ("dense", "moe") or \
                    cfg.attention == "swa":
                raise PagingError(
                    "prefix_cache supports global-attention dense/moe "
                    f"families; got family={cfg.family!r} "
                    f"attention={cfg.attention!r}")
            self.prefix = PrefixCache(self.page_pool, self.page_table,
                                      self.pager, page_size)

        self.events = EventLoop(metrics=self.metrics)
        self.events.on(EventKind.TICK, self._on_tick)
        self.events.on(EventKind.PAGE_ARRIVED, self._on_page_arrived)
        self.events.on(EventKind.COMPLETE, self._on_complete)
        self.events.on(EventKind.DEADLINE, self._on_deadline)
        # dict-compatible view onto the shared registry ("engine" group):
        # callers keep reading eng.stats["preemptions"] etc. unchanged
        self.stats = self.metrics.counters(
            "engine",
            initial={"steps": 0, "prefills": 0, "admitted": 0,
                     "preemptions": 0, "resumes": 0, "mixed_steps": 0,
                     "chunks": 0, "prefill_preempts": 0,
                     "prefix_hits": 0, "prefix_tokens_saved": 0,
                     "prefix_far_hits": 0, "deadline_misses": 0,
                     "slo_attained": 0, "slo_missed": 0,
                     "shed_admissions": 0})

    # -- public API ----------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int = 16,
               eos_id: Optional[int] = None,
               src_embeds: Optional[np.ndarray] = None,
               tier: Tier = Tier.INTERACTIVE,
               ttft_slo: Optional[float] = None,
               tpot_slo: Optional[float] = None,
               arrival_t: Optional[float] = None) -> int:
        """Queue one request.  SLO fields: ``tier`` picks the priority
        class (maps to pager QoS under the SLO scheduler), ``ttft_slo``
        / ``tpot_slo`` override the :class:`SchedulerConfig` defaults,
        and ``arrival_t`` places the request on the virtual-clock time
        axis (a trace replay submits the whole workload up front; the
        engine admits nothing before its arrival time).  Defaults
        reproduce the old behaviour: arrive now, no SLOs."""
        prompt = np.asarray(prompt, np.int32)
        if self.paging:
            full = pages_for(min(len(prompt) + max_new_tokens,
                                 self.slot_tokens), self.page_size)
            if full > self.page_pool.n_pages:
                raise PagingError(
                    f"request needs {full} pages; pool has only "
                    f"{self.page_pool.n_pages} — it could never complete")
            # admission only ever needs the prompt's pages (growth is
            # exempt from the low watermark) — reject what can't admit
            admit = pages_for(min(len(prompt), self.slot_tokens),
                              self.page_size)
            if admit + self.policy.low > self.page_pool.n_pages:
                raise PagingError(
                    f"request needs {admit} pages at admission; pool of "
                    f"{self.page_pool.n_pages} under low watermark "
                    f"{self.policy.low} can never admit it")
        rid = next(self._ids)
        now = self.clock()
        req = Request(rid=rid, prompt=prompt,
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      src_embeds=src_embeds, submitted_t=now,
                      tier=Tier(tier),
                      ttft_slo=(ttft_slo if ttft_slo is not None
                                else self.sched_cfg.ttft_slo),
                      tpot_slo=(tpot_slo if tpot_slo is not None
                                else self.sched_cfg.tpot_slo),
                      arrival_t=now if arrival_t is None else arrival_t)
        self.queue.append(req)
        self.sched.on_submit(req)
        return rid

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Event loop until every submitted request completes.

        Example (8 requests through 3 slots, continuous batching)::

            eng = Engine(cfg, params, EngineConfig(
                max_batch=3, max_len=64,
                chunking=ChunkingConfig(chunk_tokens=8)))
            rids = [eng.submit(p, max_new_tokens=5) for p in prompts]
            outputs = eng.run()          # {rid: [token, ...]}
        """
        for _ in range(max_steps):
            if not self.queue and not self.active and not self._resuming \
                    and not self.prefilling:
                break
            self._admit()
            if self.active or self.prefilling:
                self._step()
            self.events.tick()
            if not self.active and not self.prefilling and self._resuming:
                # nothing decodable: land the in-flight pages, then
                # demand-fetch the head resume so the loop always
                # progresses (its misses may evict other resumes' pages)
                for req in list(self._resuming.values()):
                    self.pager.wait_arriving(req.rid)
                self.pager.wait_seq(next(iter(self._resuming.values())).rid)
                self._admit()
            if not self.active and not self.prefilling \
                    and not self._resuming and self.queue:
                # everything just finished this step: retry admission
                # now rather than waiting for the next iteration
                self._admit()
                if not self.active and not self.prefilling \
                        and not self._resuming:
                    future = [r.arrival_t for r in self.queue
                              if r.arrival_t > self.clock()]
                    if future and len(future) == len(self.queue):
                        # the system is idle only because the trace is:
                        # fast-forward the virtual clock to the next
                        # arrival (a wall clock advances by itself)
                        if self._own_clock:
                            self.clock.advance(min(future) - self.clock())
                        continue
                    # nothing running and nothing in flight: the state
                    # can never change, so admission is blocked for
                    # good — fail loudly instead of spinning to max_steps
                    raise PagingError(
                        f"{len(self.queue)} queued requests can never be "
                        "admitted (free pages "
                        f"{self.page_pool.n_free if self.paging else 'n/a'}"
                        f", low watermark {self.policy.low})")
        if not self.queue and not self.active and not self._resuming \
                and not self.prefilling:
            # fully drained: the telemetry counters must balance
            self.check_invariants()
        ob = self.config.obs
        if ob.trace_out:
            self.export_trace(ob.trace_out)
        if ob.metrics_out:
            self.export_metrics(ob.metrics_out)
        return {r.rid: r.generated for r in self.finished.values()}

    # -- event handlers -------------------------------------------------------
    def _on_tick(self, ev) -> None:
        # the engine-owned virtual clock advances here, by step_dt, in
        # lockstep with the pager's simulated backend below — one time
        # axis for transfers AND request telemetry
        if self._own_clock:
            self.clock.advance(self.step_dt)
        for t, rid in self.deadlines.pop_due(self.clock()):
            self.events.post(EventKind.DEADLINE, (t, rid))
        if self.pager is None:
            return
        for seq, logical in self.pager.advance(self.step_dt):
            self.events.post(EventKind.PAGE_ARRIVED, (seq, logical))
        # capacity pressure: when free frames sit under the low
        # watermark, push cold RESIDENT pages (parked hot tails, idle
        # prefix-cache frames) to the far tier *now*, so the BULK
        # astores overlap decode instead of serialising inside the next
        # admission's _make_room
        if self.policy.low:
            self.pager.balance(self.policy.low)

    def _on_page_arrived(self, ev) -> None:
        seq, logical = ev.payload
        pte = self.page_table.entry(seq, logical)
        if pte.state is PageState.RESIDENT:
            self._land_frame(pte.phys)       # scatter into the device pool
            self.page_pool.touch(pte.phys)

    def _on_complete(self, ev) -> None:
        rid = ev.payload
        if self.paging and rid in self.page_table.sequences():
            self.page_table.drop(rid)
            if not self.offload_finished:
                # offloaded sequences keep their far-tier pages: that IS
                # the finished-KV store fetch_finished reads back
                self.pager.drop_far(rid)

    def _on_deadline(self, ev) -> None:
        """A TTFT deadline passed.  If the request still has no first
        token it has missed its SLO *now* — count it while it is still
        schedulable, so preemption's already-blown preference and the
        telemetry agree in real time rather than post hoc."""
        t, rid = ev.payload
        req = self.finished.get(rid)
        if req is None:
            for r in itertools.chain(self.queue, self.active.values(),
                                     self.prefilling.values(),
                                     self._resuming.values()):
                if r.rid == rid:
                    req = r
                    break
        if req is not None and not req.token_ts:
            self.stats["deadline_misses"] += 1
            if self.tracer.enabled:
                self.tracer.instant("engine", "sched", "deadline_miss",
                                    {"rid": rid, "tier": req.tier.name,
                                     "deadline": t})

    # -- telemetry ------------------------------------------------------------
    def _obs_phase(self, req: Request, name: Optional[str]) -> None:
        """Advance a request's lifecycle track: close its current phase
        span and open ``name`` (None just closes — the finish path).
        The first phase a request ever enters also back-fills a
        ``queued`` span covering arrival → now, so the Perfetto track
        reads arrival → admit → prefill/decode → … end to end."""
        tr = self.tracer
        if not tr.enabled:
            return
        tid = f"req{req.rid}"
        if req.rid not in self._obs_started:
            self._obs_started.add(req.rid)
            tr.complete("requests", tid, "queued", req.arrival_t,
                        args={"tier": req.tier.name})
        tr.end(self._phase_span.pop(req.rid, 0))
        if name is not None:
            self._phase_span[req.rid] = tr.begin(
                "requests", tid, name, {"tier": req.tier.name})

    def check_invariants(self) -> None:
        """Cross-layer conservation checks over the telemetry counters.

        * preemptions == resumes + requests *currently* parked by a
          preemption (a prefix-far admission parks without one, so only
          ``n_preempts > 0`` requests count),
        * ADMIT events == admissions + resumes (every ADMIT post has
          exactly one matching stats increment),
        * the pager's per-QoS window takes/releases balance its
          in-flight gauges (see :meth:`Pager.check_invariants`).
        """
        s = self.stats
        pending = sum(
            1 for r in itertools.chain(self.queue, self._resuming.values())
            if r.parked and r.n_preempts > 0)
        if s["preemptions"] != s["resumes"] + pending:
            raise PagingError(
                f"preempt/resume imbalance: {s['preemptions']} preemptions "
                f"!= {s['resumes']} resumes + {pending} currently parked")
        admits = self.events.history.get(EventKind.ADMIT, 0)
        if admits != s["admitted"] + s["resumes"]:
            raise PagingError(
                f"ADMIT event imbalance: {admits} events != "
                f"{s['admitted']} admissions + {s['resumes']} resumes")
        if self.pager is not None:
            self.pager.check_invariants()

    def export_trace(self, path: Optional[str] = None) -> dict:
        """Chrome-trace/Perfetto JSON of everything traced so far (AMU
        transfers, pager actions, residency flips, request lifecycle —
        one virtual time axis).  Writes to ``path`` when given."""
        if path is not None:
            write_chrome_trace(path, self.tracer, metrics=self.metrics)
        return to_chrome_trace(self.tracer, metrics=self.metrics)

    def export_metrics(self, path: Optional[str] = None) -> dict:
        """Flat JSON snapshot of every counter/gauge/histogram."""
        if path is not None:
            write_metrics(path, self.metrics)
        return self.metrics.snapshot()

    # -- internals ------------------------------------------------------------
    def _bucket(self, plen: int) -> int:
        # SSM/hybrid state is corrupted by pad tokens, so exact lengths
        # there; attention families pad to the next bucket (cache entries
        # beyond plen are never attended: pos starts at plen).
        if self.cfg.family in ("ssm", "hybrid"):
            return plen
        for b in self.buckets:
            if plen <= b:
                return b
        return self.max_len

    def _prefill_one(self, req: Request):
        plen = len(req.prompt)
        bucket = self._bucket(plen)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = req.prompt
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "encdec":
            se = req.src_embeds
            if se is None:
                se = np.zeros((bucket, self.cfg.d_model), np.float32)
            src = np.zeros((1, bucket, self.cfg.d_model), np.float32)
            src[0, :se.shape[0]] = se[:bucket]
            batch["src_embeds"] = jnp.asarray(src)
        if self.cfg.mrope_sections:
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(bucket, dtype=jnp.int32), (3, 1, bucket))
        key = (bucket, self.cfg.family)
        if key not in self._prefills:
            cfg = self.cfg
            self._prefills[key] = jax.jit(
                lambda p, b, lp: prefill(p, cfg, b, max_len=self.max_len,
                                         last_pos=lp))
        # logits come from the prompt's true last token (plen - 1), never
        # from the padded bucket tail — the first sampled token must not
        # depend on pad embeddings, and the chunked-prefill path (which
        # never materialises the pad tail) must agree with this one
        logits, single = self._prefills[key](
            self.params, batch, jnp.asarray([plen - 1], jnp.int32))
        self.stats["prefills"] += 1
        # true position is plen (ignore pad tail): set pos = plen
        single = single._replace(pos=jnp.full((1,), plen, jnp.int32))
        return logits, single

    # -- paged device-pool plumbing -------------------------------------------
    def _read_frame(self, phys: int) -> Dict[str, np.ndarray]:
        """Pull one frame's content (L, page, Hkv, D) off the device —
        the page-granularity transfer unit the pager's astores move."""
        kv = self.cache.kv
        return {"k": np.asarray(kv["k_pages"][:, phys]),
                "v": np.asarray(kv["v_pages"][:, phys])}

    def _land_frame(self, phys: int) -> None:
        """If the pool frame holds a far-tier payload that has not been
        scattered into the device pool yet, land it now."""
        frame = self.page_pool.frames[phys]
        if frame.data is None:
            return                       # content already lives in the pool
        kv = self.cache.kv
        kp, vp = _scatter_one_page(
            kv["k_pages"], kv["v_pages"],
            jnp.asarray(frame.data["k"]), jnp.asarray(frame.data["v"]),
            jnp.asarray(phys, jnp.int32))
        self.cache = self.cache._replace(kv=dict(kv, k_pages=kp, v_pages=vp))
        frame.data = None

    def _install_sequence(self, req: Request, single: Cache) -> None:
        """Admission on the paged layout: scatter the prefilled KV pages
        into their pool frames and install the slot's page-table row +
        aux state.  No dense batched KV exists to insert into."""
        slot = req.slot
        kv = self.cache.kv
        # only the prompt's pages — exactly the frames _alloc_pinned just
        # mapped; the bucket tail beyond them is zeros, never attended
        n_pg = pages_for(min(len(req.prompt), self.slot_tokens),
                         self.page_size)
        frames = jnp.asarray(self._pt_np[slot, :n_pg])
        kp, vp = _scatter_seq_pages(
            kv["k_pages"], kv["v_pages"],
            single.kv["k"], single.kv["v"], frames, n_pg)
        cache = self.cache._replace(kv=dict(kv, k_pages=kp, v_pages=vp))
        aux = {"ssm": single.ssm, "cross": single.cross, "pos": single.pos}
        self.cache = insert_aux_slot(cache, aux, slot, self.max_batch)

    def _install_cross(self, req: Request) -> None:
        """Enc-dec chunk-queue admission: run the encoder once and park
        its cross-attention KV in the slot's rows of ``cache.cross`` —
        every later prompt chunk and decode token reads it from there
        (the decode path never writes cross state, so the rows survive
        the whole prefill).  The projections are the exact ones dense
        prefill computes, so chunked and dense agree bit-for-bit."""
        plen = len(req.prompt)
        bucket = self._bucket(plen)
        se = req.src_embeds
        if se is None:
            se = np.zeros((bucket, self.cfg.d_model), np.float32)
        src = np.zeros((1, bucket, self.cfg.d_model), np.float32)
        src[0, :se.shape[0]] = se[:bucket]
        key = ("cross", bucket)
        if key not in self._prefills:
            cfg = self.cfg
            self._prefills[key] = jax.jit(
                lambda p, s: encode_cross(p, cfg, s))
        cross = self._prefills[key](self.params, jnp.asarray(src))
        slot = req.slot
        new_cross = {}
        for name, dst in self.cache.cross.items():
            src_rows = cross[name]
            # slot axis by leaf name: k/v are (L, B, Ssrc, ...), enc_out
            # is (B, Ssrc, d) — a shape heuristic misfires when Ssrc
            # happens to equal max_batch
            axis = 1 if name in ("k", "v") else 0
            new_cross[name] = jax.lax.dynamic_update_slice_in_dim(
                dst, src_rows.astype(dst.dtype), slot, axis=axis)
        self.cache = self.cache._replace(cross=new_cross)
        req.src_len = bucket

    # -- paging helpers -------------------------------------------------------
    def _make_room(self, need: int, protect: frozenset,
                   preempt: bool = True) -> bool:
        """Bring the pool to at least ``need`` free frames.  Escalation
        order: getfin poll, LRU eviction of unpinned cached pages,
        draining in-flight fetches (their frames become evictable), then
        — for growth, never for fresh admission — preempting a victim."""
        pool = self.page_pool
        if pool.n_free >= need:
            return True
        self.pager.poll()
        while pool.n_free < need:
            if self.pager.evict_lru(need - pool.n_free):
                continue
            if self._resuming:
                for req in list(self._resuming.values()):
                    self.pager.wait_arriving(req.rid)
                if self.pager.evict_lru(need - pool.n_free):
                    continue
            if not preempt or not self._preempt_one(protect):
                return False
        return True

    def _preempt_one(self, protect: frozenset) -> bool:
        """Park the scheduler's chosen victim — a running sequence
        (:meth:`_park`) or a half-prefilled one whose completed chunks
        are parked as-is (:meth:`_park_prefilling`).  The watermark
        policy picks the most recently admitted; the SLO policy picks
        the slot whose SLO is already blown or furthest from its
        deadline, batch tier first."""
        victims = [r for r in list(self.active.values())
                   + list(self.prefilling.values()) if r.rid not in protect]
        if not victims or len(self.active) + len(self.prefilling) <= 1:
            return False
        victim = self.sched.pick_victim(victims, self.clock())
        if victim.mid_prefill:
            self._park_prefilling(victim)
        else:
            self._park(victim)
        return True

    def _shed_pages(self, req: Request, valid: int,
                    hot_pages: Optional[int] = None) -> None:
        """Shared parking machinery: keep the hot tail cached in the
        pool (unpinned, LRU-evictable), move cold pages to the far tier
        — BULK astore for dirty ones, for free when the far copy is
        still current (clean-eviction fast path, §2.3 QoS split).

        A far copy is *current* when its stored valid-token tag equals
        the page's live token count (append-only KV never rewrites a
        position, so equal coverage means equal content) — this is what
        lets previously-parked pages, prefix-shared pages and re-fetched
        pages all park for free, while a page that grew since its last
        writeback pays a fresh astore.  SWA rings rewrite pages in place
        on wrap, so they always write back.  Shared frames are released,
        not freed: the prefix cache (or another sharer) keeps them.
        """
        rid = req.rid
        n_pages = pages_for(valid, self.page_size)
        # a frame allocated for the *next* write (pos on a page boundary)
        # holds no content yet — release it; resume growth re-allocates
        self.page_table.truncate(rid, n_pages)
        n_hot = min(self.hot_tail_pages if hot_pages is None else hot_pages,
                    n_pages)
        n_cold = n_pages - n_hot
        for logical in range(n_pages - 1, -1, -1):   # tail first: hot
            pte = self.page_table.entry(rid, logical)
            if pte.state is PageState.PARKED:
                continue                 # already far (and current, by
            self.page_table.unpin_page(rid, logical)  # the park invariant)
            cur = min(self.page_size, valid - logical * self.page_size)
            clean = (self.cfg.attention != "swa"
                     and self.pager.far_tokens(rid, logical) == cur)
            if logical >= n_cold:                    # hot tail: stays pooled
                frame = self.page_pool.frames[pte.phys]
                frame.data = None                    # content is in the pool
                frame.dirty = not clean
                frame.tokens = cur   # LRU eviction keeps the freshness tag
                self.page_pool.touch(pte.phys)
            elif clean:
                self.pager.park_clean(rid, logical)  # far copy current
            else:
                self.pager.writeback(rid, logical,
                                     self._read_frame(pte.phys), tokens=cur,
                                     qos=self.sched.store_qos(req))

    def _park(self, req: Request) -> None:
        """Preempt a running sequence: cold pages → far tier (BULK), hot
        tail stays cached *in the device pool* (unpinned, LRU-evictable),
        slot freed, request back to the head of the queue.  The KV never
        round-trips through a dense slot: cold pages are read
        frame-by-frame off the pool (the page-granularity astore
        payload), hot pages do not move at all."""
        slot = req.slot
        tokens = int(np.asarray(self.cache.pos)[slot])
        self._shed_pages(req, min(tokens, self.slot_tokens))
        req.residue = extract_aux_slot(self.cache, slot, self.max_batch)
        req.parked = True
        req.n_preempts += 1
        req.slot = None
        self._pt_np[slot] = self.trash_frame
        self._pt_dirty = True
        del self.active[slot]
        self.pool.release(slot)
        self.queue.insert(0, req)
        self.stats["preemptions"] += 1
        self._obs_phase(req, "parked")
        self.events.post(EventKind.PREEMPT, req.rid)

    def _park_prefilling(self, req: Request) -> None:
        """Cancel a half-prefilled sequence: its *completed* chunks park
        exactly like a running sequence's pages (hot tail pooled, cold
        written back), and the prompt remainder simply re-enters the
        chunk queue on resume — no prefill work is redone.  The non-KV
        carry (hybrid SSM state between chunks) already lives host-side
        in ``req.chunk_ssm``, so nothing dense is extracted."""
        slot = req.slot
        self._shed_pages(req, req.prefill_pos)
        req.parked = True
        req.n_preempts += 1
        req.slot = None
        req.chunk_rows = None            # rebuilt from the table on resume
        del self.prefilling[slot]
        self.pool.release(slot)
        self.queue.insert(0, req)
        self.stats["preemptions"] += 1
        self.stats["prefill_preempts"] += 1
        self._obs_phase(req, "parked")
        self.events.post(EventKind.PREEMPT, req.rid)

    def _start_resume(self, req: Request) -> bool:
        """Begin bringing a parked request back: prefetch of its parked
        pages (LATENCY QoS for interactive tier, the scheduler may
        demote batch resumes to STANDARD), hot tail first, overlapping
        decode.  A resume is a continuation, not a fresh admission, so
        like growth it is exempt from the low watermark — it only needs
        raw frames."""
        parked = self.page_table.logical_pages(req.rid, PageState.PARKED)
        if self.page_pool.n_free < len(parked) and \
                not self._make_room(len(parked), frozenset({req.rid}),
                                    preempt=False):
            return False
        self.pager.prefetch_seq(req.rid, tail_first=True,
                                qos=self.sched.fetch_qos(req))
        self._resuming[req.rid] = req
        self._obs_phase(req, "resuming")
        return True

    def _try_finish_resumes(self) -> None:
        """Slot in any resuming request whose pages have all arrived.
        Re-entry is a page-table patch: pin the frames, land any payload
        that is still host-side, point the slot's page-table row at the
        frames and restore the tiny aux state.  The KV itself is already
        where decode reads it.  A request parked *mid-prefill* re-enters
        the chunk queue instead of the decode batch: its device
        page-table row stays on the trash frame and its completed-chunk
        frames go back into ``chunk_rows`` for the next chunk to attend
        through."""
        for rid, req in list(self._resuming.items()):
            if not self.page_table.resident(rid):
                # pages evicted again under pressure mid-resume get a
                # fresh prefetch (no-op when all are in flight)
                self.pager.prefetch_seq(rid, tail_first=True,
                                        qos=self.sched.fetch_qos(req))
                continue
            if not self.pool.n_free:
                continue
            slot = self.pool.alloc()
            rows = np.full((self.pages_per_seq,), self.trash_frame, np.int32)
            for logical in range(self.page_table.n_pages(rid)):
                pte = self.page_table.entry(rid, logical)
                self.page_table.pin_page(rid, logical)
                self.page_pool.touch(pte.phys)
                self._land_frame(pte.phys)
                rows[logical] = pte.phys
            req.slot = slot
            req.parked = False
            # a request admitted straight onto far-tier prefix pages
            # arrives here having never run: that is an admission, not a
            # resume (preemption/resume stats must stay balanced)
            first_admit = req.admit_seq < 0
            req.admit_seq = next(self._admits)
            if req.mid_prefill:
                req.chunk_rows = rows
                if self.cfg.family == "encdec":
                    self._install_cross(req)     # cross rows left with the slot
                self.prefilling[slot] = req
            else:
                self._ensure_private_tail(req)
                rows = np.full((self.pages_per_seq,), self.trash_frame,
                               np.int32)
                for logical in range(self.page_table.n_pages(rid)):
                    rows[logical] = self.page_table.entry(rid, logical).phys
                self._pt_np[slot] = rows
                self._pt_dirty = True
                self.cache = insert_aux_slot(self.cache, req.residue,
                                             slot, self.max_batch)
                req.residue = None
                self.active[slot] = req
            del self._resuming[rid]
            self.stats["admitted" if first_admit else "resumes"] += 1
            self._obs_phase(req, "prefill" if req.mid_prefill else "decode")
            self.events.post(EventKind.ADMIT, rid)

    def _alloc_pinned(self, req: Request, n_tokens: int) -> None:
        """Allocate (pin + mark dirty) frames so ``req`` covers
        ``n_tokens`` positions and point its slot's page-table row at
        them — active slots own their pages.  While a request is still
        chunk-prefilling, its frames go into the host-side
        ``chunk_rows`` instead: the *device* row keeps pointing at the
        trash frame so the fused decode half of the mixed step cannot
        scribble on a half-written prompt."""
        mid = req.mid_prefill and req.chunk_rows is not None
        for logical in self.page_table.ensure_capacity(req.rid, n_tokens):
            pte = self.page_table.entry(req.rid, logical)
            self.page_table.pin_page(req.rid, logical)
            self.page_pool.mark_dirty(pte.phys)
            if mid:
                req.chunk_rows[logical] = pte.phys
            else:
                self._pt_np[req.slot, logical] = pte.phys
                self._pt_dirty = True

    def _ensure_private(self, req: Request, logical: int) -> None:
        """COW break: if the frame backing ``(req, logical)`` is a
        prefix-shared (copy-on-write) frame this step is about to write,
        remap the page onto a private duplicate first.  Unreachable on
        the supported sharing families by construction — only *full*
        prompt pages are shared and decode appends strictly after them —
        but the guard keeps the donated in-place pool scatters safe
        against any future schedule that routes a write at a shared
        frame."""
        pte = self.page_table.entry(req.rid, logical)
        if pte.phys == NOT_MAPPED:
            return
        frame = self.page_pool.frames[pte.phys]
        if not frame.cow or frame.refs <= 1:
            return
        old, new = self.page_table.remap_private(req.rid, logical)
        if new == old:
            return
        kv = self.cache.kv
        kp, vp = _copy_frame(kv["k_pages"], kv["v_pages"],
                             jnp.asarray(old, jnp.int32),
                             jnp.asarray(new, jnp.int32))
        self.cache = self.cache._replace(kv=dict(kv, k_pages=kp, v_pages=vp))
        if req.mid_prefill and req.chunk_rows is not None:
            req.chunk_rows[logical] = new
        elif req.slot is not None:
            self._pt_np[req.slot, logical] = new
            self._pt_dirty = True

    def _ensure_private_tail(self, req: Request) -> None:
        """Guard the page decode writes next (the sequence's last mapped
        page) against COW sharing before the slot goes active."""
        n = self.page_table.n_pages(req.rid)
        if n:
            self._ensure_private(req, n - 1)

    def _ensure_growth(self) -> None:
        """Before a decode step: every active sequence about to cross a
        page boundary gets a pinned frame, evicting/preempting under the
        watermark policy when the pool is short."""
        pos_np = np.asarray(self.cache.pos)     # one device sync per step
        for req in list(self.active.values()):
            if req.slot is None or req.slot not in self.active:
                continue                    # preempted by an earlier victim
            pos = int(pos_np[req.slot])
            if pos >= self.slot_tokens:
                continue                    # SWA ring wrapped: no growth
            wp = pos // self.page_size      # page this step's token writes
            if wp < self.page_table.n_pages(req.rid):
                self._ensure_private(req, wp)
            need = self.page_table.pages_needed(req.rid, pos + 1)
            if not need:
                continue
            if not self._make_room(need, frozenset({req.rid})):
                raise PagingError(
                    f"cannot grow request {req.rid}: pool of "
                    f"{self.page_pool.n_pages} pages exhausted")
            self._alloc_pinned(req, pos + 1)

    # -- scheduling ------------------------------------------------------------
    def _chunkable(self, req: Request) -> bool:
        """Chunk-queue admission requires the whole prompt to fit the
        slot's token capacity (an SWA ring that wraps mid-prompt would
        rewrite pages the chunk path still attends); longer prompts fall
        back to the legacy dense-prefill admission."""
        return (self.chunking and len(req.prompt) > 0
                and len(req.prompt) <= self.slot_tokens)

    def _admit_prefix(self, req: Request, hits: List[int]) -> bool:
        """Map prefix-cache hits onto the request's fresh page-table row.

        Device-resident hits are refcount-shared in place (zero traffic,
        zero compute); hits whose shared page lives only in the far tier
        make the request start *parked* — it rides the ordinary resume
        machinery (LATENCY prefetch of a private copy, including the
        resume-while-ARRIVING paths) before its first chunk.  Either
        way ``prefill_pos`` starts past the shared prefix, so those
        chunks are simply never queued.  Returns True on the far route.
        """
        self.page_table.register(req.rid)
        req.target_len = len(req.prompt)
        far = False
        for l in hits:
            key = self.prefix.far_key(l)
            if self.prefix.entry_state(l) is PageState.RESIDENT:
                phys = self.prefix.entry_phys(l)
                logical = self.page_table.append_shared(req.rid, phys)
                self.page_pool.touch(phys)
            else:
                far = True
                logical = self.page_table.append_parked(req.rid)
                self.stats["prefix_far_hits"] += 1
            # far alias (no copy: same host payload) so this mapping can
            # always park clean and a far hit fetches through the pager
            self.pager.store_far(req.rid, logical, self.far_tier.home(key),
                                 tokens=self.page_size)
        req.prefill_pos = len(hits) * self.page_size
        self.stats["prefix_hits"] += len(hits)
        self.stats["prefix_tokens_saved"] += req.prefill_pos
        if far:
            req.parked = True
        return far

    def _admit(self) -> None:
        if self.paging:
            self._try_finish_resumes()
        now = self.clock()
        self.sched.order_queue(self.queue, now)
        while self.queue:
            req = self.queue[0]
            if req.arrival_t > now:
                break                 # trace replay: not in the system yet
            if req.parked:                                # preempted: resume
                if req.rid in self._resuming or not self._start_resume(req):
                    break
                self.queue.pop(0)
                self._try_finish_resumes()
                continue
            if not self.pool.n_free:
                break
            hits: List[int] = []
            if self.paging:
                need = pages_for(min(len(req.prompt), self.slot_tokens),
                                 self.page_size)
                if self.prefix is not None and self._chunkable(req) \
                        and req.rid not in self.page_table.sequences():
                    hits = self.prefix.match(req.prompt)
                    # device-resident hits take no new frames
                    need -= sum(
                        1 for l in hits
                        if self.prefix.entry_state(l) is PageState.RESIDENT)
                if not self.sched.may_admit(req, need):
                    # SLO load shedding: the highest-priority admissible
                    # request is batch-tier and the pool is too tight to
                    # take it without risking interactive deadlines
                    self.stats["shed_admissions"] += 1
                    if self.tracer.enabled:
                        self.tracer.instant(
                            "engine", "sched", "shed",
                            {"rid": req.rid, "tier": req.tier.name,
                             "need_pages": need,
                             "free": self.page_pool.n_free})
                    break
                if not self.policy.can_admit(self.page_pool, need) and \
                        not self._make_room(need + self.policy.low,
                                            frozenset(), preempt=False):
                    break
            if hits and self._admit_prefix(req, hits):
                # far-tier hits: request left at the queue head, parked;
                # the next iteration routes it through _start_resume
                continue
            self.queue.pop(0)
            slot = self.pool.alloc()
            req.slot = slot
            if self._chunkable(req):
                # chunk-queue admission: install bookkeeping only — the
                # prompt is computed chunk-by-chunk by the mixed step,
                # interleaved with every running slot's decode
                if req.rid not in self.page_table.sequences():
                    self.page_table.register(req.rid)
                req.target_len = len(req.prompt)
                req.chunk_rows = np.full((self.pages_per_seq,),
                                         self.trash_frame, np.int32)
                # prefix hits already mapped: pin them for the slot and
                # point the chunk row at the shared frames
                for logical in range(self.page_table.n_pages(req.rid)):
                    self.page_table.pin_page(req.rid, logical)
                    req.chunk_rows[logical] = \
                        self.page_table.entry(req.rid, logical).phys
                if self.cfg.family == "hybrid":
                    req.chunk_ssm = jax.tree_util.tree_map(
                        np.copy, self._zero_chunk_ssm)
                if self.cfg.family == "encdec":
                    self._install_cross(req)
                req.admit_seq = next(self._admits)
                self.prefilling[slot] = req
                self.stats["admitted"] += 1
                self._obs_phase(req, "prefill")
                self.events.post(EventKind.ADMIT, req.rid)
                continue
            logits, single = self._prefill_one(req)
            if self.paging:
                self.page_table.register(req.rid)
                self._alloc_pinned(req,
                                   min(len(req.prompt), self.slot_tokens))
                self._install_sequence(req, single)
            else:
                self.cache = insert_slot(self.cache, single, slot,
                                         self.max_batch)
            req.admit_seq = next(self._admits)
            first = int(np.argmax(np.asarray(logits)[0]))
            req.generated.append(first)
            req.first_token_t = self.clock()
            req.token_ts.append(req.first_token_t)
            self.active[slot] = req
            self.stats["admitted"] += 1
            self._obs_phase(req, "decode")
            if self.tracer.enabled:
                self.tracer.instant(
                    "requests", f"req{req.rid}", "first_token",
                    {"ttft_s": req.first_token_t - req.arrival_t})
            self.events.post(EventKind.ADMIT, req.rid)
            self._finish_if_done(req)

    # -- chunk-queue scheduling (chunked paged prefill) ------------------------
    def _select_chunks(self) -> List:
        """Pick chunk-vs-decode work for this step.

        A chunk for the oldest admitting slots runs fused with the
        decode step when (a) the LATENCY aload window has room — resume
        traffic saturating the per-QoS window (§2.2 MACR) means parked
        pages are mid-flight and chunk compute would only delay their
        landing — and (b) the chunk's pages fit the pool without
        preempting anyone (free-page-watermark occupancy; chunk growth,
        like decode growth, is a continuation and so is exempt from the
        admission low watermark)."""
        if not self.prefilling:
            return []
        if self._resuming and not self.pager.windows.has_room(QoS.LATENCY):
            return []
        picks: List = []
        t_exact = None
        exact = self.cfg.family == "hybrid"    # pad tokens corrupt SSM state
        for req in self.sched.chunk_order(self.prefilling.values()):
            if len(picks) >= self.chunk_slots:
                break
            start = req.prefill_pos
            end = min(req.target_len, start + self.chunk_tokens)
            if exact and t_exact is not None and end - start != t_exact:
                continue                   # exact-shape batch: next step
            need = self.page_table.pages_needed(req.rid, end)
            if need and not self._make_room(need, frozenset({req.rid}),
                                            preempt=False):
                continue                   # pool tight: decode-only step
            if exact and t_exact is None:
                t_exact = end - start      # pin shape only once a row fits
            self._alloc_pinned(req, end)
            picks.append((req, start, end))
        return picks

    def _force_chunk(self) -> List:
        """Nothing decodable and no chunk fit the pool politely: force
        the oldest admitting slot's chunk through, preempting (parking
        another half-prefilled victim) if that is what it takes — the
        loop must always progress."""
        req = min(self.prefilling.values(), key=lambda r: r.admit_seq)
        end = min(req.target_len, req.prefill_pos + self.chunk_tokens)
        need = self.page_table.pages_needed(req.rid, end)
        if need and not self._make_room(need, frozenset({req.rid}),
                                        preempt=True):
            raise PagingError(
                f"chunked prefill of request {req.rid} cannot progress: "
                f"pool of {self.page_pool.n_pages} pages exhausted")
        self._alloc_pinned(req, end)
        return [(req, req.prefill_pos, end)]

    def _build_chunk(self, picks) -> Dict[str, Any]:
        """Assemble the mixed step's chunk operand (C = ``chunk_slots``
        rows, unused rows inert with length 0 / trash page rows)."""
        C = self.chunk_slots
        if self.cfg.family == "hybrid":
            T = picks[0][2] - picks[0][1]  # exact shapes (no pad tokens)
        else:
            T = self.chunk_tokens
        tokens = np.zeros((C, T), np.int32)
        offset = np.zeros((C,), np.int32)
        length = np.zeros((C,), np.int32)
        slots = np.zeros((C,), np.int32)
        src_len = np.zeros((C,), np.int32)
        rows = np.full((C, self.pages_per_seq), self.trash_frame, np.int32)
        for i, (req, start, end) in enumerate(picks):
            n = end - start
            tokens[i, :n] = req.prompt[start:end]
            offset[i] = start
            length[i] = n
            slots[i] = req.slot
            src_len[i] = req.src_len
            rows[i] = req.chunk_rows
        chunk = {"tokens": jnp.asarray(tokens),
                 "offset": jnp.asarray(offset),
                 "length": jnp.asarray(length),
                 "page_rows": jnp.asarray(rows)}
        if self.cfg.family == "encdec":
            chunk["slots"] = jnp.asarray(slots)
            chunk["src_len"] = jnp.asarray(src_len)
        if self.cfg.family == "hybrid":
            trees = [r.chunk_ssm for r, _, _ in picks]
            trees += [self._zero_chunk_ssm] * (C - len(picks))
            chunk["ssm"] = jax.tree_util.tree_map(
                lambda *xs: jnp.asarray(np.concatenate(xs, axis=1)), *trees)
        return chunk

    def _finish_chunks(self, picks, chunk_logits, carry) -> None:
        """Advance every picked request past its chunk; rows that just
        covered their prompt's last token graduate to the decode batch
        (their first sampled token is the chunk's last-valid logits)."""
        tr = self.tracer
        for i, (req, start, end) in enumerate(picks):
            req.prefill_pos = end
            if tr.enabled:
                tr.instant("requests", f"req{req.rid}", "chunk",
                           {"start": start, "end": end,
                            "target": req.target_len})
            if self.cfg.family == "hybrid":
                req.chunk_ssm = jax.tree_util.tree_map(
                    lambda a: np.asarray(a[:, i:i + 1]), carry)
            if end >= req.target_len:
                self._finalize_prefill(req, chunk_logits[i])

    def _finalize_prefill(self, req: Request, logits_row) -> None:
        """Graduate a fully-prefilled request into the decode batch: the
        device page-table row flips from the trash frame to the real
        frames (one host-mirror write — the KV is already in its pool
        frames), pos and any SSM carry land in the cache, and the first
        token comes from the final chunk's logits at the prompt's last
        valid position — matching the dense path's ``last_pos`` exactly."""
        slot = req.slot
        self._pt_np[slot] = req.chunk_rows
        self._pt_dirty = True
        pos_row = jnp.asarray([req.target_len], jnp.int32)
        cache = self.cache
        new_pos = jax.lax.dynamic_update_slice_in_dim(
            cache.pos, pos_row.astype(cache.pos.dtype), slot, axis=0)
        ssm = cache.ssm
        if self.cfg.family == "hybrid":
            ssm = jax.tree_util.tree_map(
                lambda dst, src: jax.lax.dynamic_update_slice_in_dim(
                    dst, jnp.asarray(src).astype(dst.dtype), slot, axis=1),
                ssm, req.chunk_ssm)
            req.chunk_ssm = None
        self.cache = cache._replace(pos=new_pos, ssm=ssm)
        req.chunk_rows = None
        del self.prefilling[slot]
        if self.prefix is not None:
            # donate the prompt's full pages to the prefix cache: future
            # requests with the same prefix share these frames instead
            # of re-running their chunks
            self.prefix.intern(req.prompt, req.rid, self._read_frame)
        first = int(np.argmax(np.asarray(logits_row)))
        req.generated.append(first)
        req.first_token_t = self.clock()
        req.token_ts.append(req.first_token_t)
        self.active[slot] = req
        self._obs_phase(req, "decode")
        if self.tracer.enabled:
            self.tracer.instant(
                "requests", f"req{req.rid}", "first_token",
                {"ttft_s": req.first_token_t - req.arrival_t})
        self._finish_if_done(req)

    def _step(self) -> None:
        if self.paging:
            self._ensure_growth()
        picks = self._select_chunks() if self.chunking else []
        if self.chunking and not picks and not self.active and \
                self.prefilling and not self._resuming:
            picks = self._force_chunk()
        if not self.active and not picks:
            return
        toks = np.zeros((self.max_batch, 1), np.int32)
        for slot, req in self.active.items():
            toks[slot, 0] = req.generated[-1]
        if self.paging and self._pt_dirty:
            # refresh the device page-table rows from the host mirror
            # (skipped on steady-state steps with no scheduling events)
            kv = self.cache.kv
            self.cache = self.cache._replace(
                kv=dict(kv, page_table=jnp.asarray(self._pt_np)))
            self._pt_dirty = False
        if picks:
            chunk = self._build_chunk(picks)
            logits, chunk_logits, carry, self.cache = self._mixed(
                self.params, self.cache, jnp.asarray(toks), chunk)
            self.stats["mixed_steps"] += 1
            self.stats["chunks"] += len(picks)
        else:
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(toks))
        self.stats["steps"] += 1
        if self.active:
            logits = np.asarray(logits)
            t_now = self.clock()
            tr = self.tracer
            for slot, req in list(self.active.items()):
                nxt = int(np.argmax(logits[slot]))
                req.generated.append(nxt)
                req.token_ts.append(t_now)
                if tr.enabled:
                    tr.instant("requests", f"req{req.rid}", "token",
                               {"n": len(req.generated)})
                self._finish_if_done(req)
        if picks:
            self._finish_chunks(picks, np.asarray(chunk_logits), carry)

    def _offload_finished(self, req: Request) -> None:
        """Park a finished sequence page-by-page into THE far tier — the
        same BULK writeback / clean-park machinery preemption uses, no
        sequence-granularity side store.  The tiny aux residue (SSM
        state, cross KV, positions) and the page count ride along as one
        more far-tier entry; :meth:`fetch_finished` reassembles."""
        slot = req.slot
        rid = req.rid
        tokens = min(int(np.asarray(self.cache.pos)[slot]), self.slot_tokens)
        aux = extract_aux_slot(self.cache, slot, self.max_batch)
        self.far_tier.offload(
            (rid, "aux"),
            {"aux": aux, "tokens": tokens,
             "pages": pages_for(tokens, self.page_size)})
        # every page goes far (hot_pages=0): the sequence is leaving the
        # device; shared prefix pages park for free via their aliases
        self._shed_pages(req, tokens, hot_pages=0)

    def fetch_finished(self, rid: int) -> Cache:
        """Reassemble a finished, offloaded request's dense single-
        sequence cache from its far-tier pages (LATENCY aloads, all
        issued before the first wait so the transfers overlap).

        Fault-safe: entries are discarded only after *every* transfer
        has verifiably landed — a fault mid-fetch raises, but the far
        copies survive and a retry re-issues the lost aloads (the PR 3
        pager fault discipline applied to the reuse path)."""
        if not self.offload_finished:
            raise PagingError("engine was not built with offload_finished")
        tier = self.far_tier
        meta = tier.get((rid, "aux"))
        n_pages, tokens = meta["pages"], meta["tokens"]
        keys = [(rid, logical) for logical in range(n_pages)]
        for key in keys:
            tier.prefetch(key)                  # overlap all page fetches
        kv = self.cache.kv
        L, _, page, Hkv, D = kv["k_pages"].shape
        pages = []
        for logical, key in enumerate(keys):
            data = tier.get(key)                # raises on fault; nothing
            take = min(page, tokens - logical * page)   # discarded yet
            if take <= 0:
                break
            pages.append({"k": np.asarray(data["k"])[:, None, :take],
                          "v": np.asarray(data["v"])[:, None, :take]})
        # all transfers verified complete: now the entries may go
        for key in keys:
            tier.discard(key)
        tier.discard((rid, "aux"))
        aux = meta["aux"]
        kdt = np.dtype(kv["k_pages"].dtype)
        residue = Cache(
            kv={"k": np.zeros((L, 1, 0, Hkv, D), kdt),
                "v": np.zeros((L, 1, 0, Hkv, D), kdt),
                "pos": np.zeros((), np.int32),
                "slots": np.asarray(self.slot_tokens, np.int32)},
            ssm=aux["ssm"], cross=aux["cross"], pos=aux["pos"])
        return join_kv_pages(residue, pages, self.slot_tokens)

    def _finish_if_done(self, req: Request) -> None:
        if not req.done:
            return
        slot = req.slot
        if slot is not None and slot in self.active:
            del self.active[slot]
        if slot is not None:
            if self.offload_finished:
                self._offload_finished(req)
            if self.paging:
                self._pt_np[slot] = self.trash_frame
                self._pt_dirty = True
            self.pool.release(slot)
        req.done_t = self.clock()
        self.finished[req.rid] = req
        self.stats["slo_attained" if req.slo_attained()
                   else "slo_missed"] += 1
        if req.token_ts:
            tier = req.tier.name
            self.metrics.observe(f"engine/ttft_s/{tier}", req.ttft)
            if len(req.token_ts) > 1:
                self.metrics.observe(f"engine/tpot_s/{tier}", req.tpot)
        if self.tracer.enabled:
            self._obs_phase(req, None)       # close the lifecycle track
            # everything trace_report needs to rebuild slo_report() from
            # the trace alone rides on this one instant
            self.tracer.instant(
                "requests", f"req{req.rid}", "finish",
                {"tier": req.tier.name, "arrival": req.arrival_t,
                 "first_token": req.first_token_t, "done": req.done_t,
                 "n_new": len(req.generated),
                 "n_preempts": req.n_preempts,
                 "ttft_slo": req.ttft_slo, "tpot_slo": req.tpot_slo,
                 "attained": bool(req.slo_attained())})
        self.events.post(EventKind.COMPLETE, req.rid)
        self.events.drain()

    # -- SLO telemetry --------------------------------------------------------
    def slo_report(self) -> Dict[str, Any]:
        """Per-tier SLO attainment over the finished requests.

        All numbers live on the engine's one clock (virtual seconds by
        default).  *Goodput* is the serving-paper definition: tokens
        generated by requests that met every SLO they carry — work that
        arrived uselessly late counts for nothing.  Example::

            eng.run()
            rep = eng.slo_report()
            rep["interactive"]["goodput"]      # SLO-attaining tok/s
            rep["interactive"]["ttft_p95"]
        """
        elapsed = max(self.clock(), 1e-12)
        out: Dict[str, Any] = {"elapsed": elapsed}
        for tier in Tier:
            reqs = [r for r in self.finished.values() if r.tier is tier]
            ttfts = sorted(r.ttft for r in reqs if r.token_ts)
            good = [r for r in reqs if r.slo_attained()]
            good_tokens = sum(len(r.generated) for r in good)
            out[tier.name.lower()] = {
                "n": len(reqs),
                "attained": len(good),
                "attainment": len(good) / len(reqs) if reqs else 1.0,
                "good_tokens": good_tokens,
                "goodput": good_tokens / elapsed,
                "ttft_p50": (float(np.percentile(ttfts, 50))
                             if ttfts else 0.0),
                "ttft_p95": (float(np.percentile(ttfts, 95))
                             if ttfts else 0.0),
                "ttft_p99": (float(np.percentile(ttfts, 99))
                             if ttfts else 0.0),
            }
        return out

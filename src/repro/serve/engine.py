"""Continuous-batching serving engine.

The scheduler is the paper's *event-driven model* (§2.3.2) applied to
requests instead of cache lines: decode steps are the event loop; new
requests are admitted into free slots the moment one finishes (no
drain-the-batch barrier); parked sequences come back from the host KV
tier via AMU prefetch that overlaps the current decode step.

Decode runs with a *fixed* batch of ``max_batch`` slots (one compiled
program); per-slot positions (``Cache.pos`` is per-sequence) make the
mixed-depth batch correct.  Empty slots decode garbage that is simply
ignored — the standard fixed-shape trade on TPU.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import (Cache, decode_step, init_cache, prefill)
from repro.serve.kv_cache import (KVOffloadTier, SlotPool, extract_slot,
                                  insert_slot)

__all__ = ["Request", "Engine"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (plen,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    src_embeds: Optional[np.ndarray] = None   # encdec frontend stub
    # filled by the engine:
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    submitted_t: float = 0.0
    first_token_t: float = 0.0
    done_t: float = 0.0

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return bool(self.generated and self.eos_id is not None
                    and self.generated[-1] == self.eos_id)


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_batch: int = 4,
        max_len: int = 256,
        prefill_buckets: tuple = (32, 64, 128, 256),
        greedy: bool = True,
        offload_finished: bool = False,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.buckets = tuple(sorted(b for b in prefill_buckets
                                    if b <= max_len)) or (max_len,)
        self.greedy = greedy
        self.clock = clock
        self.pool = SlotPool(max_batch)
        self.cache: Cache = init_cache(cfg, max_batch, max_len)
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}     # slot -> request
        self.finished: Dict[int, Request] = {}
        self.kv_tier = KVOffloadTier() if offload_finished else None
        self._ids = itertools.count()
        self._decode = jax.jit(
            lambda p, c, t: decode_step(p, cfg, c, t))
        self._prefills: Dict[int, Any] = {}
        self.stats = {"steps": 0, "prefills": 0, "admitted": 0}

    # -- public API ----------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int = 16,
               eos_id: Optional[int] = None,
               src_embeds: Optional[np.ndarray] = None) -> int:
        rid = next(self._ids)
        req = Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      src_embeds=src_embeds, submitted_t=self.clock())
        self.queue.append(req)
        return rid

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Event loop until every submitted request completes."""
        for _ in range(max_steps):
            if not self.queue and not self.active:
                break
            self._admit()
            if self.active:
                self._step()
        return {r.rid: r.generated for r in self.finished.values()}

    # -- internals ------------------------------------------------------------
    def _bucket(self, plen: int) -> int:
        # SSM/hybrid state is corrupted by pad tokens, so exact lengths
        # there; attention families pad to the next bucket (cache entries
        # beyond plen are never attended: pos starts at plen).
        if self.cfg.family in ("ssm", "hybrid"):
            return plen
        for b in self.buckets:
            if plen <= b:
                return b
        return self.max_len

    def _prefill_one(self, req: Request):
        plen = len(req.prompt)
        bucket = self._bucket(plen)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = req.prompt
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "encdec":
            se = req.src_embeds
            if se is None:
                se = np.zeros((bucket, self.cfg.d_model), np.float32)
            src = np.zeros((1, bucket, self.cfg.d_model), np.float32)
            src[0, :se.shape[0]] = se[:bucket]
            batch["src_embeds"] = jnp.asarray(src)
        if self.cfg.mrope_sections:
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(bucket, dtype=jnp.int32), (3, 1, bucket))
        key = (bucket, self.cfg.family)
        if key not in self._prefills:
            cfg = self.cfg
            self._prefills[key] = jax.jit(
                lambda p, b: prefill(p, cfg, b, max_len=self.max_len))
        logits, single = self._prefills[key](self.params, batch)
        self.stats["prefills"] += 1
        # true position is plen (ignore pad tail), and next token comes
        # from the logits at plen-1 — recompute it from the last real
        # token by letting decode handle it: set pos = plen.
        single = single._replace(pos=jnp.full((1,), plen, jnp.int32))
        return logits, single

    def _admit(self) -> None:
        while self.queue and self.pool.n_free:
            req = self.queue.pop(0)
            slot = self.pool.alloc()
            logits, single = self._prefill_one(req)
            self.cache = insert_slot(self.cache, single, slot, self.max_batch)
            req.slot = slot
            first = int(np.argmax(np.asarray(logits)[0]))
            req.generated.append(first)
            req.first_token_t = self.clock()
            self.active[slot] = req
            self.stats["admitted"] += 1
            self._finish_if_done(req)

    def _step(self) -> None:
        toks = np.zeros((self.max_batch, 1), np.int32)
        for slot, req in self.active.items():
            toks[slot, 0] = req.generated[-1]
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        self.stats["steps"] += 1
        logits = np.asarray(logits)
        for slot, req in list(self.active.items()):
            nxt = int(np.argmax(logits[slot]))
            req.generated.append(nxt)
            self._finish_if_done(req)

    def _finish_if_done(self, req: Request) -> None:
        if not req.done:
            return
        slot = req.slot
        if slot is not None and slot in self.active:
            del self.active[slot]
        if slot is not None:
            if self.kv_tier is not None:
                self.kv_tier.park(req.rid, extract_slot(
                    self.cache, slot, self.max_batch))
            self.pool.release(slot)
        req.done_t = self.clock()
        self.finished[req.rid] = req

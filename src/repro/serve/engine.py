"""Continuous-batching serving engine over the paged KV subsystem.

The scheduler is the paper's *event-driven model* (§2.3.2) applied to
requests instead of cache lines: decode steps are the event loop's
ticks; pager ``getfin`` completions post PAGE_ARRIVED events; admission
and preemption decisions come from *free-page watermarks* over the
device page pool (``repro.paging``) instead of free-slot counts.  This
is what lets the engine admit more concurrent sequences than device
memory can hold:

  * each sequence's KV is accounted in fixed-size pages of a shared
    :class:`~repro.paging.PagePool`; active slots pin their pages,
  * when growth (or a new admission) exceeds the pool, a victim is
    *preempted*: only its **cold** pages are written back (BULK-QoS
    ``astore``; pages whose far-tier copy is still current move for
    free), while the hot tail stays cached on-device,
  * rescheduling prefetches the parked pages **hot tail first** with
    LATENCY-QoS ``aload`` that overlaps the current decode step; the
    sequence re-enters a slot the moment its residency bits are all set
    — no re-prefill, bit-exact resume.

Decode computes **directly on the paged layout**: the device cache is a
:class:`~repro.models.model.PagedCache` whose k/v live in the pool's
page frames, and the serve step's attention reads them through the
per-slot page table (:func:`~repro.models.attention.
paged_decode_attention_block` — the Pallas scalar-prefetch gather on
TPU).  Preemption parks cold pages without ever extracting a dense
slot; resume is a page-table patch plus a LATENCY prefetch.  The
admit/preempt/resume hot path performs **zero dense KV
re-materialisation** — ``extract_slot``/``insert_slot`` survive only on
the non-paged fallback, exactly the round-trip the AMU papers argue
against eliminating elsewhere.

**The storage layer is an explicit two-tier hierarchy**: the device
page pool (near tier) over ONE host
:class:`~repro.core.offload.FarMemoryTier` behind the pager.  Every
cold page is a page-granularity resident of that tier — preempted
pages via BULK writeback (or for free when the far copy's valid-token
tag is current), watermark-evicted pages via the pager's LRU
``balance`` loop that runs every tick the free-frame count sits under
the low watermark, and *finished* sequences' KV via the same shed
path (``offload_finished``; ``fetch_finished`` reassembles with
overlapped LATENCY aloads, discarding entries only after every
transfer verifiably landed).  There is no sequence-granularity side
store.

**Cross-request prefix sharing** (``prefix_cache=True``) sits on top:
full prompt pages are content-addressed by a rolling token-id hash
(:mod:`repro.paging.prefix_cache`) and interned at prefill
graduation; a later request whose prompt starts with the same tokens
maps its page-table rows onto the shared frames — refcounted + COW on
a device hit, one LATENCY page fetch on a far-tier hit — and its
prefill simply starts past them (``prefill_pos``), so a system prompt
shared by thousands of users costs one prefill.  Only the partial
boundary page and the unseen tail are computed; outputs stay
token-exact with the dense engine.

**Prefill is chunked and continuously batched** (``chunk_tokens``): the
last dense-KV hold-out — admit-then-scatter whole-prompt prefill — is
replaced by a *chunk queue*.  Admission installs a slot and page-table
bookkeeping only; the prompt is then computed in chunks **on the pool
layout** (:func:`~repro.models.model.prefill_chunk` scatters each
chunk's K/V straight into its mapped frames while flash-attending the
pool-resident prefix), fused with every running slot's decode token in
one jitted mixed step (:func:`~repro.dist.steps.make_mixed_step`).  The
scheduler picks chunk-vs-decode work off free-page watermarks and the
pager's LATENCY-window occupancy, and preemption can cancel a
half-prefilled sequence by parking its completed chunks — the prompt
remainder re-enters the chunk queue on resume.  A new request therefore
never serialises a dense-prefill bubble in front of running decodes:
the request-level massive parallelism the follow-up AMU paper
(2404.11044) targets.  With ``chunk_tokens=None`` (default) admission
falls back to the legacy whole-prompt dense prefill; both paths are
token-exact with a dense non-paged run.

Decode itself is mesh-sharded: the step function comes from
``repro.dist.steps.make_serve_step`` (TP-sharded params, paged-cache
PartitionSpecs) bound to the engine's mesh — a 1×1 mesh by default, the
production (data, model) mesh when one is passed in.  Decode runs with
a *fixed* batch of ``max_batch`` slots (one compiled program); per-slot
positions make the mixed-depth batch correct, and empty slots decode
garbage into a reserved *trash frame* that no live sequence maps — the
standard fixed-shape trade on TPU, made safe at page granularity.

**The engine is assembled from role components** (this module is the
assembly; the behaviour lives in the mixins):

  * :class:`~repro.serve.policy.SchedulerPolicy` /
    :class:`~repro.serve.policy.SLOScheduler` — every discretionary
    scheduling decision (``serve/policy.py``),
  * :class:`~repro.serve.admission.AdmissionMixin` — dense + chunked
    prefill admission, prefix mapping, and the DECODE-role
    ``admit_handoff`` (``serve/admission.py``),
  * :class:`~repro.serve.transfer.TransferMixin` — park/resume,
    room-making, finished-sequence offload/fetch, and the PREFILL-role
    handoff publish (``serve/transfer.py``),
  * :class:`~repro.serve.decode.DecodeMixin` — the step loop, chunk
    scheduling, graduation, and the finish path with the
    ``_role_done`` hook (``serve/decode.py``).

An :class:`~repro.serve.config.EngineRole` parameterises the assembly:
``FUSED`` (default) is the single-engine pipeline, bit-identical to the
pre-split engine; ``PREFILL``/``DECODE`` run two engines against ONE
shared far tier with the park/resume machinery pointed *across* them —
see :mod:`repro.serve.disagg` and ``docs/ARCHITECTURE.md``.
"""

from __future__ import annotations

import itertools
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.steps import make_mixed_step, make_serve_step
from repro.launch.mesh import make_mesh_compat
from repro.models import ssm as ssm_mod
from repro.models.model import init_cache, init_paged_cache
from repro.obs import (MetricsRegistry, Tracer, to_chrome_trace,
                       write_chrome_trace, write_metrics)
from repro.paging import (DeadlineQueue, EventKind, EventLoop, PagePool,
                          PageState, PageTable, Pager, PagingError,
                          PrefixCache, WatermarkPolicy, pages_for)
from repro.serve.admission import AdmissionMixin
from repro.serve.config import (EngineConfig, EngineRole, Tier,
                                VirtualClock, engine_config_from_kwargs)
from repro.serve.decode import DecodeMixin
from repro.serve.disagg import HandoffBoard
from repro.serve.kv_cache import SlotPool
from repro.serve.policy import SCHEDULERS as _SCHEDULERS
from repro.serve.policy import SchedulerPolicy, SLOScheduler
from repro.serve.request import Request
from repro.serve.speculate import NgramProposer
from repro.serve.transfer import TransferMixin

__all__ = ["Request", "Engine", "SchedulerPolicy", "SLOScheduler"]


class Engine(AdmissionMixin, TransferMixin, DecodeMixin):
    """Continuous-batching serving engine on the paged far-memory KV.

    The module docstring describes the design; operationally::

        eng = Engine(cfg, params, EngineConfig(
            max_batch=4, max_len=256,
            paging=PagingConfig(page_size=16,
                                device_pages=48),   # oversubscribed
            chunking=ChunkingConfig(chunk_tokens=32)))  # chunked prefill
        for p in prompts:
            eng.submit(p, max_new_tokens=16)
        outputs = eng.run()                           # {rid: tokens}

    Construction takes one frozen :class:`~repro.serve.config.
    EngineConfig` (the documented path; the pre-config flat kwargs are
    still accepted for one release with a DeprecationWarning).  Knobs:
    ``paging.device_pages`` below ``max_batch * pages_per_seq``
    oversubscribes the pool (watermark admission + preemption, §2.3.2);
    ``chunking.chunk_tokens`` switches admission to the chunk queue
    (mixed prefill/decode steps); ``chunking.prefix_cache=True`` adds
    cross-request prefix sharing on top of it (content-addressed prompt
    pages; dense/moe global-attention families);
    ``paging.offload_finished`` parks finished sequences' pages in the
    far tier for later :meth:`fetch_finished` reuse;
    ``paging.enabled=False`` is the dense A/B reference;
    ``kernel_impl`` selects the paged-attention backend
    (``auto``/``pallas``/``interpret``/``xla``);
    ``paging.pager_factory`` injects a custom
    :class:`~repro.paging.Pager` (tests use a simulated-latency AMU
    backend); ``scheduler.policy="slo"`` switches scheduling from
    utilisation to goodput (see :class:`SLOScheduler`); ``role``
    selects the disaggregated half this engine runs (default
    ``"fused"`` — see :mod:`repro.serve.disagg`).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        config: Optional[EngineConfig] = None,
        **legacy_kwargs,
    ):
        if legacy_kwargs:
            config = engine_config_from_kwargs(config, **legacy_kwargs)
        ec = config or EngineConfig()
        pg, ck, sc = ec.paging, ec.chunking, ec.scheduler
        max_batch, max_len = ec.max_batch, ec.max_len
        self.config = ec
        self.sched_cfg = sc
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.buckets = tuple(sorted(b for b in ec.prefill_buckets
                                    if b <= max_len)) or (max_len,)
        self.greedy = ec.greedy
        # ONE clock for every request timestamp (arrival, first token,
        # per-token, completion).  Default: an engine-owned VirtualClock
        # advanced by step_dt per tick, in lockstep with the pager's
        # simulated AMU — deterministic SLO measurement.  Injecting
        # e.g. time.monotonic opts into wall-clock telemetry.
        self.clock = sc.clock if sc.clock is not None else VirtualClock()
        self._own_clock = sc.clock is None
        # -- unified telemetry: one registry + one tracer on THE clock ------
        # (repro.obs; ec.obs.tracing turns span emission on — default off,
        # in which case every instrumented site costs one branch)
        self.metrics = MetricsRegistry()
        self.tracer = Tracer(clock=self.clock, enabled=ec.obs.tracing)
        self._phase_span: Dict[int, int] = {}    # rid -> open lifecycle sid
        self._obs_started: set = set()           # rids with a queued span
        self.pool = SlotPool(max_batch)
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}     # slot -> request
        self.finished: Dict[int, Request] = {}
        self.offload_finished = pg.offload_finished
        # rid allocation is a plain counter (not itertools.count) so a
        # DECODE-role engine can bump it past handed-off rids — local
        # submissions and adopted requests share one id space
        self._next_rid = 0
        self._admits = itertools.count()

        # -- page-granularity KV residency over a fixed device pool --------
        # (decided before the decode step is built: the step consumes the
        # paged layout directly when the family has attention KV)
        page_size = pg.page_size
        shapes = jax.eval_shape(lambda: init_cache(cfg, max_batch, max_len))
        kv_shapes = shapes.kv if isinstance(shapes.kv, dict) else {}
        self.paging = ("k" in kv_shapes) if pg.enabled is None else \
            (pg.enabled and "k" in kv_shapes)
        self.page_size = page_size
        self.step_dt = sc.step_dt
        self.hot_tail_pages = max(0, pg.hot_tail_pages)
        self._resuming: Dict[int, Request] = {}
        if self.paging:
            k = kv_shapes["k"]
            self.slot_tokens = int(k.shape[2])       # ring size for SWA
            if self.slot_tokens % page_size:
                raise PagingError(
                    f"page_size {page_size} must divide the per-sequence "
                    f"token capacity {self.slot_tokens}")
            self.pages_per_seq = self.slot_tokens // page_size
            n_pages = pg.device_pages if pg.device_pages is not None \
                else max_batch * self.pages_per_seq
            page_nbytes = int(2 * k.shape[0] * page_size * k.shape[3]
                              * k.shape[4] * k.dtype.itemsize)
            self.page_pool = PagePool(n_pages, page_size)
            self.page_table = PageTable(self.page_pool)
            if pg.pager_factory is not None:
                self.pager = pg.pager_factory(self.page_pool,
                                              self.page_table,
                                              page_nbytes=page_nbytes)
            else:
                self.pager = Pager(self.page_pool, self.page_table,
                                   page_nbytes=page_nbytes)
            if self.pager.read_frame is None:    # keep a factory's hook
                self.pager.read_frame = self._read_frame
            # adopt the pager (factory-built or not) into the engine's
            # registry + tracer: its ad-hoc stats migrate into the
            # "pager" counter group and its AMU/page-table emit spans on
            # the engine clock
            self.pager.bind_obs(self.metrics, self.tracer)
            # THE far tier: one FarMemoryTier behind the pager holds
            # every cold page — preempted, watermark-evicted, finished —
            # plus finished sequences' aux residues and the prefix
            # cache's shared page homes.  Under disaggregation the
            # pager_factory points two engines' pagers at ONE shared
            # tier (see repro.serve.disagg.tier_pager_factory).
            self.far_tier = self.pager.tier
            # device frames: pool frames + one trash frame at the end
            self.trash_frame = n_pages
            self.cache: Any = init_paged_cache(
                cfg, max_batch, max_len, n_frames=n_pages + 1,
                page_size=page_size)
            self._pt_np = np.full((max_batch, self.pages_per_seq),
                                  self.trash_frame, np.int32)
            self._pt_dirty = True
        else:
            self.slot_tokens = 0
            self.page_pool = self.page_table = self.pager = None
            self.far_tier = None
            self.cache = init_cache(cfg, max_batch, max_len)
        if self.offload_finished and not self.paging:
            raise PagingError(
                "offload_finished requires the paged engine: finished KV "
                "is parked page-by-page through the pager's far tier")
        # -- engine role: which half of the pipeline this assembly runs ----
        self.role = EngineRole(ec.role)
        if self.role is not EngineRole.FUSED and not self.paging:
            raise PagingError(
                "disaggregated roles require the paged engine: the "
                "prefill/decode handoff travels through the far tier")
        if self.role is EngineRole.PREFILL:
            # graduation IS an offload_finished park into the (shared)
            # far tier — the role implies the flag
            self.offload_finished = True
        self.handoff = ec.handoff
        if self.role is EngineRole.PREFILL and self.handoff is None:
            self.handoff = HandoffBoard()
        self.policy = pg.watermark or WatermarkPolicy(low=0, critical=0)
        # the scheduling-policy layer: every discretionary decision
        # (queue order, victim, chunk order, per-request QoS) goes
        # through self.sched — see SchedulerPolicy / SLOScheduler
        if sc.policy not in _SCHEDULERS:
            raise PagingError(
                f"unknown scheduler policy {sc.policy!r}; "
                f"expected one of {sorted(_SCHEDULERS)}")
        self.sched = _SCHEDULERS[sc.policy](self)
        self.deadlines = DeadlineQueue()

        # -- mesh-sharded decode step (dist.steps, not a raw jit) ----------
        self.mesh = ec.mesh if ec.mesh is not None else \
            make_mesh_compat((1, 1), ("data", "model"))
        shape = ShapeConfig("serve_engine", max_len, max_batch, "decode")
        # cache donated: the step aliases the pool frames in place —
        # no per-token copy of the KV pool (self.cache is rebound to the
        # step's output immediately, so the donation is safe)
        self._decode, self._decode_specs = make_serve_step(
            cfg, self.mesh, shape, donate=True, paged=self.paging,
            kernel_impl=ec.kernel_impl)
        self._prefills: Dict[Any, Any] = {}

        # -- chunk-queue admission (chunked paged prefill) ------------------
        # admission installs page-table rows only; prompts are then fed
        # through the mixed step in chunks that interleave with decode
        self.chunk_tokens = int(ck.chunk_tokens) if ck.chunk_tokens else 0
        self.chunk_slots = max(1, int(ck.chunk_slots))
        self.chunking = bool(self.chunk_tokens) and self.paging
        self.prefilling: Dict[int, Request] = {}     # slot -> admitting req
        if self.chunking:
            self._mixed, self._mixed_specs = make_mixed_step(
                cfg, self.mesh, shape, donate=True,
                kernel_impl=ec.kernel_impl)
            if cfg.family == "hybrid":
                s = ssm_mod.mamba2_state_init(cfg, 1)
                self._zero_chunk_ssm = jax.tree_util.tree_map(
                    lambda a: np.zeros((cfg.num_layers,) + a.shape,
                                       np.asarray(a).dtype), s)

        # -- cross-request prefix sharing (content-addressed prompt pages)
        # full prompt pages are interned by rolling token-id hash at
        # prefill graduation; later requests map their page-table rows
        # onto the shared frames (device hit) or fetch a private copy
        # with a LATENCY aload (far hit) and skip those prefill chunks.
        # Supported where the shared KV is position- and content-exact
        # for every sharer: global-attention dense/moe (append-only KV,
        # absolute rope; SWA ring wrap rewrites pages in place, and
        # hybrid/encdec carry non-KV per-request prefix state).
        self.prefix: Optional[PrefixCache] = None
        if ck.prefix_cache:
            if not self.chunking:
                raise PagingError(
                    "prefix_cache requires chunked paged admission "
                    "(chunk_tokens > 0 on the paged engine)")
            if cfg.family not in ("dense", "moe") or \
                    cfg.attention == "swa":
                raise PagingError(
                    "prefix_cache supports global-attention dense/moe "
                    f"families; got family={cfg.family!r} "
                    f"attention={cfg.attention!r}")
            self.prefix = PrefixCache(self.page_pool, self.page_table,
                                      self.pager, page_size)

        # -- draft-free self-speculative decode (verify-K) ------------------
        # an n-gram prompt-lookup proposer drafts up to K tokens per slot
        # from the slot's own committed history; one jitted verify step
        # scores all drafts through the multi-query paged kernel, and
        # greedy acceptance keeps the stream token-exact with single-step
        # decode.  Same family gate as the prefix cache: append-only KV,
        # absolute rope (SWA ring wrap would rewrite rolled-back pages).
        sp = ec.speculation
        self.speculate_k = int(sp.speculate_k or 0)
        self.speculating = self.speculate_k > 0
        self.proposer = None
        if self.speculating:
            if not self.paging:
                raise PagingError(
                    "speculative decode requires the paged engine "
                    "(verify-K scatters through the page table)")
            if cfg.family not in ("dense", "moe") or \
                    cfg.attention == "swa":
                raise PagingError(
                    "speculative decode supports global-attention "
                    f"dense/moe families; got family={cfg.family!r} "
                    f"attention={cfg.attention!r}")
            if self.role is EngineRole.PREFILL:
                raise PagingError(
                    "a PREFILL-role engine never decodes past the first "
                    "token — speculation has nothing to draft")
            if sp.proposer_factory is not None:
                self.proposer = sp.proposer_factory(sp.speculate_ngram,
                                                    self.speculate_k)
            else:
                self.proposer = NgramProposer(n=sp.speculate_ngram,
                                              k=self.speculate_k)
            self._verify, self._verify_specs = make_serve_step(
                cfg, self.mesh, shape, donate=True, paged=True,
                kernel_impl=ec.kernel_impl, speculate_k=self.speculate_k)
            if self.chunking:
                self._mixed_verify, _ = make_mixed_step(
                    cfg, self.mesh, shape, donate=True,
                    kernel_impl=ec.kernel_impl,
                    speculate_k=self.speculate_k)

        self.events = EventLoop(metrics=self.metrics)
        self.events.on(EventKind.TICK, self._on_tick)
        self.events.on(EventKind.PAGE_ARRIVED, self._on_page_arrived)
        self.events.on(EventKind.COMPLETE, self._on_complete)
        self.events.on(EventKind.DEADLINE, self._on_deadline)
        # dict-compatible view onto the shared registry ("engine" group):
        # callers keep reading eng.stats["preemptions"] etc. unchanged
        initial = {"steps": 0, "prefills": 0, "admitted": 0,
                   "preemptions": 0, "resumes": 0, "mixed_steps": 0,
                   "chunks": 0, "prefill_preempts": 0,
                   "prefix_hits": 0, "prefix_tokens_saved": 0,
                   "prefix_far_hits": 0, "deadline_misses": 0,
                   "slo_attained": 0, "slo_missed": 0,
                   "shed_admissions": 0}
        if self.role is not EngineRole.FUSED:
            initial["handoffs"] = 0      # FUSED snapshots stay unchanged
        if self.speculating:
            # seeded only when speculation is on, so non-speculative
            # snapshots (and the bench baselines) stay byte-identical
            initial.update({"spec_steps": 0, "drafted": 0,
                            "accepted": 0, "rejected": 0})
        self.stats = self.metrics.counters("engine", initial=initial)

    # -- public API ----------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int = 16,
               eos_id: Optional[int] = None,
               src_embeds: Optional[np.ndarray] = None,
               tier: Tier = Tier.INTERACTIVE,
               ttft_slo: Optional[float] = None,
               tpot_slo: Optional[float] = None,
               arrival_t: Optional[float] = None) -> int:
        """Queue one request.  SLO fields: ``tier`` picks the priority
        class (maps to pager QoS under the SLO scheduler), ``ttft_slo``
        / ``tpot_slo`` override the :class:`SchedulerConfig` defaults,
        and ``arrival_t`` places the request on the virtual-clock time
        axis (a trace replay submits the whole workload up front; the
        engine admits nothing before its arrival time).  Defaults
        reproduce the old behaviour: arrive now, no SLOs."""
        prompt = np.asarray(prompt, np.int32)
        if self.paging:
            # a PREFILL-role engine never decodes: its pool only ever
            # holds the prompt's pages, so the completion horizon is the
            # prompt alone
            horizon = len(prompt) + (
                0 if self.role is EngineRole.PREFILL else max_new_tokens)
            full = pages_for(min(horizon, self.slot_tokens),
                             self.page_size)
            if full > self.page_pool.n_pages:
                raise PagingError(
                    f"request needs {full} pages; pool has only "
                    f"{self.page_pool.n_pages} — it could never complete")
            # admission only ever needs the prompt's pages (growth is
            # exempt from the low watermark) — reject what can't admit
            admit = pages_for(min(len(prompt), self.slot_tokens),
                              self.page_size)
            if admit + self.policy.low > self.page_pool.n_pages:
                raise PagingError(
                    f"request needs {admit} pages at admission; pool of "
                    f"{self.page_pool.n_pages} under low watermark "
                    f"{self.policy.low} can never admit it")
        rid = self._next_rid
        self._next_rid += 1
        now = self.clock()
        req = Request(rid=rid, prompt=prompt,
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      src_embeds=src_embeds, submitted_t=now,
                      tier=Tier(tier),
                      ttft_slo=(ttft_slo if ttft_slo is not None
                                else self.sched_cfg.ttft_slo),
                      tpot_slo=(tpot_slo if tpot_slo is not None
                                else self.sched_cfg.tpot_slo),
                      arrival_t=now if arrival_t is None else arrival_t)
        self.queue.append(req)
        self.sched.on_submit(req)
        return rid

    @property
    def drained(self) -> bool:
        """No work anywhere: queue, batch, chunk queue and resume set
        all empty (a disaggregated driver polls this per engine)."""
        return not (self.queue or self.active or self._resuming
                    or self.prefilling)

    def step_once(self) -> None:
        """One iteration of the serving loop: admit, step, tick, and the
        stall handling that keeps the loop progressing.  Public so a
        disaggregated driver (:func:`~repro.serve.disagg.
        run_disaggregated`) can interleave two engines; :meth:`run` is
        this in a drain loop."""
        self._admit()
        if self.active or self.prefilling:
            self._step()
        self.events.tick()
        if not self.active and not self.prefilling and self._resuming:
            # nothing decodable: land the in-flight pages, then
            # demand-fetch the head resume so the loop always
            # progresses (its misses may evict other resumes' pages)
            for req in list(self._resuming.values()):
                self.pager.wait_arriving(req.rid)
            self.pager.wait_seq(next(iter(self._resuming.values())).rid)
            self._admit()
        if not self.active and not self.prefilling \
                and not self._resuming and self.queue:
            # everything just finished this step: retry admission
            # now rather than waiting for the next iteration
            self._admit()
            if not self.active and not self.prefilling \
                    and not self._resuming:
                future = [r.arrival_t for r in self.queue
                          if r.arrival_t > self.clock()]
                if future and len(future) == len(self.queue):
                    # the system is idle only because the trace is:
                    # fast-forward the virtual clock to the next
                    # arrival (a wall clock advances by itself)
                    if self._own_clock:
                        self.clock.advance(min(future) - self.clock())
                    return
                # nothing running and nothing in flight: the state
                # can never change, so admission is blocked for
                # good — fail loudly instead of spinning to max_steps
                raise PagingError(
                    f"{len(self.queue)} queued requests can never be "
                    "admitted (free pages "
                    f"{self.page_pool.n_free if self.paging else 'n/a'}"
                    f", low watermark {self.policy.low})")

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Event loop until every submitted request completes.

        Example (8 requests through 3 slots, continuous batching)::

            eng = Engine(cfg, params, EngineConfig(
                max_batch=3, max_len=64,
                chunking=ChunkingConfig(chunk_tokens=8)))
            rids = [eng.submit(p, max_new_tokens=5) for p in prompts]
            outputs = eng.run()          # {rid: [token, ...]}
        """
        for _ in range(max_steps):
            if self.drained:
                break
            self.step_once()
        if self.drained:
            # fully drained: the telemetry counters must balance
            self.check_invariants()
        ob = self.config.obs
        if ob.trace_out:
            self.export_trace(ob.trace_out)
        if ob.metrics_out:
            self.export_metrics(ob.metrics_out)
        return {r.rid: r.generated for r in self.finished.values()}

    # -- event handlers -------------------------------------------------------
    def _on_tick(self, ev) -> None:
        # the engine-owned virtual clock advances here, by step_dt, in
        # lockstep with the pager's simulated backend below — one time
        # axis for transfers AND request telemetry
        if self._own_clock:
            self.clock.advance(self.step_dt)
        for t, rid in self.deadlines.pop_due(self.clock()):
            self.events.post(EventKind.DEADLINE, (t, rid))
        if self.pager is None:
            return
        for seq, logical in self.pager.advance(self.step_dt):
            self.events.post(EventKind.PAGE_ARRIVED, (seq, logical))
        # capacity pressure: when free frames sit under the low
        # watermark, push cold RESIDENT pages (parked hot tails, idle
        # prefix-cache frames) to the far tier *now*, so the BULK
        # astores overlap decode instead of serialising inside the next
        # admission's _make_room
        if self.policy.low:
            self.pager.balance(self.policy.low)

    def _on_page_arrived(self, ev) -> None:
        seq, logical = ev.payload
        pte = self.page_table.entry(seq, logical)
        if pte.state is PageState.RESIDENT:
            self._land_frame(pte.phys)       # scatter into the device pool
            self.page_pool.touch(pte.phys)

    def _on_complete(self, ev) -> None:
        rid = ev.payload
        if self.paging and rid in self.page_table.sequences():
            self.page_table.drop(rid)
            if not self.offload_finished:
                # offloaded sequences keep their far-tier pages: that IS
                # the finished-KV store fetch_finished (or a DECODE-role
                # peer's admit_handoff) reads back
                self.pager.drop_far(rid)

    def _on_deadline(self, ev) -> None:
        """A TTFT deadline passed.  If the request still has no first
        token it has missed its SLO *now* — count it while it is still
        schedulable, so preemption's already-blown preference and the
        telemetry agree in real time rather than post hoc."""
        t, rid = ev.payload
        req = self.finished.get(rid)
        if req is None:
            for r in itertools.chain(self.queue, self.active.values(),
                                     self.prefilling.values(),
                                     self._resuming.values()):
                if r.rid == rid:
                    req = r
                    break
        if req is not None and not req.token_ts:
            self.stats["deadline_misses"] += 1
            if self.tracer.enabled:
                self.tracer.instant("engine", "sched", "deadline_miss",
                                    {"rid": rid, "tier": req.tier.name,
                                     "deadline": t})

    # -- telemetry ------------------------------------------------------------
    def _obs_phase(self, req: Request, name: Optional[str]) -> None:
        """Advance a request's lifecycle track: close its current phase
        span and open ``name`` (None just closes — the finish path).
        The first phase a request ever enters also back-fills a
        ``queued`` span covering arrival → now, so the Perfetto track
        reads arrival → admit → prefill/decode → … end to end."""
        tr = self.tracer
        if not tr.enabled:
            return
        tid = f"req{req.rid}"
        if req.rid not in self._obs_started:
            self._obs_started.add(req.rid)
            tr.complete("requests", tid, "queued", req.arrival_t,
                        args={"tier": req.tier.name})
        tr.end(self._phase_span.pop(req.rid, 0))
        if name is not None:
            self._phase_span[req.rid] = tr.begin(
                "requests", tid, name, {"tier": req.tier.name})

    def check_invariants(self) -> None:
        """Cross-layer conservation checks over the telemetry counters.

        * preemptions == resumes + requests *currently* parked by a
          preemption (a prefix-far admission parks without one, so only
          ``n_preempts > 0`` requests count),
        * ADMIT events == admissions + resumes (every ADMIT post has
          exactly one matching stats increment),
        * on a PREFILL role: HANDOFF events == published handoffs,
        * speculating: accepted + rejected == drafted (every drafted
          token is adjudicated exactly once), and no active slot's
          valid tokens exceed its scattered (mapped) frames,
        * the pager's per-QoS window takes/releases balance its
          in-flight gauges (see :meth:`Pager.check_invariants`).
        """
        s = self.stats
        if self.speculating:
            if s["accepted"] + s["rejected"] != s["drafted"]:
                raise PagingError(
                    f"speculation imbalance: {s['accepted']} accepted + "
                    f"{s['rejected']} rejected != {s['drafted']} drafted")
            if self.paging:
                pos_np = np.asarray(self.cache.pos)
                for slot, req in self.active.items():
                    covered = self.page_table.n_pages(req.rid) \
                        * self.page_size
                    if int(pos_np[slot]) > covered:
                        raise PagingError(
                            f"rid {req.rid}: valid tokens "
                            f"{int(pos_np[slot])} exceed scattered frames "
                            f"({covered} positions mapped)")
        pending = sum(
            1 for r in itertools.chain(self.queue, self._resuming.values())
            if r.parked and r.n_preempts > 0)
        if s["preemptions"] != s["resumes"] + pending:
            raise PagingError(
                f"preempt/resume imbalance: {s['preemptions']} preemptions "
                f"!= {s['resumes']} resumes + {pending} currently parked")
        admits = self.events.history.get(EventKind.ADMIT, 0)
        if admits != s["admitted"] + s["resumes"]:
            raise PagingError(
                f"ADMIT event imbalance: {admits} events != "
                f"{s['admitted']} admissions + {s['resumes']} resumes")
        if self.role is EngineRole.PREFILL:
            hoffs = self.events.history.get(EventKind.HANDOFF, 0)
            if hoffs != s["handoffs"]:
                raise PagingError(
                    f"HANDOFF event imbalance: {hoffs} events != "
                    f"{s['handoffs']} published handoffs")
        if self.pager is not None:
            self.pager.check_invariants()

    def export_trace(self, path: Optional[str] = None) -> dict:
        """Chrome-trace/Perfetto JSON of everything traced so far (AMU
        transfers, pager actions, residency flips, request lifecycle —
        one virtual time axis).  Writes to ``path`` when given."""
        if path is not None:
            write_chrome_trace(path, self.tracer, metrics=self.metrics)
        return to_chrome_trace(self.tracer, metrics=self.metrics)

    def export_metrics(self, path: Optional[str] = None) -> dict:
        """Flat JSON snapshot of every counter/gauge/histogram."""
        if path is not None:
            write_metrics(path, self.metrics)
        return self.metrics.snapshot()

"""Continuous-batching serving engine over the paged KV subsystem.

The scheduler is the paper's *event-driven model* (§2.3.2) applied to
requests instead of cache lines: decode steps are the event loop's
ticks; pager ``getfin`` completions post PAGE_ARRIVED events; admission
and preemption decisions come from *free-page watermarks* over the
device page pool (``repro.paging``) instead of free-slot counts.  This
is what lets the engine admit more concurrent sequences than device
memory can hold:

  * each sequence's KV is accounted in fixed-size pages of a shared
    :class:`~repro.paging.PagePool`; active slots pin their pages,
  * when growth (or a new admission) exceeds the pool, a victim is
    *preempted*: only its **cold** pages are written back (BULK-QoS
    ``astore``; pages whose far-tier copy is still current move for
    free), while the hot tail stays cached on-device,
  * rescheduling prefetches the parked pages **hot tail first** with
    LATENCY-QoS ``aload`` that overlaps the current decode step; the
    sequence re-enters a slot the moment its residency bits are all set
    — no re-prefill, bit-exact resume.

Decode itself is mesh-sharded: the step function comes from
``repro.dist.steps.make_serve_step`` (TP-sharded params, donated cache)
bound to the engine's mesh — a 1×1 mesh by default, the production
(data, model) mesh when one is passed in.  Decode runs with a *fixed*
batch of ``max_batch`` slots (one compiled program); per-slot positions
make the mixed-depth batch correct, and empty slots decode garbage that
is simply ignored — the standard fixed-shape trade on TPU.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.steps import make_serve_step
from repro.launch.mesh import make_mesh_compat
from repro.models.model import Cache, init_cache, prefill
from repro.paging import (EventKind, EventLoop, PagePool, PageState,
                          PageTable, Pager, PagingError, WatermarkPolicy,
                          pages_for)
from repro.serve.kv_cache import (KVOffloadTier, SlotPool, extract_slot,
                                  insert_slot, join_kv_pages, split_kv_pages)

__all__ = ["Request", "Engine"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (plen,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    src_embeds: Optional[np.ndarray] = None   # encdec frontend stub
    # filled by the engine:
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    submitted_t: float = 0.0
    first_token_t: float = 0.0
    done_t: float = 0.0
    # paging state (set when the request has been preempted):
    residue: Any = None                 # non-KV cache remainder while parked
    clean_pages: int = 0                # leading pages whose far copy is current
    n_preempts: int = 0
    admit_seq: int = -1                 # admission order (preemption priority)

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return bool(self.generated and self.eos_id is not None
                    and self.generated[-1] == self.eos_id)


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_batch: int = 4,
        max_len: int = 256,
        prefill_buckets: tuple = (32, 64, 128, 256),
        greedy: bool = True,
        offload_finished: bool = False,
        clock: Callable[[], float] = time.monotonic,
        mesh=None,
        page_size: int = 16,
        device_pages: Optional[int] = None,
        watermark: Optional[WatermarkPolicy] = None,
        hot_tail_pages: int = 1,
        pager: Optional[Pager] = None,
        step_dt: float = 1e-3,
    ):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.buckets = tuple(sorted(b for b in prefill_buckets
                                    if b <= max_len)) or (max_len,)
        self.greedy = greedy
        self.clock = clock
        self.pool = SlotPool(max_batch)
        self.cache: Cache = init_cache(cfg, max_batch, max_len)
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}     # slot -> request
        self.finished: Dict[int, Request] = {}
        self.kv_tier = KVOffloadTier() if offload_finished else None
        self._ids = itertools.count()
        self._admits = itertools.count()

        # -- mesh-sharded decode step (dist.steps, not a raw jit) ----------
        self.mesh = mesh if mesh is not None else \
            make_mesh_compat((1, 1), ("data", "model"))
        shape = ShapeConfig("serve_engine", max_len, max_batch, "decode")
        self._decode, self._decode_specs = make_serve_step(
            cfg, self.mesh, shape, donate=False)
        self._prefills: Dict[int, Any] = {}

        # -- page-granularity KV residency over a fixed device pool --------
        kv = self.cache.kv if isinstance(self.cache.kv, dict) else {}
        self.paging = "k" in kv
        self.page_size = page_size
        self.step_dt = step_dt
        self.hot_tail_pages = max(0, hot_tail_pages)
        self._resuming: Dict[int, Request] = {}
        if self.paging:
            k = kv["k"]
            self.slot_tokens = int(k.shape[2])       # ring size for SWA
            per_seq = pages_for(self.slot_tokens, page_size)
            n_pages = device_pages if device_pages is not None \
                else max_batch * per_seq
            page_nbytes = int(2 * k.shape[0] * page_size * k.shape[3]
                              * k.shape[4] * k.dtype.itemsize)
            self.page_pool = PagePool(n_pages, page_size)
            self.page_table = PageTable(self.page_pool)
            self.pager = pager or Pager(self.page_pool, self.page_table,
                                        page_nbytes=page_nbytes)
        else:
            self.slot_tokens = 0
            self.page_pool = self.page_table = self.pager = None
        self.policy = watermark or WatermarkPolicy(low=0, critical=0)

        self.events = EventLoop()
        self.events.on(EventKind.TICK, self._on_tick)
        self.events.on(EventKind.PAGE_ARRIVED, self._on_page_arrived)
        self.events.on(EventKind.COMPLETE, self._on_complete)
        self.stats = {"steps": 0, "prefills": 0, "admitted": 0,
                      "preemptions": 0, "resumes": 0}

    # -- public API ----------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int = 16,
               eos_id: Optional[int] = None,
               src_embeds: Optional[np.ndarray] = None) -> int:
        prompt = np.asarray(prompt, np.int32)
        if self.paging:
            full = pages_for(min(len(prompt) + max_new_tokens,
                                 self.slot_tokens), self.page_size)
            if full > self.page_pool.n_pages:
                raise PagingError(
                    f"request needs {full} pages; pool has only "
                    f"{self.page_pool.n_pages} — it could never complete")
            # admission only ever needs the prompt's pages (growth is
            # exempt from the low watermark) — reject what can't admit
            admit = pages_for(min(len(prompt), self.slot_tokens),
                              self.page_size)
            if admit + self.policy.low > self.page_pool.n_pages:
                raise PagingError(
                    f"request needs {admit} pages at admission; pool of "
                    f"{self.page_pool.n_pages} under low watermark "
                    f"{self.policy.low} can never admit it")
        rid = next(self._ids)
        req = Request(rid=rid, prompt=prompt,
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      src_embeds=src_embeds, submitted_t=self.clock())
        self.queue.append(req)
        return rid

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Event loop until every submitted request completes."""
        for _ in range(max_steps):
            if not self.queue and not self.active and not self._resuming:
                break
            self._admit()
            if self.active:
                self._step()
            self.events.tick()
            if not self.active and self._resuming:
                # nothing decodable: land the in-flight pages, then
                # demand-fetch the head resume so the loop always
                # progresses (its misses may evict other resumes' pages)
                for req in list(self._resuming.values()):
                    self.pager.wait_arriving(req.rid)
                self.pager.wait_seq(next(iter(self._resuming.values())).rid)
                self._admit()
            if not self.active and not self._resuming and self.queue:
                # everything just finished this step: retry admission
                # now rather than waiting for the next iteration
                self._admit()
                if not self.active and not self._resuming:
                    # nothing running and nothing in flight: the state
                    # can never change, so admission is blocked for
                    # good — fail loudly instead of spinning to max_steps
                    raise PagingError(
                        f"{len(self.queue)} queued requests can never be "
                        "admitted (free pages "
                        f"{self.page_pool.n_free if self.paging else 'n/a'}"
                        f", low watermark {self.policy.low})")
        return {r.rid: r.generated for r in self.finished.values()}

    # -- event handlers -------------------------------------------------------
    def _on_tick(self, ev) -> None:
        if self.pager is None:
            return
        for seq, logical in self.pager.advance(self.step_dt):
            self.events.post(EventKind.PAGE_ARRIVED, (seq, logical))

    def _on_page_arrived(self, ev) -> None:
        seq, logical = ev.payload
        pte = self.page_table.entry(seq, logical)
        if pte.state is PageState.RESIDENT:
            self.page_pool.touch(pte.phys)

    def _on_complete(self, ev) -> None:
        rid = ev.payload
        if self.paging and rid in self.page_table.sequences():
            self.page_table.drop(rid)
            self.pager.drop_far(rid)

    # -- internals ------------------------------------------------------------
    def _bucket(self, plen: int) -> int:
        # SSM/hybrid state is corrupted by pad tokens, so exact lengths
        # there; attention families pad to the next bucket (cache entries
        # beyond plen are never attended: pos starts at plen).
        if self.cfg.family in ("ssm", "hybrid"):
            return plen
        for b in self.buckets:
            if plen <= b:
                return b
        return self.max_len

    def _prefill_one(self, req: Request):
        plen = len(req.prompt)
        bucket = self._bucket(plen)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = req.prompt
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "encdec":
            se = req.src_embeds
            if se is None:
                se = np.zeros((bucket, self.cfg.d_model), np.float32)
            src = np.zeros((1, bucket, self.cfg.d_model), np.float32)
            src[0, :se.shape[0]] = se[:bucket]
            batch["src_embeds"] = jnp.asarray(src)
        if self.cfg.mrope_sections:
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(bucket, dtype=jnp.int32), (3, 1, bucket))
        key = (bucket, self.cfg.family)
        if key not in self._prefills:
            cfg = self.cfg
            self._prefills[key] = jax.jit(
                lambda p, b: prefill(p, cfg, b, max_len=self.max_len))
        logits, single = self._prefills[key](self.params, batch)
        self.stats["prefills"] += 1
        # true position is plen (ignore pad tail), and next token comes
        # from the logits at plen-1 — recompute it from the last real
        # token by letting decode handle it: set pos = plen.
        single = single._replace(pos=jnp.full((1,), plen, jnp.int32))
        return logits, single

    # -- paging helpers -------------------------------------------------------
    def _make_room(self, need: int, protect: frozenset,
                   preempt: bool = True) -> bool:
        """Bring the pool to at least ``need`` free frames.  Escalation
        order: getfin poll, LRU eviction of unpinned cached pages,
        draining in-flight fetches (their frames become evictable), then
        — for growth, never for fresh admission — preempting a victim."""
        pool = self.page_pool
        if pool.n_free >= need:
            return True
        self.pager.poll()
        while pool.n_free < need:
            if self.pager.evict_lru(need - pool.n_free):
                continue
            if self._resuming:
                for req in list(self._resuming.values()):
                    self.pager.wait_arriving(req.rid)
                if self.pager.evict_lru(need - pool.n_free):
                    continue
            if not preempt or not self._preempt_one(protect):
                return False
        return True

    def _preempt_one(self, protect: frozenset) -> bool:
        """Park the most recently admitted unprotected active sequence."""
        victims = [r for r in self.active.values()
                   if r.rid not in protect]
        if not victims or len(self.active) <= 1:
            return False
        victim = max(victims, key=lambda r: r.admit_seq)
        self._park(victim)
        return True

    def _park(self, req: Request) -> None:
        """Preempt: cold pages → far tier (BULK), hot tail stays cached
        on-device (unpinned, LRU-evictable), slot freed, request back to
        the head of the queue."""
        slot = req.slot
        tokens = int(np.asarray(self.cache.pos)[slot])
        single = extract_slot(self.cache, slot, self.max_batch)
        residue, pages = split_kv_pages(single, self.page_size, tokens)
        rid = req.rid
        # a frame allocated for the *next* write (pos on a page boundary)
        # holds no content yet — release it; resume growth re-allocates
        self.page_table.truncate(rid, len(pages))
        n_hot = min(self.hot_tail_pages, len(pages))
        n_cold = len(pages) - n_hot
        for logical in range(len(pages) - 1, -1, -1):   # tail first: hot
            pte = self.page_table.entry(rid, logical)
            self.page_pool.unpin(pte.phys)
            if logical >= n_cold:                        # hot tail: cached
                frame = self.page_pool.frames[pte.phys]
                frame.data = pages[logical]
                frame.dirty = not (logical < req.clean_pages
                                   and self.pager.has_far(rid, logical))
                self.page_pool.touch(pte.phys)
            elif (logical < req.clean_pages
                  and self.pager.has_far(rid, logical)):
                self.pager.park_clean(rid, logical)      # far copy current
            else:
                self.pager.writeback(rid, logical, pages[logical])
        req.residue = residue
        # append-only KV: full far-tier pages stay valid forever — except
        # under an SWA ring, where wrap rewrites old pages in place.
        req.clean_pages = 0 if self.cfg.attention == "swa" \
            else min(n_cold, tokens // self.page_size)
        req.n_preempts += 1
        req.slot = None
        del self.active[slot]
        self.pool.release(slot)
        self.queue.insert(0, req)
        self.stats["preemptions"] += 1
        self.events.post(EventKind.PREEMPT, rid)

    def _start_resume(self, req: Request) -> bool:
        """Begin bringing a parked request back: LATENCY-QoS prefetch of
        its parked pages, hot tail first, overlapping decode.  A resume
        is a continuation, not a fresh admission, so like growth it is
        exempt from the low watermark — it only needs raw frames."""
        parked = self.page_table.logical_pages(req.rid, PageState.PARKED)
        if self.page_pool.n_free < len(parked) and \
                not self._make_room(len(parked), frozenset({req.rid}),
                                    preempt=False):
            return False
        self.pager.prefetch_seq(req.rid, tail_first=True)
        self._resuming[req.rid] = req
        return True

    def _try_finish_resumes(self) -> None:
        """Slot in any resuming request whose pages have all arrived."""
        for rid, req in list(self._resuming.items()):
            if not self.page_table.resident(rid):
                # pages evicted again under pressure mid-resume get a
                # fresh LATENCY prefetch (no-op when all are in flight)
                self.pager.prefetch_seq(rid, tail_first=True)
                continue
            if not self.pool.n_free:
                continue
            pages = []
            for logical in range(self.page_table.n_pages(rid)):
                pte = self.page_table.entry(rid, logical)
                pages.append(self.page_pool.frames[pte.phys].data)
                self.page_pool.pin(pte.phys)
                self.page_pool.touch(pte.phys)
            single = join_kv_pages(req.residue, pages, self.slot_tokens)
            slot = self.pool.alloc()
            self.cache = insert_slot(self.cache, single, slot, self.max_batch)
            req.slot = slot
            req.residue = None
            req.admit_seq = next(self._admits)
            self.active[slot] = req
            del self._resuming[rid]
            self.stats["resumes"] += 1
            self.events.post(EventKind.ADMIT, rid)

    def _alloc_pinned(self, rid: int, n_tokens: int) -> None:
        """Allocate (pin + mark dirty) frames so ``rid`` covers
        ``n_tokens`` positions — active slots own their pages."""
        for logical in self.page_table.ensure_capacity(rid, n_tokens):
            pte = self.page_table.entry(rid, logical)
            self.page_pool.pin(pte.phys)
            self.page_pool.mark_dirty(pte.phys)

    def _ensure_growth(self) -> None:
        """Before a decode step: every active sequence about to cross a
        page boundary gets a pinned frame, evicting/preempting under the
        watermark policy when the pool is short."""
        pos_np = np.asarray(self.cache.pos)     # one device sync per step
        for req in list(self.active.values()):
            if req.slot is None or req.slot not in self.active:
                continue                    # preempted by an earlier victim
            pos = int(pos_np[req.slot])
            if pos >= self.slot_tokens:
                continue                    # SWA ring wrapped: no growth
            need = self.page_table.pages_needed(req.rid, pos + 1)
            if not need:
                continue
            if not self._make_room(need, frozenset({req.rid})):
                raise PagingError(
                    f"cannot grow request {req.rid}: pool of "
                    f"{self.page_pool.n_pages} pages exhausted")
            self._alloc_pinned(req.rid, pos + 1)

    # -- scheduling ------------------------------------------------------------
    def _admit(self) -> None:
        self._try_finish_resumes()
        while self.queue:
            req = self.queue[0]
            if req.residue is not None:                   # preempted: resume
                if req.rid in self._resuming or not self._start_resume(req):
                    break
                self.queue.pop(0)
                self._try_finish_resumes()
                continue
            if not self.pool.n_free:
                break
            if self.paging:
                need = pages_for(min(len(req.prompt), self.slot_tokens),
                                 self.page_size)
                if not self.policy.can_admit(self.page_pool, need) and \
                        not self._make_room(need + self.policy.low,
                                            frozenset(), preempt=False):
                    break
            self.queue.pop(0)
            slot = self.pool.alloc()
            logits, single = self._prefill_one(req)
            self.cache = insert_slot(self.cache, single, slot, self.max_batch)
            req.slot = slot
            req.admit_seq = next(self._admits)
            if self.paging:
                self.page_table.register(req.rid)
                self._alloc_pinned(req.rid,
                                   min(len(req.prompt), self.slot_tokens))
            first = int(np.argmax(np.asarray(logits)[0]))
            req.generated.append(first)
            req.first_token_t = self.clock()
            self.active[slot] = req
            self.stats["admitted"] += 1
            self.events.post(EventKind.ADMIT, req.rid)
            self._finish_if_done(req)

    def _step(self) -> None:
        if self.paging:
            self._ensure_growth()
        if not self.active:
            return
        toks = np.zeros((self.max_batch, 1), np.int32)
        for slot, req in self.active.items():
            toks[slot, 0] = req.generated[-1]
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        self.stats["steps"] += 1
        logits = np.asarray(logits)
        for slot, req in list(self.active.items()):
            nxt = int(np.argmax(logits[slot]))
            req.generated.append(nxt)
            self._finish_if_done(req)

    def _finish_if_done(self, req: Request) -> None:
        if not req.done:
            return
        slot = req.slot
        if slot is not None and slot in self.active:
            del self.active[slot]
        if slot is not None:
            if self.kv_tier is not None:
                self.kv_tier.park(req.rid, extract_slot(
                    self.cache, slot, self.max_batch))
            self.pool.release(slot)
        req.done_t = self.clock()
        self.finished[req.rid] = req
        self.events.post(EventKind.COMPLETE, req.rid)
        self.events.drain()
"""repro.serve"""

"""repro.serve — continuous-batching inference over the paged KV pool.

Two modules:

  * :mod:`repro.serve.engine` — the serving engine: chunk-queue
    admission (chunked paged prefill fused with decode in one mixed
    step), free-page-watermark preemption/resume over
    :mod:`repro.paging`, and the event-driven scheduler loop (the
    paper's §2.3.2 model applied to requests),
  * :mod:`repro.serve.kv_cache` — slot bookkeeping around the batched
    device cache: the :class:`~repro.serve.kv_cache.SlotPool`, dense
    slot extract/insert (the ``paging=False`` fallback path), and page
    split/join for far-tier payloads.  Finished-sequence offload is
    engine-level now: pages park through the pager into the single
    :class:`~repro.core.offload.FarMemoryTier` and
    ``Engine.fetch_finished`` reassembles them.

Minimal use::

    from repro.serve.engine import Engine
    eng = Engine(cfg, params, max_batch=4, max_len=256, chunk_tokens=32)
    rid = eng.submit(prompt_tokens, max_new_tokens=16)
    tokens = eng.run()[rid]

``docs/ARCHITECTURE.md`` maps every piece back to the paper.
"""

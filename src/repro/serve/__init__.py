"""repro.serve — continuous-batching inference over the paged KV pool.

Four modules:

  * :mod:`repro.serve.config` — the grouped, frozen
    :class:`~repro.serve.config.EngineConfig` construction API
    (:class:`~repro.serve.config.PagingConfig` /
    :class:`~repro.serve.config.ChunkingConfig` /
    :class:`~repro.serve.config.SchedulerConfig`), the
    :class:`~repro.serve.config.Tier` priority enum and the injected
    :class:`~repro.serve.config.VirtualClock` every request timestamp
    goes through,
  * :mod:`repro.serve.engine` — the serving engine: chunk-queue
    admission (chunked paged prefill fused with decode in one mixed
    step), free-page-watermark preemption/resume over
    :mod:`repro.paging`, the event-driven scheduler loop (the paper's
    §2.3.2 model applied to requests) and the pluggable
    :class:`~repro.serve.engine.SchedulerPolicy` layer (``watermark``
    utilisation scheduling vs ``slo`` goodput scheduling that maps
    priority tiers onto the pager's QoS windows),
  * :mod:`repro.serve.workload` — the production traffic model (bursty
    diurnal arrivals, lognormal/Zipf lengths, interactive-vs-batch
    tiers with per-request TTFT/TPOT SLOs),
  * :mod:`repro.serve.kv_cache` — slot bookkeeping around the batched
    device cache: the :class:`~repro.serve.kv_cache.SlotPool`, dense
    slot extract/insert (the ``paging=False`` fallback path), and page
    split/join for far-tier payloads.  Finished-sequence offload is
    engine-level now: pages park through the pager into the single
    :class:`~repro.core.offload.FarMemoryTier` and
    ``Engine.fetch_finished`` reassembles them.

Minimal use::

    from repro.serve import Engine, EngineConfig, ChunkingConfig
    eng = Engine(cfg, params, EngineConfig(
        max_batch=4, max_len=256,
        chunking=ChunkingConfig(chunk_tokens=32)))
    rid = eng.submit(prompt_tokens, max_new_tokens=16)
    tokens = eng.run()[rid]

``docs/ARCHITECTURE.md`` maps every piece back to the paper.
"""

from repro.serve.config import (ChunkingConfig, EngineConfig, PagingConfig,
                                SchedulerConfig, Tier, VirtualClock)
from repro.serve.engine import Engine, Request, SchedulerPolicy

__all__ = [
    "Engine", "Request", "SchedulerPolicy", "EngineConfig", "PagingConfig",
    "ChunkingConfig", "SchedulerConfig", "Tier", "VirtualClock",
]

"""repro.serve — continuous-batching inference over the paged KV pool.

The package is organised as role components assembled into one engine:

  * :mod:`repro.serve.config` — the grouped, frozen
    :class:`~repro.serve.config.EngineConfig` construction API
    (:class:`~repro.serve.config.PagingConfig` /
    :class:`~repro.serve.config.ChunkingConfig` /
    :class:`~repro.serve.config.SchedulerConfig`), the
    :class:`~repro.serve.config.Tier` priority enum, the
    :class:`~repro.serve.config.EngineRole` disaggregation role and the
    injected :class:`~repro.serve.config.VirtualClock` every request
    timestamp goes through,
  * :mod:`repro.serve.request` — the :class:`~repro.serve.request.
    Request` lifecycle record (timestamps, SLO accounting, park state),
  * :mod:`repro.serve.policy` — the pluggable
    :class:`~repro.serve.policy.SchedulerPolicy` layer (``watermark``
    utilisation scheduling vs ``slo`` goodput scheduling that maps
    priority tiers onto the pager's QoS windows),
  * :mod:`repro.serve.admission` — dense + chunked prefill admission,
    prefix-cache mapping, and the DECODE-role ``admit_handoff``,
  * :mod:`repro.serve.transfer` — park/resume transfer machinery,
    watermark room-making, finished-sequence offload/fetch and the
    PREFILL-role handoff publish,
  * :mod:`repro.serve.decode` — the decode/mixed step loop, chunk
    scheduling, prefill graduation and the finish path,
  * :mod:`repro.serve.engine` — the assembly: chunk-queue admission
    (chunked paged prefill fused with decode in one mixed step),
    free-page-watermark preemption/resume over :mod:`repro.paging` and
    the event-driven scheduler loop (the paper's §2.3.2 model applied
    to requests), composed from the mixins above and parameterised by
    :class:`~repro.serve.config.EngineRole`,
  * :mod:`repro.serve.disagg` — disaggregated prefill/decode: the
    :class:`~repro.serve.disagg.HandoffRecord` /
    :class:`~repro.serve.disagg.HandoffBoard` handoff protocol, the
    shared-:class:`~repro.core.offload.FarMemoryTier` pager factory and
    the :func:`~repro.serve.disagg.run_disaggregated` two-engine
    driver,
  * :mod:`repro.serve.workload` — the production traffic model (bursty
    diurnal arrivals, lognormal/Zipf lengths, interactive-vs-batch
    tiers with per-request TTFT/TPOT SLOs),
  * :mod:`repro.serve.kv_cache` — slot bookkeeping around the batched
    device cache: the :class:`~repro.serve.kv_cache.SlotPool`, dense
    slot extract/insert (the ``paging=False`` fallback path), and page
    split/join for far-tier payloads.

Minimal use::

    from repro.serve import Engine, EngineConfig, ChunkingConfig
    eng = Engine(cfg, params, EngineConfig(
        max_batch=4, max_len=256,
        chunking=ChunkingConfig(chunk_tokens=32)))
    rid = eng.submit(prompt_tokens, max_new_tokens=16)
    tokens = eng.run()[rid]

``docs/ARCHITECTURE.md`` maps every piece back to the paper.
"""

from repro.serve.config import (ChunkingConfig, EngineConfig, EngineRole,
                                PagingConfig, SchedulerConfig,
                                SpeculationConfig, Tier, VirtualClock)
from repro.serve.disagg import (HandoffBoard, HandoffRecord,
                                make_shared_tier, run_disaggregated,
                                tier_pager_factory)
from repro.serve.engine import Engine, Request, SchedulerPolicy

__all__ = [
    "Engine", "Request", "SchedulerPolicy", "EngineConfig", "PagingConfig",
    "ChunkingConfig", "SchedulerConfig", "SpeculationConfig", "Tier",
    "VirtualClock",
    "EngineRole", "HandoffBoard", "HandoffRecord", "make_shared_tier",
    "tier_pager_factory", "run_disaggregated",
]

"""The request lifecycle record shared by every engine role component.

A :class:`Request` moves through admit → (chunked prefill) → decode →
park/resume (any number of times, from either phase) → finish.  Under
the disaggregated topology the same record crosses an engine boundary:
a PREFILL-role engine finishes it at its first token and publishes a
:class:`~repro.serve.disagg.HandoffRecord`; a DECODE-role engine
rebuilds it (parked, with its aux residue) and decodes it to
completion through the ordinary resume machinery.  The fields are the
complete per-request state either side needs — nothing request-scoped
lives anywhere else.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional

import numpy as np

from repro.serve.config import Tier

__all__ = ["Request"]


@dataclass
class Request:
    """One submitted generation request and its full lifecycle state.

    A request moves through admit → (chunked prefill) → decode →
    park/resume (any number of times, from either phase) → finish; see
    ``docs/ARCHITECTURE.md`` for the lifecycle diagram.  Example::

        rid = engine.submit(np.arange(7), max_new_tokens=4)
        tokens = engine.run()[rid]
    """

    rid: int
    prompt: np.ndarray                  # (plen,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    src_embeds: Optional[np.ndarray] = None   # encdec frontend stub
    # SLO contract (production traffic model; see repro.serve.workload):
    tier: Tier = Tier.INTERACTIVE
    ttft_slo: Optional[float] = None    # time-to-first-token budget
    tpot_slo: Optional[float] = None    # mean time-per-output-token budget
    arrival_t: float = 0.0              # when the request enters the system
    # filled by the engine:
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    submitted_t: float = 0.0
    first_token_t: float = 0.0
    done_t: float = 0.0
    token_ts: List[float] = field(default_factory=list)  # one per token
    # paging state (set when the request has been preempted):
    parked: bool = False                # preempted, waiting to resume
    residue: Any = None                 # non-KV aux payload while parked
    n_preempts: int = 0
    admit_seq: int = -1                 # admission order (preemption priority)
    # chunked-prefill state (chunk-queue admission path):
    prefill_pos: int = 0                # prompt tokens already prefilled
    target_len: int = 0                 # tokens the chunk path must cover
    chunk_rows: Any = None              # host page-table row while prefilling
    chunk_ssm: Any = None               # hybrid: SSM carry between chunks
    src_len: int = 0                    # encdec: true encoder length

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return bool(self.generated and self.eos_id is not None
                    and self.generated[-1] == self.eos_id)

    @property
    def mid_prefill(self) -> bool:
        """True while the prompt is only partially chunk-prefilled."""
        return self.target_len > 0 and self.prefill_pos < self.target_len

    # -- SLO telemetry (all timestamps on the engine's one clock) ----------
    @property
    def ttft(self) -> float:
        """Time to first token (inf until one exists)."""
        if not self.token_ts:
            return float("inf")
        return self.token_ts[0] - self.arrival_t

    @property
    def tpot(self) -> float:
        """Mean time per output token after the first (0 for 1 token)."""
        if len(self.token_ts) < 2:
            return 0.0
        return ((self.token_ts[-1] - self.token_ts[0])
                / (len(self.token_ts) - 1))

    def slo_attained(self) -> bool:
        """Did this request meet every SLO it carries?  A request with
        no SLOs trivially attains (batch completion traffic)."""
        if self.ttft_slo is not None and self.ttft > self.ttft_slo:
            return False
        if self.tpot_slo is not None and self.tpot > self.tpot_slo:
            return False
        return True

"""The transfer role component: every byte the engine moves.

:class:`TransferMixin` owns the park/resume machinery and the
device-pool plumbing — frame reads/lands, pool-frame scatters,
room-making (evict → drain → preempt), page shedding with the
clean-park fast path, resume prefetch + slot re-entry, and the
finished-sequence offload/fetch pair.  It is role-agnostic by
construction: a FUSED engine points park at its own resume; a PREFILL
engine's graduation is the same ``_offload_finished`` park plus a
:meth:`_publish_handoff`; a DECODE engine's handoff admission is the
same resume machinery fed by :meth:`~repro.paging.Pager.fetch_keys`.
The mixin assumes the host class provides the engine state surface
(``page_pool``/``page_table``/``pager``/``cache``/``sched``/…) —
``serve/engine.py`` assembles it.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Cache
from repro.paging import (NOT_MAPPED, EventKind, PageState, PagingError,
                          pages_for)
from repro.serve.config import EngineRole
from repro.serve.disagg import HandoffRecord
from repro.serve.kv_cache import extract_aux_slot, insert_aux_slot, \
    join_kv_pages
from repro.serve.request import Request

__all__ = ["TransferMixin", "_scatter_seq_pages", "_scatter_one_page",
           "_copy_frame"]


# -- jitted pool-frame scatters (module level: one compile per shape) ---------

@partial(jax.jit, donate_argnums=(0, 1), static_argnums=(5,))
def _scatter_seq_pages(k_pages, v_pages, k_single, v_single, frames,
                       n_pg: int):
    """Write one sequence's dense prefill KV into its pool frames.

    ``k_single``/``v_single``: (L, 1, S, Hkv, D) from prefill — S is the
    prefill *bucket*, at most the slot capacity; only the leading
    ``n_pg`` pages (the prompt's — the exact frames admission just
    mapped) are scattered, the tail zero-padded up to a page multiple.
    The pool arrays are donated: the update aliases in place instead of
    copying the whole pool per admission."""
    L, _, S, Hkv, D = k_single.shape
    page = k_pages.shape[2]
    take = min(n_pg * page, S)
    k_single = k_single[:, :, :take]
    v_single = v_single[:, :, :take]
    pad = n_pg * page - take
    if pad:
        widths = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
        k_single = jnp.pad(k_single, widths)
        v_single = jnp.pad(v_single, widths)
    ks = k_single[:, 0].reshape(L, n_pg, page, Hkv, D)
    vs = v_single[:, 0].reshape(L, n_pg, page, Hkv, D)
    k_pages = k_pages.at[:, frames].set(ks.astype(k_pages.dtype))
    v_pages = v_pages.at[:, frames].set(vs.astype(v_pages.dtype))
    return k_pages, v_pages


@partial(jax.jit, donate_argnums=(0, 1))
def _scatter_one_page(k_pages, v_pages, k_data, v_data, phys):
    """Land one far-tier page payload (L, page, Hkv, D) in frame ``phys``
    (pool arrays donated: an in-place page write, not a pool copy)."""
    k_pages = k_pages.at[:, phys].set(k_data.astype(k_pages.dtype))
    v_pages = v_pages.at[:, phys].set(v_data.astype(v_pages.dtype))
    return k_pages, v_pages


@partial(jax.jit, donate_argnums=(0, 1))
def _copy_frame(k_pages, v_pages, src, dst):
    """Device-side page copy (COW break: a sharer about to write a
    prefix-shared frame gets a private duplicate first)."""
    k_pages = k_pages.at[:, dst].set(k_pages[:, src])
    v_pages = v_pages.at[:, dst].set(v_pages[:, src])
    return k_pages, v_pages


class TransferMixin:
    """Park/resume transfer machinery + device-pool plumbing (see the
    module docstring).  Mixed into :class:`~repro.serve.engine.Engine`."""

    # -- paged device-pool plumbing -------------------------------------------
    def _read_frame(self, phys: int) -> Dict[str, np.ndarray]:
        """Pull one frame's content (L, page, Hkv, D) off the device —
        the page-granularity transfer unit the pager's astores move."""
        kv = self.cache.kv
        return {"k": np.asarray(kv["k_pages"][:, phys]),
                "v": np.asarray(kv["v_pages"][:, phys])}

    def _land_frame(self, phys: int) -> None:
        """If the pool frame holds a far-tier payload that has not been
        scattered into the device pool yet, land it now."""
        frame = self.page_pool.frames[phys]
        if frame.data is None:
            return                       # content already lives in the pool
        kv = self.cache.kv
        kp, vp = _scatter_one_page(
            kv["k_pages"], kv["v_pages"],
            jnp.asarray(frame.data["k"]), jnp.asarray(frame.data["v"]),
            jnp.asarray(phys, jnp.int32))
        self.cache = self.cache._replace(kv=dict(kv, k_pages=kp, v_pages=vp))
        frame.data = None

    # -- paging helpers -------------------------------------------------------
    def _make_room(self, need: int, protect: frozenset,
                   preempt: bool = True) -> bool:
        """Bring the pool to at least ``need`` free frames.  Escalation
        order: getfin poll, LRU eviction of unpinned cached pages,
        draining in-flight fetches (their frames become evictable), then
        — for growth, never for fresh admission — preempting a victim."""
        pool = self.page_pool
        if pool.n_free >= need:
            return True
        self.pager.poll()
        while pool.n_free < need:
            if self.pager.evict_lru(need - pool.n_free):
                continue
            if self._resuming:
                for req in list(self._resuming.values()):
                    self.pager.wait_arriving(req.rid)
                if self.pager.evict_lru(need - pool.n_free):
                    continue
            if not preempt or not self._preempt_one(protect):
                return False
        return True

    def _preempt_one(self, protect: frozenset) -> bool:
        """Park the scheduler's chosen victim — a running sequence
        (:meth:`_park`) or a half-prefilled one whose completed chunks
        are parked as-is (:meth:`_park_prefilling`).  The watermark
        policy picks the most recently admitted; the SLO policy picks
        the slot whose SLO is already blown or furthest from its
        deadline, batch tier first."""
        victims = [r for r in list(self.active.values())
                   + list(self.prefilling.values()) if r.rid not in protect]
        if not victims or len(self.active) + len(self.prefilling) <= 1:
            return False
        victim = self.sched.pick_victim(victims, self.clock())
        if victim.mid_prefill:
            self._park_prefilling(victim)
        else:
            self._park(victim)
        return True

    def _shed_pages(self, req: Request, valid: int,
                    hot_pages: Optional[int] = None) -> None:
        """Shared parking machinery: keep the hot tail cached in the
        pool (unpinned, LRU-evictable), move cold pages to the far tier
        — BULK astore for dirty ones, for free when the far copy is
        still current (clean-eviction fast path, §2.3 QoS split).

        A far copy is *current* when its stored valid-token tag equals
        the page's live token count (append-only KV never rewrites a
        position, so equal coverage means equal content) — this is what
        lets previously-parked pages, prefix-shared pages and re-fetched
        pages all park for free, while a page that grew since its last
        writeback pays a fresh astore.  SWA rings rewrite pages in place
        on wrap, so they always write back.  Shared frames are released,
        not freed: the prefix cache (or another sharer) keeps them.
        """
        rid = req.rid
        n_pages = pages_for(valid, self.page_size)
        # a frame allocated for the *next* write (pos on a page boundary)
        # holds no content yet — release it; resume growth re-allocates
        self.page_table.truncate(rid, n_pages)
        n_hot = min(self.hot_tail_pages if hot_pages is None else hot_pages,
                    n_pages)
        n_cold = n_pages - n_hot
        for logical in range(n_pages - 1, -1, -1):   # tail first: hot
            pte = self.page_table.entry(rid, logical)
            if pte.state is PageState.PARKED:
                continue                 # already far (and current, by
            self.page_table.unpin_page(rid, logical)  # the park invariant)
            cur = min(self.page_size, valid - logical * self.page_size)
            clean = (self.cfg.attention != "swa"
                     and self.pager.far_tokens(rid, logical) == cur)
            if logical >= n_cold:                    # hot tail: stays pooled
                frame = self.page_pool.frames[pte.phys]
                frame.data = None                    # content is in the pool
                frame.dirty = not clean
                frame.tokens = cur   # LRU eviction keeps the freshness tag
                self.page_pool.touch(pte.phys)
            elif clean:
                self.pager.park_clean(rid, logical)  # far copy current
            else:
                self.pager.writeback(rid, logical,
                                     self._read_frame(pte.phys), tokens=cur,
                                     qos=self.sched.store_qos(req))

    def _park(self, req: Request) -> None:
        """Preempt a running sequence: cold pages → far tier (BULK), hot
        tail stays cached *in the device pool* (unpinned, LRU-evictable),
        slot freed, request back to the head of the queue.  The KV never
        round-trips through a dense slot: cold pages are read
        frame-by-frame off the pool (the page-granularity astore
        payload), hot pages do not move at all."""
        slot = req.slot
        tokens = int(np.asarray(self.cache.pos)[slot])
        self._shed_pages(req, min(tokens, self.slot_tokens))
        req.residue = extract_aux_slot(self.cache, slot, self.max_batch)
        req.parked = True
        req.n_preempts += 1
        req.slot = None
        self._pt_np[slot] = self.trash_frame
        self._pt_dirty = True
        del self.active[slot]
        self.pool.release(slot)
        self.queue.insert(0, req)
        self.stats["preemptions"] += 1
        self._obs_phase(req, "parked")
        self.events.post(EventKind.PREEMPT, req.rid)

    def _park_prefilling(self, req: Request) -> None:
        """Cancel a half-prefilled sequence: its *completed* chunks park
        exactly like a running sequence's pages (hot tail pooled, cold
        written back), and the prompt remainder simply re-enters the
        chunk queue on resume — no prefill work is redone.  The non-KV
        carry (hybrid SSM state between chunks) already lives host-side
        in ``req.chunk_ssm``, so nothing dense is extracted."""
        slot = req.slot
        self._shed_pages(req, req.prefill_pos)
        req.parked = True
        req.n_preempts += 1
        req.slot = None
        req.chunk_rows = None            # rebuilt from the table on resume
        del self.prefilling[slot]
        self.pool.release(slot)
        self.queue.insert(0, req)
        self.stats["preemptions"] += 1
        self.stats["prefill_preempts"] += 1
        self._obs_phase(req, "parked")
        self.events.post(EventKind.PREEMPT, req.rid)

    def _start_resume(self, req: Request) -> bool:
        """Begin bringing a parked request back: prefetch of its parked
        pages (LATENCY QoS for interactive tier, the scheduler may
        demote batch resumes to STANDARD), hot tail first, overlapping
        decode.  A resume is a continuation, not a fresh admission, so
        like growth it is exempt from the low watermark — it only needs
        raw frames."""
        parked = self.page_table.logical_pages(req.rid, PageState.PARKED)
        if self.page_pool.n_free < len(parked) and \
                not self._make_room(len(parked), frozenset({req.rid}),
                                    preempt=False):
            return False
        self.pager.prefetch_seq(req.rid, tail_first=True,
                                qos=self.sched.fetch_qos(req))
        self._resuming[req.rid] = req
        self._obs_phase(req, "resuming")
        return True

    def _try_finish_resumes(self) -> None:
        """Slot in any resuming request whose pages have all arrived.
        Re-entry is a page-table patch: pin the frames, land any payload
        that is still host-side, point the slot's page-table row at the
        frames and restore the tiny aux state.  The KV itself is already
        where decode reads it.  A request parked *mid-prefill* re-enters
        the chunk queue instead of the decode batch: its device
        page-table row stays on the trash frame and its completed-chunk
        frames go back into ``chunk_rows`` for the next chunk to attend
        through."""
        for rid, req in list(self._resuming.items()):
            if not self.page_table.resident(rid):
                # pages evicted again under pressure mid-resume get a
                # fresh prefetch (no-op when all are in flight)
                self.pager.prefetch_seq(rid, tail_first=True,
                                        qos=self.sched.fetch_qos(req))
                continue
            if not self.pool.n_free:
                continue
            slot = self.pool.alloc()
            rows = np.full((self.pages_per_seq,), self.trash_frame, np.int32)
            for logical in range(self.page_table.n_pages(rid)):
                pte = self.page_table.entry(rid, logical)
                self.page_table.pin_page(rid, logical)
                self.page_pool.touch(pte.phys)
                self._land_frame(pte.phys)
                rows[logical] = pte.phys
            req.slot = slot
            req.parked = False
            # a request admitted straight onto far-tier prefix pages —
            # or handed off from a PREFILL-role engine — arrives here
            # having never run: that is an admission, not a resume
            # (preemption/resume stats must stay balanced)
            first_admit = req.admit_seq < 0
            req.admit_seq = next(self._admits)
            if req.mid_prefill:
                req.chunk_rows = rows
                if self.cfg.family == "encdec":
                    self._install_cross(req)     # cross rows left with the slot
                self.prefilling[slot] = req
            else:
                self._ensure_private_tail(req)
                rows = np.full((self.pages_per_seq,), self.trash_frame,
                               np.int32)
                for logical in range(self.page_table.n_pages(rid)):
                    rows[logical] = self.page_table.entry(rid, logical).phys
                self._pt_np[slot] = rows
                self._pt_dirty = True
                self.cache = insert_aux_slot(self.cache, req.residue,
                                             slot, self.max_batch)
                req.residue = None
                self.active[slot] = req
            del self._resuming[rid]
            self.stats["admitted" if first_admit else "resumes"] += 1
            self._obs_phase(req, "prefill" if req.mid_prefill else "decode")
            self.events.post(EventKind.ADMIT, rid)

    def _alloc_pinned(self, req: Request, n_tokens: int) -> None:
        """Allocate (pin + mark dirty) frames so ``req`` covers
        ``n_tokens`` positions and point its slot's page-table row at
        them — active slots own their pages.  While a request is still
        chunk-prefilling, its frames go into the host-side
        ``chunk_rows`` instead: the *device* row keeps pointing at the
        trash frame so the fused decode half of the mixed step cannot
        scribble on a half-written prompt."""
        mid = req.mid_prefill and req.chunk_rows is not None
        for logical in self.page_table.ensure_capacity(req.rid, n_tokens):
            pte = self.page_table.entry(req.rid, logical)
            self.page_table.pin_page(req.rid, logical)
            self.page_pool.mark_dirty(pte.phys)
            if mid:
                req.chunk_rows[logical] = pte.phys
            else:
                self._pt_np[req.slot, logical] = pte.phys
                self._pt_dirty = True

    def _ensure_private(self, req: Request, logical: int) -> None:
        """COW break: if the frame backing ``(req, logical)`` is a
        prefix-shared (copy-on-write) frame this step is about to write,
        remap the page onto a private duplicate first.  Unreachable on
        the supported sharing families by construction — only *full*
        prompt pages are shared and decode appends strictly after them —
        but the guard keeps the donated in-place pool scatters safe
        against any future schedule that routes a write at a shared
        frame."""
        pte = self.page_table.entry(req.rid, logical)
        if pte.phys == NOT_MAPPED:
            return
        frame = self.page_pool.frames[pte.phys]
        if not frame.cow or frame.refs <= 1:
            return
        old, new = self.page_table.remap_private(req.rid, logical)
        if new == old:
            return
        kv = self.cache.kv
        kp, vp = _copy_frame(kv["k_pages"], kv["v_pages"],
                             jnp.asarray(old, jnp.int32),
                             jnp.asarray(new, jnp.int32))
        self.cache = self.cache._replace(kv=dict(kv, k_pages=kp, v_pages=vp))
        if req.mid_prefill and req.chunk_rows is not None:
            req.chunk_rows[logical] = new
        elif req.slot is not None:
            self._pt_np[req.slot, logical] = new
            self._pt_dirty = True

    def _ensure_private_tail(self, req: Request) -> None:
        """Guard the page decode writes next (the sequence's last mapped
        page) against COW sharing before the slot goes active."""
        n = self.page_table.n_pages(req.rid)
        if n:
            self._ensure_private(req, n - 1)

    def _ensure_growth(self, drafts: Optional[Dict[int, int]] = None) -> None:
        """Before a decode step: every active sequence about to cross a
        page boundary gets a pinned frame, evicting/preempting under the
        watermark policy when the pool is short.

        ``drafts`` (rid -> drafted tokens) widens a speculating slot's
        write window from one position to ``1 + drafts[rid]`` — the
        verify step scatters K/V at ``[pos, pos + 1 + drafts[rid])``,
        possibly straddling a page boundary, so every touched mapped
        page gets the COW guard and enough frames are pinned up front.
        The speculative extra degrades instead of failing: when the
        pool cannot cover the full draft the entry is clamped in place
        (down to 0 = plain decode) and only the base ``pos + 1`` growth
        keeps the old must-succeed contract."""
        pos_np = np.asarray(self.cache.pos)     # one device sync per step
        for req in list(self.active.values()):
            if req.slot is None or req.slot not in self.active:
                continue                    # preempted by an earlier victim
            pos = int(pos_np[req.slot])
            if pos >= self.slot_tokens:
                continue                    # SWA ring wrapped: no growth
            extra = drafts.get(req.rid, 0) if drafts else 0
            if extra and pos + 1 + extra > self.slot_tokens:
                extra = max(0, self.slot_tokens - pos - 1)
            # COW-guard every mapped page the write range touches (the
            # draft tail can straddle into the next page)
            n_mapped = self.page_table.n_pages(req.rid)
            first_wp = pos // self.page_size
            last_wp = min((pos + extra) // self.page_size, n_mapped - 1)
            for wp in range(first_wp, last_wp + 1):
                self._ensure_private(req, wp)
            while True:
                target = pos + 1 + extra
                need = self.page_table.pages_needed(req.rid, target)
                if not need:
                    break
                if self._make_room(need, frozenset({req.rid})):
                    break
                if extra == 0:
                    raise PagingError(
                        f"cannot grow request {req.rid}: pool of "
                        f"{self.page_pool.n_pages} pages exhausted")
                extra -= 1              # shed draft positions, not the slot
            if drafts is not None and req.rid in drafts:
                drafts[req.rid] = extra
            if need:
                self._alloc_pinned(req, target)

    # -- finished-sequence offload + cross-engine handoff ---------------------
    def _offload_finished(self, req: Request) -> None:
        """Park a finished sequence page-by-page into THE far tier — the
        same BULK writeback / clean-park machinery preemption uses, no
        sequence-granularity side store.  The tiny aux residue (SSM
        state, cross KV, positions) and the page count ride along as one
        more far-tier entry; :meth:`fetch_finished` reassembles — or,
        under a PREFILL role, a DECODE-role engine's
        :meth:`~repro.serve.admission.AdmissionMixin.admit_handoff`."""
        slot = req.slot
        rid = req.rid
        tokens = min(int(np.asarray(self.cache.pos)[slot]), self.slot_tokens)
        aux = extract_aux_slot(self.cache, slot, self.max_batch)
        self.far_tier.offload(
            (rid, "aux"),
            {"aux": aux, "tokens": tokens,
             "pages": pages_for(tokens, self.page_size)})
        # every page goes far (hot_pages=0): the sequence is leaving the
        # device; shared prefix pages park for free via their aliases
        self._shed_pages(req, tokens, hot_pages=0)

    def _publish_handoff(self, req: Request) -> None:
        """PREFILL-role graduation, control-plane half: the data plane
        (pages + aux residue) is already in the shared tier courtesy of
        :meth:`_offload_finished`; publish the identity/SLO record the
        decode engine admits by.  Published strictly *after* every tier
        entry exists — the handoff-record invariant the disagg property
        tests pin (a record must never dangle)."""
        meta = self.far_tier.home((req.rid, "aux"))
        rec = HandoffRecord(
            rid=req.rid, prompt=np.asarray(req.prompt),
            max_new_tokens=req.max_new_tokens, eos_id=req.eos_id,
            n_tokens=meta["tokens"], n_pages=meta["pages"],
            generated=list(req.generated), token_ts=list(req.token_ts),
            tier=req.tier, ttft_slo=req.ttft_slo, tpot_slo=req.tpot_slo,
            arrival_t=req.arrival_t, submitted_t=req.submitted_t,
            first_token_t=req.first_token_t, done=req.done,
            src_len=req.src_len)
        self.handoff.publish(rec)
        self.stats["handoffs"] += 1
        if self.tracer.enabled:
            self.tracer.instant(
                "requests", f"req{req.rid}", "handoff",
                {"n_pages": rec.n_pages, "n_tokens": rec.n_tokens,
                 "done": rec.done})
        self.events.post(EventKind.HANDOFF, req.rid)

    def fetch_finished(self, rid: int) -> Cache:
        """Reassemble a finished, offloaded request's dense single-
        sequence cache from its far-tier pages (LATENCY aloads, all
        issued before the first wait so the transfers overlap — the
        pager's fault-safe :meth:`~repro.paging.Pager.fetch_keys`
        helper, shared with the cross-engine handoff fetch).

        Fault-safe: entries are discarded only after *every* transfer
        has verifiably landed — a fault mid-fetch raises, but the far
        copies survive and a retry re-issues the lost aloads (the PR 3
        pager fault discipline applied to the reuse path)."""
        if not self.offload_finished:
            raise PagingError("engine was not built with offload_finished")
        tier = self.far_tier
        meta = tier.get((rid, "aux"))
        n_pages, tokens = meta["pages"], meta["tokens"]
        keys = [(rid, logical) for logical in range(n_pages)]
        # overlapped fetch; discards only after every payload landed
        payloads = self.pager.fetch_keys(keys, discard_after=True)
        kv = self.cache.kv
        L, _, page, Hkv, D = kv["k_pages"].shape
        pages = []
        for logical, key in enumerate(keys):
            data = payloads[key]
            take = min(page, tokens - logical * page)
            if take <= 0:
                break
            pages.append({"k": np.asarray(data["k"])[:, None, :take],
                          "v": np.asarray(data["v"])[:, None, :take]})
        tier.discard((rid, "aux"))
        aux = meta["aux"]
        kdt = np.dtype(kv["k_pages"].dtype)
        residue = Cache(
            kv={"k": np.zeros((L, 1, 0, Hkv, D), kdt),
                "v": np.zeros((L, 1, 0, Hkv, D), kdt),
                "pos": np.zeros((), np.int32),
                "slots": np.asarray(self.slot_tokens, np.int32)},
            ssm=aux["ssm"], cross=aux["cross"], pos=aux["pos"])
        return join_kv_pages(residue, pages, self.slot_tokens)

"""Root pytest config.

When the real ``hypothesis`` package is unavailable (hermetic containers
where ``pip install`` is not an option), install a deterministic,
minimal stand-in into ``sys.modules`` before test collection so the
property-test modules still collect and run.  CI installs the real
package via ``pip install -e .[test]``, in which case this shim is
completely inert.

The stub covers exactly the API surface the test-suite uses — ``given``,
``settings``, ``assume`` and the ``integers`` / ``floats`` / ``booleans``
/ ``sampled_from`` / ``lists`` / ``tuples`` / ``just`` / ``one_of`` /
``permutations`` / ``sets`` / ``data`` / ``composite`` strategies —
drawing
pseudo-random examples from a per-test seeded RNG (reproducible across
runs; no shrinking, no example database).
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types


def _build_hypothesis_stub() -> types.ModuleType:
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    mod.__version__ = "0.0-repro-stub"
    mod.strategies = st

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rnd: random.Random):
            return self._draw(rnd)

    def integers(min_value, max_value):
        def draw(rnd):
            if rnd.random() < 0.15:          # bias toward the bounds
                return rnd.choice((min_value, max_value))
            return rnd.randint(min_value, max_value)
        return _Strategy(draw)

    def floats(min_value, max_value, **_kw):
        def draw(rnd):
            if rnd.random() < 0.1:
                return rnd.choice((min_value, max_value))
            return rnd.uniform(min_value, max_value)
        return _Strategy(draw)

    def booleans():
        return _Strategy(lambda rnd: rnd.random() < 0.5)

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rnd: rnd.choice(elements))

    def lists(elements, *, min_size=0, max_size=10, **_kw):
        def draw(rnd):
            n = rnd.randint(min_size, max_size)
            return [elements.draw(rnd) for _ in range(n)]
        return _Strategy(draw)

    def tuples(*strategies):
        return _Strategy(lambda rnd: tuple(s.draw(rnd) for s in strategies))

    def just(value):
        return _Strategy(lambda rnd: value)

    def one_of(*strategies):
        flat = list(strategies)
        return _Strategy(lambda rnd: rnd.choice(flat).draw(rnd))

    def permutations(values):
        values = list(values)

        def draw(rnd):
            out = list(values)
            rnd.shuffle(out)
            return out
        return _Strategy(draw)

    def sets(elements, *, min_size=0, max_size=None, **_kw):
        def draw(rnd):
            n = rnd.randint(min_size, 10 if max_size is None else max_size)
            out = set()
            for _ in range(n * 5):       # bounded retry on duplicates
                if len(out) >= n:
                    break
                out.add(elements.draw(rnd))
            return out
        return _Strategy(draw)

    class _DataObject:
        """Interactive draws (``st.data()``): strategies drawn mid-test
        from the same per-test seeded RNG."""

        def __init__(self, rnd):
            self._rnd = rnd

        def draw(self, strategy, label=None):
            return strategy.draw(self._rnd)

    def data():
        return _Strategy(lambda rnd: _DataObject(rnd))

    def composite(fn):
        """``@st.composite``: the wrapped function's first argument
        becomes a ``draw`` callable bound to the per-test RNG."""
        @functools.wraps(fn)
        def builder(*args, **kwargs):
            def drawer(rnd):
                return fn(lambda strategy, label=None: strategy.draw(rnd),
                          *args, **kwargs)
            return _Strategy(drawer)
        return builder

    st.integers = integers
    st.floats = floats
    st.booleans = booleans
    st.sampled_from = sampled_from
    st.lists = lists
    st.tuples = tuples
    st.just = just
    st.one_of = one_of
    st.permutations = permutations
    st.sets = sets
    st.data = data
    st.composite = composite

    class _Unsatisfied(Exception):
        pass

    def assume(condition):
        if not condition:
            raise _Unsatisfied()
        return True

    class settings:  # noqa: N801 - mirrors the hypothesis name
        def __init__(self, max_examples=20, deadline=None, **_kw):
            self.max_examples = max_examples
            self.deadline = deadline

        def __call__(self, fn):
            fn._stub_settings = self
            return fn

    class HealthCheck:  # noqa: N801 - attribute access only
        all = staticmethod(lambda: [])
        too_slow = data_too_large = filter_too_much = None

    def given(*given_args, **given_kwargs):
        def decorate(fn):
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            # positional strategies bind right-aligned, like hypothesis
            strat_map = dict(zip(names[len(names) - len(given_args):],
                                 given_args))
            strat_map.update(given_kwargs)
            passthrough = [sig.parameters[n] for n in names
                           if n not in strat_map]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                cfg = (getattr(wrapper, "_stub_settings", None)
                       or getattr(fn, "_stub_settings", None))
                n_examples = cfg.max_examples if cfg else 20
                rnd = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                ran = 0
                for _ in range(n_examples * 5):
                    if ran >= n_examples:
                        break
                    drawn = {k: s.draw(rnd) for k, s in strat_map.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except _Unsatisfied:
                        continue
                    ran += 1

            # pytest must only see the fixture params, not the drawn ones
            wrapper.__signature__ = sig.replace(parameters=passthrough)
            wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
            return wrapper
        return decorate

    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.HealthCheck = HealthCheck
    mod.strategies = st
    return mod


try:
    import hypothesis  # noqa: F401
except ImportError:
    _stub = _build_hypothesis_stub()
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _stub.strategies

"""Per-architecture smoke tests: reduced same-family config, one forward
+ one backward (train) step and a prefill+decode step on CPU; asserts
output shapes and absence of NaNs.  The FULL configs are exercised only
via the dry-run (launch/dryrun.py)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models import (decode_step, init_params, prefill, train_loss,
                          count_params)


def _batch(cfg, B=2, S=16, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(ks[2], (B, S, cfg.d_model),
                                                jnp.float32)
    if cfg.mrope_sections:
        batch["positions"] = jnp.broadcast_to(jnp.arange(S), (3, B, S))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    assert count_params(params) > 0
    batch = _batch(cfg)

    def loss_fn(p):
        loss, metrics = train_loss(p, cfg, batch, remat="block")
        return loss, metrics

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True))(params)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves, f"{arch}: empty grads"
    for g in leaves:
        assert jnp.isfinite(g).all(), f"{arch}: non-finite grad"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, cache = jax.jit(lambda p, b: prefill(p, cfg, b))(params, batch)
    assert logits.shape == (B, cfg.padded_vocab)
    assert jnp.isfinite(logits).all()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    for _ in range(3):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert logits.shape == (B, cfg.padded_vocab)
    assert jnp.isfinite(logits).all()
    assert int(cache.pos[0]) == S + 3


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_sanity(arch):
    """Full configs: no allocation — only analytical invariants."""
    cfg = get_config(arch)
    assert cfg.padded_vocab % 256 == 0
    assert cfg.param_count() > 0
    if cfg.num_experts:
        assert cfg.active_param_count() < cfg.param_count()
    if cfg.family in ("dense", "moe"):
        assert cfg.num_heads % cfg.num_kv_heads == 0

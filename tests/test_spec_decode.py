"""Property test: self-speculative decode is bit-exact with plain greedy.

The acceptance criterion for the verify-K path: with speculation ON —
any proposer, any K in 1..4, drafts straddling page boundaries, drafts
rejected at position 0, random admission/preempt/resume/prefix-hit
churn — every request's token stream must equal the dense single-step
engine's byte for byte.  Greedy argmax decode is deterministic, so
exact equality is the bar, not closeness.

Three proposers cover the acceptance spectrum:

* ``NgramProposer`` (the shipping one) — whatever the prompt-lookup
  index happens to hit;
* an oracle that drafts the reference continuation — forces the
  accept-all / bonus-token path and page-boundary-straddling commits;
* an adversary that drafts provably wrong tokens — forces the
  reject-at-position-0 rollback path every single step.

Uses the real ``hypothesis`` when installed, the deterministic conftest
stand-in otherwise.
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke
from repro.models import init_params
from repro.paging import PagingError, pages_for
from repro.serve.config import (ChunkingConfig, EngineConfig, PagingConfig,
                                SpeculationConfig)
from repro.serve.engine import Engine
from repro.serve.speculate import NgramProposer, ngram_key
from tests.test_paged_decode import _slow_pager_factory


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("phi4-mini-3.8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params, {}


def _dense_reference(cfg, params, cache, requests):
    key = tuple((tuple(int(t) for t in p), n) for p, n in requests)
    if key not in cache:
        eng = Engine(cfg, params, EngineConfig(
            max_batch=3, max_len=64, prefill_buckets=(16,),
            paging=PagingConfig(enabled=False)))
        for prompt, new in requests:
            eng.submit(prompt, max_new_tokens=new)
        cache[key] = eng.run()
    return cache[key]


class _OracleProposer:
    """Drafts the dense reference's continuation: every draft token
    matches the verify argmax, driving the accept-all + bonus path."""

    def __init__(self, refs, prompt_lens, k):
        self.refs, self.prompt_lens, self.k = refs, prompt_lens, k

    def propose(self, rid, history):
        ngen = len(history) - self.prompt_lens[rid]
        return list(self.refs[rid][ngen:ngen + self.k])

    def drop(self, rid):
        pass


class _WrongProposer(_OracleProposer):
    """Drafts reference-token + 1 (mod V): provably wrong at every
    position, so each verify step rejects at position 0 and commits
    only the bonus token — the maximal-rollback worst case."""

    def __init__(self, refs, prompt_lens, k, vocab):
        super().__init__(refs, prompt_lens, k)
        self.vocab = vocab

    def propose(self, rid, history):
        return [(t + 1) % self.vocab
                for t in super().propose(rid, history)]


class _FirstRightProposer(_WrongProposer):
    """First draft token right, the rest wrong: pins the partial-accept
    arithmetic (accepted == 1 per step when K > 1)."""

    def propose(self, rid, history):
        right = _OracleProposer.propose(self, rid, history)
        return right[:1] + [(t + 1) % self.vocab for t in right[1:]]


def _proposer_factory(kind, refs, requests, vocab):
    lens = {i: len(p) for i, (p, _) in enumerate(requests)}
    return {
        "ngram": None,                        # engine default
        "oracle": lambda n, k: _OracleProposer(refs, lens, k),
        "wrong": lambda n, k: _WrongProposer(refs, lens, k, vocab),
        "first": lambda n, k: _FirstRightProposer(refs, lens, k, vocab),
    }[kind]


def _spec_engine(cfg, params, requests, *, k, factory=None, page_size=4,
                 spare_pages=8, latency=None, chunking=False, ngram=3):
    need = max(pages_for(min(len(p) + n, 64), page_size)
               for p, n in requests)
    pager = _slow_pager_factory(latency) if latency else None
    eng = Engine(cfg, params, EngineConfig(
        max_batch=3, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(page_size=page_size,
                            device_pages=need + spare_pages,
                            pager_factory=pager),
        chunking=ChunkingConfig(chunk_tokens=4) if chunking
        else ChunkingConfig(),
        speculation=SpeculationConfig(speculate_k=k, speculate_ngram=ngram,
                                      proposer_factory=factory)))
    for prompt, new in requests:
        eng.submit(prompt, max_new_tokens=new)
    return eng


def _check(eng, out, ref):
    assert out == ref
    eng.check_invariants()
    s = eng.stats
    assert s["accepted"] + s["rejected"] == s["drafted"]
    assert eng.page_pool.n_free == eng.page_pool.n_pages
    return s


# ---------------------------------------------------------------------------
# kernel-level anchors
# ---------------------------------------------------------------------------

def test_multi_token_row_bitexact_with_one_token():
    """Token-exactness rests on this: row ``s`` of the S-row verify
    attention must be BITWISE equal to a sequential one-token step with
    the same visible KV — same expression chain, one extra axis."""
    import jax.numpy as jnp

    from repro.models.attention import (multi_token_attention,
                                        one_token_attention)

    rng = np.random.default_rng(3)
    B, S, Hkv, G, D, Skv = 2, 3, 2, 3, 16, 24
    H = Hkv * G
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.bfloat16)
    kc = jnp.asarray(rng.standard_normal((B, Skv, Hkv, D)), jnp.bfloat16)
    vc = jnp.asarray(rng.standard_normal((B, Skv, Hkv, D)), jnp.bfloat16)
    valid = jnp.asarray(rng.integers(1, Skv + 1, (B, S)), jnp.int32)
    multi = np.asarray(multi_token_attention(q, kc, vc, valid, Hkv))
    for s in range(S):
        one = np.asarray(one_token_attention(
            q[:, s], kc, vc, valid[:, s], Hkv))
        np.testing.assert_array_equal(multi[:, s], one[:, 0])


def test_paged_verify_attention_interpret_matches_xla():
    """The multi-query Pallas gather kernel vs the XLA dense-view path
    on mixed per-row lengths and permuted page tables."""
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(5)
    B, S, Hkv, G, D, page, per_seq = 2, 4, 2, 4, 32, 16, 3
    H = Hkv * G
    N = B * per_seq + 2
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((N, page, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((N, page, Hkv, D)), jnp.float32)
    pt = rng.permutation(N)[:B * per_seq].reshape(B, per_seq)
    slots = per_seq * page
    # each verify row sees one more token than the last; straddle pages
    base = np.array([13, 30], np.int32)
    lengths = np.minimum(base[:, None] + np.arange(S)[None, :] + 1, slots)
    xla = ops.paged_verify_attention(
        q, kp, vp, jnp.asarray(pt.astype(np.int32)),
        jnp.asarray(lengths.astype(np.int32)), impl="xla")
    pallas = ops.paged_verify_attention(
        q, kp, vp, jnp.asarray(pt.astype(np.int32)),
        jnp.asarray(lengths.astype(np.int32)), impl="interpret")
    np.testing.assert_allclose(np.asarray(pallas), np.asarray(xla),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# proposer unit tests
# ---------------------------------------------------------------------------

def test_ngram_proposer_prompt_lookup():
    p = NgramProposer(n=2, k=3)
    # trailing (5, 6) last occurred at position 0 -> draft what followed
    assert p.propose("r", [5, 6, 7, 8, 5, 6]) == [7, 8, 5]
    # no earlier occurrence of the trailing n-gram -> no draft
    assert p.propose("x", [1, 2, 3, 4]) == []


def test_ngram_proposer_index_is_incremental_and_droppable():
    p = NgramProposer(n=2, k=2)
    hist = [1, 2, 3, 1, 2]
    assert p.propose("r", hist) == [3, 1]
    # growing the same history only indexes the new suffix; the most
    # recent occurrence wins the lookup
    hist = hist + [3, 1, 2]
    assert p.propose("r", hist) == [3, 1]
    p.drop("r")
    assert "r" not in p._idx


def test_ngram_key_is_order_sensitive():
    assert ngram_key([1, 2, 3]) != ngram_key([3, 2, 1])
    assert ngram_key([1, 2, 3]) == ngram_key(np.array([1, 2, 3], np.int32))


def test_ngram_proposer_validates_params():
    with pytest.raises(ValueError):
        NgramProposer(n=0, k=2)
    with pytest.raises(ValueError):
        NgramProposer(n=2, k=0)


# ---------------------------------------------------------------------------
# deterministic accept / reject / boundary cases
# ---------------------------------------------------------------------------

def _requests(cfg, seed=7, n_req=4):
    rng = np.random.default_rng(seed)
    base = rng.integers(1, cfg.vocab_size, size=6, dtype=np.int32)
    out = []
    for i in range(n_req):
        tail = rng.integers(1, cfg.vocab_size, size=i + 1, dtype=np.int32)
        out.append((np.concatenate([base, base, tail]).astype(np.int32),
                    int(rng.integers(8, 13))))
    return out


def test_oracle_accepts_all_and_compresses_steps(setup):
    """A perfect draft commits K+1 tokens per verify step — token-exact,
    zero rejections, and far fewer engine steps than plain decode."""
    cfg, params, ref_cache = setup
    requests = _requests(cfg)
    ref = _dense_reference(cfg, params, ref_cache, requests)
    eng = _spec_engine(cfg, params, requests, k=3,
                       factory=_proposer_factory("oracle", ref, requests,
                                                 cfg.vocab_size))
    s = _check(eng, eng.run(), ref)
    assert s["drafted"] > 0 and s["rejected"] == 0
    total_new = sum(n for _, n in requests)
    assert s["steps"] < total_new  # K+1 tokens/step actually compressed


def test_reject_at_position_zero_rolls_back_every_step(setup):
    """Provably-wrong drafts: every verify step rejects at position 0,
    rolls the rejected tail back, and still emits the plain-path
    token — the stream stays exact under maximal rollback churn."""
    cfg, params, ref_cache = setup
    requests = _requests(cfg)
    ref = _dense_reference(cfg, params, ref_cache, requests)
    eng = _spec_engine(cfg, params, requests, k=3,
                       factory=_proposer_factory("wrong", ref, requests,
                                                 cfg.vocab_size))
    s = _check(eng, eng.run(), ref)
    assert s["drafted"] > 0 and s["accepted"] == 0
    assert s["rejected"] == s["drafted"]


def test_partial_accept_first_token_only(setup):
    cfg, params, ref_cache = setup
    requests = _requests(cfg)
    ref = _dense_reference(cfg, params, ref_cache, requests)
    eng = _spec_engine(cfg, params, requests, k=3,
                       factory=_proposer_factory("first", ref, requests,
                                                 cfg.vocab_size))
    s = _check(eng, eng.run(), ref)
    assert s["drafted"] > 0
    assert 0 < s["accepted"] < s["drafted"]


def test_drafts_straddle_page_boundaries(setup):
    """page_size=4 with K=4 drafts: the verify write window [pos, pos+5)
    regularly spans two pages, and rejected tails land on freshly grown
    pages that rollback must return to the pool."""
    cfg, params, ref_cache = setup
    requests = _requests(cfg, seed=11)
    ref = _dense_reference(cfg, params, ref_cache, requests)
    for kind in ("oracle", "wrong"):
        eng = _spec_engine(cfg, params, requests, k=4, page_size=4,
                           factory=_proposer_factory(kind, ref, requests,
                                                     cfg.vocab_size))
        s = _check(eng, eng.run(), ref)
        assert s["drafted"] > 0


def test_ngram_default_proposer_stays_exact(setup):
    """The shipping prompt-lookup proposer, no injection: acceptance is
    whatever the index earns, exactness is unconditional."""
    cfg, params, ref_cache = setup
    requests = _requests(cfg)
    ref = _dense_reference(cfg, params, ref_cache, requests)
    eng = _spec_engine(cfg, params, requests, k=3)
    _check(eng, eng.run(), ref)


def test_speculation_requires_paged_engine(setup):
    cfg, params, _ = setup
    with pytest.raises(PagingError):
        Engine(cfg, params, EngineConfig(
            max_batch=2, max_len=64, prefill_buckets=(16,),
            paging=PagingConfig(enabled=False),
            speculation=SpeculationConfig(speculate_k=2)))


# ---------------------------------------------------------------------------
# the property: churn + speculation stays token-exact
# ---------------------------------------------------------------------------

@st.composite
def _scenarios(draw):
    return {
        "seed": draw(st.integers(0, 2**16)),
        "page_size": draw(st.sampled_from([4, 8])),
        "spare_pages": draw(st.integers(0, 3)),
        "k": draw(st.integers(1, 4)),
        "kind": draw(st.sampled_from(["ngram", "oracle", "wrong", "first"])),
        "latency": draw(st.floats(1e-5, 3e-3)),
        "chunking": draw(st.booleans()),
    }


@settings(max_examples=6, deadline=None)
@given(sc=_scenarios())
def test_property_spec_decode_matches_plain(setup, sc):
    """Random admission/preempt/resume churn (tight pool, slow pager)
    with speculation ON across K in 1..4 and all proposer kinds: the
    token streams must be byte-identical to the dense single-step
    engine, the accounting identity must hold, and rollback must leave
    the pool clean."""
    cfg, params, ref_cache = setup
    rng = np.random.default_rng(sc["seed"])
    n_req = int(rng.integers(3, 6))
    requests = [(rng.integers(0, cfg.vocab_size,
                              int(rng.integers(2, 17))).astype(np.int32),
                 int(rng.integers(2, 13)))
                for _ in range(n_req)]
    ref = _dense_reference(cfg, params, ref_cache, requests)
    eng = _spec_engine(
        cfg, params, requests, k=sc["k"], page_size=sc["page_size"],
        spare_pages=sc["spare_pages"], latency=sc["latency"],
        chunking=sc["chunking"],
        factory=_proposer_factory(sc["kind"], ref, requests,
                                  cfg.vocab_size))
    s = _check(eng, eng.run(), ref)
    if sc["kind"] in ("oracle", "wrong", "first"):
        assert s["drafted"] > 0
    assert eng.stats["resumes"] == eng.stats["preemptions"]

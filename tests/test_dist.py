"""Distributed tests: run dist_worker.py in a subprocess with 8 forced
host devices (keeps this process single-device), parse RESULT lines."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

_WORKER = Path(__file__).parent / "dist_worker.py"
_SRC = str(Path(__file__).parent.parent / "src")


def _run(mode: str, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    out = subprocess.run(
        [sys.executable, str(_WORKER), mode],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"worker failed:\n{out.stdout}\n{out.stderr}"
    for line in out.stdout.splitlines():
        if line.startswith("RESULT"):
            return line
    raise AssertionError(f"no RESULT line:\n{out.stdout}\n{out.stderr}")


def test_sharded_train_step_moe():
    line = _run("train")
    assert "finite=True" in line
    assert "improved=True" in line        # loss drops on repeated batch
    assert "sharded=True" in line         # TP/EP actually sharded params


def test_sharded_prefill_and_serve_step():
    line = _run("serve")
    assert "finite=True" in line
    assert "pos=66" in line               # 64 prefill + 2 decode steps


def test_sharded_prefill_matches_single_device():
    """(2, 4)-mesh full-sequence prefill equality vs single device: the
    regression guard for the rope-over-sharded-projection SPMD
    miscompile on the prefill/train path (ROADMAP record; decode and
    chunked prefill have their own guard in test_mixed_step)."""
    line = _run("prefill_eq")
    assert "logits_ok=True" in line
    assert "k_ok=True" in line


def test_engine_decode_mesh_sharded():
    """Engine wired onto dist.steps.make_serve_step: TP-sharded params,
    continuous batching and the paged KV pool all on a (2, 4) mesh."""
    line = _run("engine")
    assert "done=5" in line
    assert "lens=[6, 6, 6, 6, 6]" in line
    assert "sharded=True" in line
    assert "shared=True" in line          # batched decode, no drain barrier


def test_elastic_restart_smaller_mesh():
    line = _run("elastic")
    assert "new_shape=(1, 4)" in line
    assert "step=2" in line               # optimizer step carried over
    assert "finite=True" in line


def test_multipod_sharding_specs():
    line = _run("specs")
    parts = dict(kv.split("=") for kv in line.split() if "=" in kv)
    # layer stacks are single leaves, so the tree is small — what matters
    # is that the big leaves are TP-sharded and everything ZeRO-shards.
    assert int(parts["model_sharded"]) >= 8      # all projections + tables
    assert int(parts["zero_sharded"]) == int(parts["total"])

"""Chunked paged prefill + mixed prefill/decode step tests.

Acceptance for the chunk-queue engine (PR 4): prompts computed chunk by
chunk directly on the pool layout, fused with decode in one mixed step,
must generate exactly the tokens a dense non-paged engine generates —
at every page/chunk boundary, under mid-prefill preemption/resume, and
across the dense / hybrid / enc-dec families.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.amu import AMU, SimBackend
from repro.models import init_params
from repro.paging import Pager
from repro.serve.config import ChunkingConfig, EngineConfig, PagingConfig
from repro.serve.engine import Engine


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("phi4-mini-3.8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run(cfg, params, prompts, *, max_new=6, src=None,
         paging=PagingConfig(), chunking=ChunkingConfig()):
    eng = Engine(cfg, params, EngineConfig(
        max_batch=3, max_len=64, prefill_buckets=(16, 32),
        paging=paging, chunking=chunking))
    for i, p in enumerate(prompts):
        kw2 = {"src_embeds": src[i]} if src is not None else {}
        eng.submit(p, max_new_tokens=max_new, **kw2)
    return eng, eng.run()


def _slow_pager_factory(base_latency):
    def factory(pool, table, *, page_nbytes):
        amu = AMU(backend=SimBackend(base_latency=base_latency,
                                     bandwidth=10e9),
                  max_outstanding=64)
        return Pager(pool, table, amu, page_nbytes=page_nbytes)
    return factory


def test_chunk_boundaries_match_dense(setup):
    """Prompt lengths at exact page (4) and chunk (4/8) multiples +/- 1:
    every boundary case rides one engine run and must match the dense
    engine token-for-token."""
    cfg, params = setup
    lengths = [3, 4, 5, 7, 8, 9, 11, 12, 13, 15, 16, 17]
    prompts = [(np.arange(n) + n) % cfg.vocab_size for n in lengths]
    _, ref = _run(cfg, params, prompts,
                  paging=PagingConfig(enabled=False))
    for chunk in (4, 8):
        eng, out = _run(cfg, params, prompts,
                        paging=PagingConfig(page_size=4),
                        chunking=ChunkingConfig(chunk_tokens=chunk,
                                                chunk_slots=2))
        assert out == ref, f"chunk_tokens={chunk}"
        assert eng.stats["chunks"] > len(prompts)      # actually chunked
        assert eng.stats["prefills"] == 0              # no dense fallback
        assert eng.page_pool.n_free == eng.page_pool.n_pages


def test_single_chunk_covers_whole_prompt(setup):
    """chunk_tokens >= prompt: one chunk per prompt, still on the pool
    layout (the admission path never materialises dense KV)."""
    cfg, params = setup
    prompts = [np.arange(7) % cfg.vocab_size, np.arange(13) % cfg.vocab_size]
    _, ref = _run(cfg, params, prompts,
                  paging=PagingConfig(enabled=False))
    eng, out = _run(cfg, params, prompts,
                    paging=PagingConfig(page_size=4),
                    chunking=ChunkingConfig(chunk_tokens=64))
    assert out == ref
    assert eng.stats["chunks"] == len(prompts)
    assert eng.stats["prefills"] == 0


def test_mid_prefill_preemption_resumes_exactly(setup):
    """A half-prefilled sequence preempted by pool pressure parks its
    completed chunks, resumes, finishes the prompt and decodes — output
    identical to the dense engine (no prefill work redone densely)."""
    cfg, params = setup
    prompts = [(np.arange(16) % cfg.vocab_size),
               (np.arange(16) + 3) % cfg.vocab_size,
               (np.arange(12) + 5) % cfg.vocab_size]
    _, ref = _run(cfg, params, prompts, max_new=8,
                  paging=PagingConfig(enabled=False))
    eng, out = _run(cfg, params, prompts, max_new=8,
                    paging=PagingConfig(page_size=4, device_pages=6,
                                        hot_tail_pages=0),
                    chunking=ChunkingConfig(chunk_tokens=4,
                                            chunk_slots=2))
    assert eng.stats["prefill_preempts"] > 0   # cancelled mid-prefill
    assert eng.stats["resumes"] == eng.stats["preemptions"]
    assert out == ref
    assert eng.page_pool.n_free == eng.page_pool.n_pages


def test_mid_prefill_preemption_slow_pager(setup):
    """Same churn with multi-tick fetch latency: resumed prefills wait
    out ARRIVING pages before their next chunk runs."""
    cfg, params = setup
    prompts = [(np.arange(16) % cfg.vocab_size),
               (np.arange(16) + 3) % cfg.vocab_size,
               (np.arange(12) + 5) % cfg.vocab_size]
    _, ref = _run(cfg, params, prompts, max_new=8,
                  paging=PagingConfig(enabled=False))
    eng, out = _run(cfg, params, prompts, max_new=8,
                    paging=PagingConfig(
                        page_size=4, device_pages=6, hot_tail_pages=0,
                        pager_factory=_slow_pager_factory(2.5e-3)),
                    chunking=ChunkingConfig(chunk_tokens=4,
                                            chunk_slots=2))
    assert eng.stats["preemptions"] > 0
    assert out == ref


@pytest.mark.parametrize("arch", ["zamba2-1.2b", "seamless-m4t-medium"])
def test_mixed_step_other_families(arch):
    """Hybrid (SSM carry threaded between chunks host-side) and enc-dec
    (cross-KV installed once at admission) also chunk-prefill on the
    pool layout, bit-compatible with their dense engines — including
    under preemption churn."""
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = [np.arange(8) % cfg.vocab_size,
               (np.arange(5) + 2) % cfg.vocab_size,
               (np.arange(8) + 4) % cfg.vocab_size]
    src = None
    if cfg.family == "encdec":
        rng = np.random.default_rng(0)
        src = [rng.standard_normal((len(p), cfg.d_model)).astype(np.float32)
               for p in prompts]

    def run(**kw):
        eng = Engine(cfg, params, EngineConfig(
            max_batch=2, max_len=32, prefill_buckets=(8,), **kw))
        for i, p in enumerate(prompts):
            kw2 = {"src_embeds": src[i]} if src is not None else {}
            eng.submit(p, max_new_tokens=6, **kw2)
        return eng, eng.run()

    _, ref = run(paging=PagingConfig(enabled=False))
    eng, out = run(paging=PagingConfig(page_size=4, device_pages=5,
                                       hot_tail_pages=1),
                   chunking=ChunkingConfig(chunk_tokens=4, chunk_slots=2))
    assert eng.chunking and eng.stats["chunks"] > 0
    assert eng.stats["preemptions"] > 0
    assert out == ref
    assert eng.page_pool.n_free == eng.page_pool.n_pages


def test_mixed_step_on_mesh_matches_dense_mesh_engine(setup):
    """On a real (2, 4) mesh the chunk-queue engine matches the legacy
    dense engine running on the same mesh (this is also the regression
    guard for the rope-over-sharded-projection SPMD workaround —
    without ``_gather_qkv_for_rope`` the chunk K comes out scaled by
    the data-axis size and every token diverges)."""
    import jax as _jax
    if len(_jax.devices()) < 8:
        pytest.skip("needs 8 forced host devices")
    from repro.launch.mesh import make_mesh_compat
    cfg, params = setup
    mesh = make_mesh_compat((2, 4), ("data", "model"))
    prompts = [np.arange(7) % cfg.vocab_size,
               np.arange(13) % cfg.vocab_size,
               np.arange(16) % cfg.vocab_size]

    def run(**kw):
        eng = Engine(cfg, params, EngineConfig(
            max_batch=3, max_len=64, prefill_buckets=(16,), mesh=mesh,
            **kw))
        for p in prompts:
            eng.submit(p, max_new_tokens=6)
        return eng.run()

    ref = run(paging=PagingConfig(enabled=False))
    out = run(paging=PagingConfig(page_size=4),
              chunking=ChunkingConfig(chunk_tokens=4, chunk_slots=2))
    assert out == ref


def test_paged_prefill_kernel_matches_xla():
    """The scalar-prefetch flash kernel (interpret mode) agrees with the
    XLA gather path on valid rows, including windowed (SWA) masks and
    inert length-0 rows."""
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    N, page, Hkv, D, H, C, T, pps = 9, 4, 2, 16, 4, 3, 8, 6
    kp = jnp.asarray(rng.standard_normal((N, page, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((N, page, Hkv, D)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((C, T, H, D)), jnp.float32)
    pt = np.full((C, pps), N - 1, np.int32)
    pt[0, :4] = [0, 1, 2, 3]
    pt[1, :2] = [4, 5]
    pt = jnp.asarray(pt)
    offset = jnp.asarray([8, 0, 0], jnp.int32)
    length = jnp.asarray([8, 5, 0], jnp.int32)
    for window in (0, 3):
        a = np.asarray(ops.paged_prefill_attention(
            q, kp, vp, pt, offset, length, window=window, impl="xla"))
        b = np.asarray(ops.paged_prefill_attention(
            q, kp, vp, pt, offset, length, window=window,
            impl="interpret"))
        for c, n in enumerate([8, 5, 0]):
            if n:
                np.testing.assert_allclose(a[c, :n], b[c, :n],
                                           atol=2e-6, rtol=2e-6)


def test_mixed_batch_sweep_ttft_improves():
    """The bench's acceptance row: at 2x request oversubscription the
    chunk-queue engine improves mean TTFT over serial dense prefill
    without losing decode throughput (deterministic virtual clock)."""
    from repro.paging.sim import simulate_mixed_batching
    r = simulate_mixed_batching(2.0)
    assert r["ttft_speedup"] > 1.0
    assert r["throughput_speedup"] >= 1.0
    # the gain grows with load: continuous batching is a queueing win
    r4 = simulate_mixed_batching(4.0)
    assert r4["ttft_speedup"] >= r["ttft_speedup"] * 0.95

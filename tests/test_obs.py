"""Telemetry-layer tests: spans, histograms, exporters, invariants.

The observability PR's acceptance surface:

  * spans are well-formed — every ``begin`` is closed by ``end``/
    ``flush_open``, durations are non-negative, nothing outruns the
    shared :class:`VirtualClock`,
  * histogram percentiles track a numpy reference within the
    log-bucketing bound (growth 1.05 ⇒ ≲5% relative error); count/sum/
    min/max are exact,
  * the Chrome-trace export passes ``tools/trace_report.py``'s schema
    validation — including the no-overlap-per-track rule the exporter's
    AMU lane packing exists to satisfy,
  * a disabled tracer is free: no events, no open spans, sid 0,
  * ``CounterView`` keeps every ``collections.Counter`` idiom the old
    ad-hoc stats dicts relied on,
  * property: the SLO report rebuilt *from the trace alone* equals the
    engine's own ``slo_report()``, and the preempt/resume +
    window-acquire/release conservation invariants hold after any run
    — including AMU fault storms.
"""

import importlib.util
import json
import pathlib

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke
from repro.core.amu import AMU, QoS, SimBackend
from repro.models import init_params
from repro.obs import (CounterView, Histogram, MetricsRegistry, NULL_TRACER,
                       Tracer, to_chrome_trace)
from repro.paging import (EventKind, PagePool, PageState, PageTable, Pager,
                          PagingError)
from repro.paging.sim import simulate_paged_serving
from repro.serve import (ChunkingConfig, Engine, EngineConfig, PagingConfig,
                         SchedulerConfig, VirtualClock)
from repro.serve.config import ObsConfig
from repro.serve.workload import WorkloadSpec, generate

# tools/trace_report.py is deliberately standalone (stdlib only, no repro
# import) so CI can run it on artifacts; load it here by path.
_TR_PATH = (pathlib.Path(__file__).resolve().parent.parent
            / "tools" / "trace_report.py")
_spec = importlib.util.spec_from_file_location("trace_report", _TR_PATH)
trace_report = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(trace_report)


# -- metrics ------------------------------------------------------------------

def test_counterview_counter_compat():
    """Every idiom the old ad-hoc Counter/dict stats relied on."""
    reg = MetricsRegistry()
    stats = reg.counters("pager")
    assert stats["missing"] == 0              # Counter: missing reads as 0
    assert "missing" not in stats             # ... without being created
    stats["writeback"] += 1
    stats["writeback"] += 2
    assert stats["writeback"] == 3
    assert stats.get("writeback") == 3
    assert stats.get("nope", 7) == 7
    assert dict(stats) == {"writeback": 3}
    assert stats == {"writeback": 3}          # tests compare against dicts
    # two views of one group share storage; EventKind keys export by name
    other = reg.counters("pager")
    other[EventKind.PREEMPT] += 1
    assert stats[EventKind.PREEMPT] == 1
    snap = reg.snapshot()
    assert snap["counters"]["pager"]["PREEMPT"] == 1
    assert snap["counters"]["pager"]["writeback"] == 3


def test_counters_initial_seeds_without_clobbering():
    reg = MetricsRegistry()
    reg.counters("engine")["steps"] = 5
    view = reg.counters("engine", initial={"steps": 0, "prefills": 0})
    assert view["steps"] == 5                 # existing value kept
    assert view["prefills"] == 0              # new key seeded


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(1, 400),
       spread=st.floats(0.1, 6.0))
def test_histogram_percentiles_vs_numpy(seed, n, spread):
    """Log-bucketed percentiles vs the numpy order-statistic reference.

    The histogram's rank walk selects the bucket holding the
    ``ceil(rank)`` order statistic, i.e. numpy's ``method="higher"``;
    the returned geometric bucket midpoint is then within a factor
    ``sqrt(growth)`` of that sample.  min/max/count/sum are exact.
    """
    rng = np.random.default_rng(seed)
    samples = np.exp(rng.normal(-6.0, spread, n))     # latency-shaped
    h = Histogram("t", growth=1.05)
    for v in samples:
        h.observe(float(v))
    assert h.count == n
    assert h.min == samples.min()
    assert h.max == samples.max()
    assert h.mean == pytest.approx(samples.mean())
    for q in (50.0, 95.0, 99.0):
        ref = float(np.percentile(samples, q, method="higher"))
        got = h.percentile(q)
        assert got == pytest.approx(ref, rel=0.055), (q, ref, got)
    # max is the operative tail stat and must carry no bucketing error
    assert h.percentile(100.0) == samples.max()


def test_histogram_empty_and_floor():
    h = Histogram()
    assert h.p50 == 0.0 and h.max == 0.0 and h.mean == 0.0
    h.observe(0.0)                            # at/below floor: bucket 0
    assert h.p50 == 0.0
    assert h.count == 1


# -- tracer -------------------------------------------------------------------

def test_spans_wellformed_on_virtual_clock():
    clock = VirtualClock()
    tr = Tracer(clock=clock)
    a = tr.begin("p", "t1", "outer")
    clock.advance(1.0)
    b = tr.begin("p", "t2", "inner", {"k": 1})
    clock.advance(0.5)
    tr.end(b, {"extra": True})
    tr.instant("p", "t1", "tick")
    clock.advance(0.25)
    tr.end(a)
    assert not tr.open_spans                  # every begin was closed
    now = clock()
    for ph, pid, tid, name, ts, dur, args in tr.events:
        assert ts >= 0.0
        if ph == "X":
            assert dur >= 0.0
            assert ts + dur <= now + 1e-12    # nothing outruns the clock
    # the inner span merged its end args into its begin args
    inner = next(e for e in tr.events if e[3] == "inner")
    assert inner[6] == {"k": 1, "extra": True}
    # double-end and unknown sids are tolerated no-ops
    tr.end(b)
    tr.end(12345)


def test_flush_open_closes_dangling_spans():
    clock = VirtualClock()
    tr = Tracer(clock=clock)
    tr.begin("p", "t", "dangling")
    clock.advance(2.0)
    doc = to_chrome_trace(tr)
    assert doc["otherData"]["open_spans_flushed"] == 1
    assert not tr.open_spans
    sp = next(e for e in doc["traceEvents"] if e["ph"] == "X")
    assert sp["args"]["incomplete"] is True
    assert sp["dur"] == pytest.approx(2.0 * 1e6)


def test_disabled_tracer_is_free():
    tr = Tracer(enabled=False)
    assert tr.begin("p", "t", "x") == 0       # sid 0: end(0) is a no-op
    tr.end(0)
    tr.instant("p", "t", "i")
    tr.counter("p", "c", 1.0)
    tr.complete("p", "t", "x", 0.0, 1.0)
    assert tr.events == [] and not tr.open_spans
    assert NULL_TRACER.events == []           # the shared instance too
    assert to_chrome_trace(tr)["traceEvents"] == []


# -- exporter schema ----------------------------------------------------------

def test_sim_trace_passes_schema_validation():
    """A real paging-sim run exports valid Chrome-trace JSON: every
    pid/tid named, spans non-overlapping per track (the AMU lane
    packing), per-QoS window-occupancy counter tracks present."""
    tracer = Tracer()
    metrics = MetricsRegistry()
    simulate_paged_serving(2.0, n_seqs=4, pages_per_seq=4, new_tokens=8,
                           tracer=tracer, metrics=metrics)
    doc = to_chrome_trace(tracer, metrics=metrics)
    assert trace_report.validate(doc) == []
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert {"M", "X", "C"} <= phases
    # round-trips through JSON (the --trace-out payload)
    doc2 = json.loads(json.dumps(doc))
    assert trace_report.validate(doc2) == []
    counters = {e["name"] for e in doc["traceEvents"] if e["ph"] == "C"}
    assert "window/LATENCY" in counters
    # AMU transfer spans landed, tagged with the queueing breakdown
    pids, tids = trace_report.track_names(doc["traceEvents"])
    amu_spans = [e for e in doc["traceEvents"]
                 if e["ph"] == "X" and pids[e["pid"]] == "amu"]
    assert amu_spans
    assert all("queued_us" in e["args"] for e in amu_spans)
    assert metrics.histograms                 # per-kind/QoS latency hists


def test_validator_rejects_malformed_docs():
    assert trace_report.validate([]) != []
    assert trace_report.validate({"traceEvents": [{"ph": "Z"}]}) != []
    # overlapping spans on one unnamed track: two problems at least
    bad = {"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 1, "name": "a", "ts": 0.0, "dur": 10.0},
        {"ph": "X", "pid": 1, "tid": 1, "name": "b", "ts": 5.0, "dur": 10.0},
    ]}
    probs = trace_report.validate(bad)
    assert any("overlaps" in p for p in probs)


# -- pager invariants under faults --------------------------------------------

def test_pager_invariants_survive_fault_storm():
    """Window acquire/release must balance even when every transfer
    faults: the ``{kind}_failed`` reap path releases windows and
    reverts ARRIVING pages, so ``check_invariants`` stays green."""
    fail = {"on": True}

    def latency_fn(req):
        if fail["on"]:
            raise RuntimeError("injected far-memory fault")
        return 5e-6

    pool = PagePool(8, 4)
    table = PageTable(pool)
    metrics = MetricsRegistry()
    amu = AMU(backend=SimBackend(base_latency=5e-6, bandwidth=10e9,
                                 latency_fn=latency_fn), max_outstanding=64)
    pager = Pager(pool, table, amu, page_nbytes=1 << 12,
                  tracer=Tracer(), metrics=metrics)
    table.register_parked("s", 4)
    for l in range(4):
        pager.store_far("s", l, None)
    assert pager.prefetch_seq("s") == 4
    pager.advance(1.0)                        # reaps all four failures
    pager.check_invariants()
    assert pager.stats["aload_failed"] == 4
    assert table.logical_pages("s", PageState.PARKED) == [0, 1, 2, 3]
    fail["on"] = False                        # fault clears: retry fills
    pager.prefetch_seq("s")
    pager.advance(1.0)
    pager.check_invariants()
    assert table.resident("s")
    # fault instants were traced on both the AMU and pager tracks
    faults = [e for e in pager.tracer.events
              if e[0] == "i" and e[3] == "fault" and e[1] == "amu"]
    assert len(faults) == 4
    pager_faults = [e for e in pager.tracer.events
                    if e[0] == "i" and e[3] == "fault" and e[1] == "pager"]
    assert len(pager_faults) == 4


# -- engine: trace-derived SLO report == the engine's own ---------------------

@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("phi4-mini-3.8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run_traced(cfg, params, seed, device_pages):
    ec = EngineConfig(
        max_batch=3, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(device_pages=device_pages, page_size=8),
        chunking=ChunkingConfig(chunk_tokens=8),
        scheduler=SchedulerConfig(policy="slo", step_dt=2e-3),
        obs=ObsConfig(trace=True))
    eng = Engine(cfg, params, ec)
    spec = WorkloadSpec(rate=2000.0, prompt_median=8.0, prompt_sigma=0.5,
                        max_prompt=16, min_output=2, max_output=8,
                        interactive_frac=0.5, ttft_slo=20e-3, tpot_slo=5e-3)
    rng = np.random.default_rng(seed)
    for wr in generate(8, spec, seed=seed):
        prompt = rng.integers(0, cfg.vocab_size,
                              wr.prompt_len).astype(np.int32)
        eng.submit(prompt, max_new_tokens=wr.output_len, tier=wr.tier,
                   ttft_slo=wr.ttft_slo, tpot_slo=wr.tpot_slo,
                   arrival_t=wr.arrival_t)
    eng.run()
    return eng


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 2**16), device_pages=st.sampled_from([8, 10]))
def test_property_trace_reproduces_slo_report(setup, seed, device_pages):
    """The whole point of the telemetry layer: the trace is a complete
    record.  ``slo_report()`` recomputed from the exported JSON alone
    (by the standalone trace_report tool) must match the engine's —
    attainment and goodput exactly, TTFT percentiles to float noise —
    and the conservation invariants must hold on the drained engine."""
    cfg, params = setup
    eng = _run_traced(cfg, params, seed, device_pages)
    eng.check_invariants()                    # preempt/resume + windows
    doc = json.loads(json.dumps(eng.export_trace()))
    assert trace_report.validate(doc) == []
    assert doc["otherData"]["open_spans_flushed"] == 0
    derived = trace_report.report_from_trace(doc)
    own = eng.slo_report()
    assert derived["elapsed"] == pytest.approx(own["elapsed"])
    for tier in ("interactive", "batch"):
        d, o = derived[tier], own[tier]
        assert d["n"] == o["n"]
        assert d["attained"] == o["attained"]
        assert d["attainment"] == pytest.approx(o["attainment"])
        assert d["good_tokens"] == o["good_tokens"]
        assert d["goodput"] == pytest.approx(o["goodput"])
        for q in ("ttft_p50", "ttft_p95", "ttft_p99"):
            assert d[q] == pytest.approx(o[q], abs=1e-9)
    # preemption storms leave their pager/residency signature on the trace
    if eng.stats["preemptions"]:
        counts = trace_report.lifecycle_counts(doc)
        assert counts.get("pager/PARKED", 0) > 0
        assert counts.get("requests/parked", 0) == eng.stats["preemptions"]


# -- speculation accounting: trace-derived == the engine's own ----------------

def _run_spec_traced(cfg, params, seed, kind):
    from repro.serve.config import SpeculationConfig
    from tests.test_spec_decode import _proposer_factory

    rng = np.random.default_rng(seed)
    requests = [(rng.integers(0, cfg.vocab_size,
                              int(rng.integers(4, 13))).astype(np.int32),
                 int(rng.integers(4, 11))) for _ in range(4)]
    dense = Engine(cfg, params, EngineConfig(
        max_batch=3, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(enabled=False)))
    for p, n in requests:
        dense.submit(p, max_new_tokens=n)
    ref = dense.run()
    eng = Engine(cfg, params, EngineConfig(
        max_batch=3, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(device_pages=24, page_size=4),
        speculation=SpeculationConfig(
            speculate_k=3,
            proposer_factory=_proposer_factory(kind, ref, requests,
                                               cfg.vocab_size)),
        obs=ObsConfig(trace=True)))
    for p, n in requests:
        eng.submit(p, max_new_tokens=n)
    out = eng.run()
    assert out == ref
    return eng


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 2**16),
       kind=st.sampled_from(["oracle", "wrong", "first"]))
def test_property_trace_reproduces_spec_accounting(setup, seed, kind):
    """The speculation accounting recomputed from the exported trace
    alone — cumulative ``spec_*`` counter tracks (which the exporter
    dedups) plus per-step ``verify`` instants — must equal the engine's
    own stats across accept-all, reject-all, and partial proposers."""
    cfg, params = setup
    eng = _run_spec_traced(cfg, params, seed, kind)
    eng.check_invariants()
    doc = json.loads(json.dumps(eng.export_trace()))
    assert trace_report.validate(doc) == []
    sp = trace_report.speculation_report(doc)
    assert sp["consistent"]
    assert sp["verify_steps"] == eng.stats["spec_steps"]
    assert sp["drafted"] == eng.stats["drafted"]
    assert sp["accepted"] == eng.stats["accepted"]
    assert sp["rejected"] == eng.stats["rejected"]
    if eng.stats["spec_steps"]:
        assert sp["mean_accepted_k"] == pytest.approx(
            eng.stats["accepted"] / eng.stats["spec_steps"])


def test_validator_flags_broken_spec_tracks():
    """Cumulative spec counters must be monotone and sum-consistent."""
    meta = [{"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
             "args": {"name": "engine"}},
            {"ph": "M", "pid": 1, "tid": 0, "name": "thread_name",
             "args": {"name": "spec"}}]

    def c(name, value, ts):
        return {"ph": "C", "pid": 1, "tid": 0, "name": name, "ts": ts,
                "args": {"value": value}}

    backwards = {"traceEvents": meta + [c("spec_drafted", 5, 0.0),
                                        c("spec_drafted", 3, 1.0)]}
    assert any("went backwards" in p
               for p in trace_report.validate(backwards))
    inconsistent = {"traceEvents": meta + [c("spec_drafted", 5, 0.0),
                                           c("spec_accepted", 2, 0.0),
                                           c("spec_rejected", 2, 0.0)]}
    assert any("accounting broken" in p
               for p in trace_report.validate(inconsistent))


def test_spec_report_empty_without_speculation(setup):
    cfg, params = setup
    eng = _run_traced(cfg, params, 1, 10)
    doc = eng.export_trace()
    assert trace_report.speculation_report(doc) == {}


def test_engine_invariant_check_detects_imbalance(setup):
    cfg, params = setup
    eng = _run_traced(cfg, params, 0, 10)
    eng.check_invariants()
    eng.stats["preemptions"] += 1             # corrupt the books
    with pytest.raises(PagingError, match="imbalance"):
        eng.check_invariants()


def test_engine_tracing_off_by_default(setup):
    """Default EngineConfig: tracer disabled, stats still registry-backed
    (one shared metrics export), trace export empty but valid."""
    cfg, params = setup
    ec = EngineConfig(max_batch=2, max_len=64, prefill_buckets=(16,),
                      chunking=ChunkingConfig(chunk_tokens=8))
    eng = Engine(cfg, params, ec)
    assert not eng.tracer.enabled
    assert not eng.config.obs.tracing
    rng = np.random.default_rng(0)
    for _ in range(3):
        eng.submit(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                   max_new_tokens=3)
    eng.run()
    eng.check_invariants()
    assert eng.tracer.events == []            # zero allocations kept
    doc = eng.export_trace()
    assert trace_report.validate(doc) == []
    snap = eng.export_metrics()
    assert snap["counters"]["engine"]["admitted"] == 3
    assert "events" in snap["counters"]       # EventLoop shares the registry
    assert eng.stats["admitted"] == 3         # CounterView reads unchanged

"""Per-kernel correctness sweeps: Pallas interpret mode vs jnp oracle.

Every kernel is swept over shapes and dtypes; tolerances are relative
(f32 accumulation order differs between chunked kernels and sequential
oracles)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.amu_matmul import amu_matmul
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba2 import ssd
from repro.kernels.moe_gather import gather_blocks, gather_rows
from repro.kernels.rwkv6 import wkv6

rng = np.random.default_rng(42)


def _rel_err(out, ref_val):
    out = np.asarray(out, np.float32)
    ref_val = np.asarray(ref_val, np.float32)
    denom = max(1e-6, float(np.abs(ref_val).max()))
    return float(np.abs(out - ref_val).max()) / denom


def _rand(shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


# ---------------------------------------------------------------------------
# amu_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N,bm,bk,bn", [
    (128, 128, 128, 128, 128, 128),       # single tile, n_k == 1
    (256, 256, 256, 128, 128, 128),       # n_k == 2 (both slots, no refill)
    (256, 512, 384, 128, 128, 128),       # deep pipeline, refills
    (384, 768, 128, 128, 256, 128),       # non-square blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_amu_matmul(M, K, N, bm, bk, bn, dtype):
    x, w = _rand((M, K), dtype), _rand((K, N), dtype)
    out = amu_matmul(x, w, bm=bm, bk=bk, bn=bn)
    tol = 5e-6 if dtype == jnp.float32 else 2e-2
    assert _rel_err(out, ref.matmul_ref(x, w)) < tol


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,Hkv,Sq,Skv,D,causal,window", [
    (2, 4, 2, 128, 128, 64, True, 0),
    (1, 4, 4, 256, 256, 32, True, 0),
    (2, 4, 2, 128, 128, 64, True, 32),     # SWA
    (1, 2, 2, 128, 256, 64, False, 0),     # cross (non-causal, Skv != Sq)
    (1, 8, 2, 192, 192, 128, True, 48),    # GQA 4:1 + SWA + full lane D
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, Hkv, Sq, Skv, D, causal, window, dtype):
    q = _rand((B, Sq, H, D), dtype)
    k = _rand((B, Skv, Hkv, D), dtype)
    v = _rand((B, Skv, Hkv, D), dtype)
    qT, kT, vT = (a.transpose(0, 2, 1, 3) for a in (q, k, v))
    out = flash_attention(qT, kT, vT, causal=causal, window=window,
                          bq=64, bkv=64).transpose(0, 2, 1, 3)
    expected = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 5e-6 if dtype == jnp.float32 else 3e-2
    assert _rel_err(out, expected) < tol


def test_flash_matches_model_chunked_attention():
    """Both execution paths (kernel / XLA scan) agree with each other."""
    from repro.models.attention import chunked_attention
    q = _rand((2, 128, 4, 64))
    k = _rand((2, 128, 2, 64))
    v = _rand((2, 128, 2, 64))
    a = chunked_attention(q, k, v, causal=True, chunk=32)
    b = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True,
                        bq=64, bkv=64).transpose(0, 2, 1, 3)
    assert _rel_err(b, a) < 5e-6


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,Hkv,S,D,valid,bkv", [
    (2, 8, 2, 512, 64, 512, 128),
    (1, 4, 4, 256, 128, 200, 128),
    (2, 16, 4, 256, 64, 33, 64),           # short valid prefix
    (1, 8, 8, 1024, 64, 1000, 256),        # MHA long cache
])
def test_decode_attention(B, H, Hkv, S, D, valid, bkv):
    q = _rand((B, H, D))
    k = _rand((B, S, Hkv, D))
    v = _rand((B, S, Hkv, D))
    out = decode_attention(q, k, v, valid_len=valid, bkv=bkv)
    assert _rel_err(out, ref.decode_attention_ref(q, k, v, valid)) < 5e-6


# ---------------------------------------------------------------------------
# wkv6 / ssd
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,T,H,K,chunk", [
    (2, 128, 2, 32, 32),
    (1, 96, 4, 64, 32),
    (2, 64, 2, 128, 16),
    (1, 256, 1, 64, 64),
])
def test_wkv6(B, T, H, K, chunk):
    r, k, v = _rand((B, T, H, K)), _rand((B, T, H, K)), _rand((B, T, H, K))
    w = -jnp.exp(_rand((B, T, H, K)) - 2)
    u = _rand((H, K)) * 0.1
    out = wkv6(r, k, v, w, u, chunk=chunk)
    assert _rel_err(out, ref.wkv6_ref(r, k, v, w, u)) < 1e-4


@pytest.mark.parametrize("B,T,H,P,N,chunk", [
    (2, 128, 2, 32, 16, 32),
    (1, 96, 4, 64, 32, 48),
    (1, 256, 2, 64, 64, 64),
])
def test_ssd(B, T, H, P, N, chunk):
    x = _rand((B, T, H, P))
    dt = jax.nn.softplus(_rand((B, T, H)))
    A = jnp.linspace(0.5, 4.0, H)
    D = _rand((H,))
    Bm, Cm = _rand((B, T, N)), _rand((B, T, N))
    out = ssd(x, dt, A, Bm, Cm, D, chunk=chunk)
    assert _rel_err(out, ref.ssd_ref(x, dt, A, Bm, Cm, D)) < 1e-4


def test_kernels_match_model_chunked_forms():
    """Pallas kernels agree with the models' XLA chunked forms (the
    exact functions the dry-run lowers)."""
    from repro.models.ssm import ssd_chunked, wkv6_chunked
    B, T, H, K = 1, 128, 2, 32
    r, k, v = _rand((B, T, H, K)), _rand((B, T, H, K)), _rand((B, T, H, K))
    w = -jnp.exp(_rand((B, T, H, K)) - 2)
    u = _rand((H, K)) * 0.1
    assert _rel_err(wkv6(r, k, v, w, u, chunk=32),
                    wkv6_chunked(r, k, v, w, u, chunk=32)) < 1e-5

    P = N = 32
    x = _rand((B, T, H, P))
    dt = jax.nn.softplus(_rand((B, T, H)))
    A = jnp.linspace(0.5, 4.0, H)
    D = _rand((H,))
    Bm, Cm = _rand((B, T, N)), _rand((B, T, N))
    assert _rel_err(ssd(x, dt, A, Bm, Cm, D, chunk=32),
                    ssd_chunked(x, dt, A, Bm, Cm, D, chunk=32)) < 1e-5


# ---------------------------------------------------------------------------
# gather
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,d,M,rpb", [
    (64, 128, 32, 8),
    (128, 256, 64, 16),
    (32, 128, 8, 8),
])
def test_gather_rows(N, d, M, rpb):
    src = _rand((N, d))
    idx = jnp.asarray(rng.integers(0, N, M), jnp.int32)
    out = gather_rows(src, idx, rows_per_block=rpb)
    assert _rel_err(out, ref.gather_rows_ref(src, idx)) == 0.0


def test_gather_blocks():
    src = _rand((64, 128))
    bidx = jnp.asarray(rng.integers(0, 8, 6), jnp.int32)
    out = gather_blocks(src, bidx, block_rows=8)
    expected = jnp.concatenate([src[int(i) * 8:(int(i) + 1) * 8]
                                for i in bidx], axis=0)
    assert _rel_err(out, expected) == 0.0

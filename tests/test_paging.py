"""repro.paging tests: page-table invariants, pager overlap under
simulated latency, QoS windows, fault recovery, watermark admission,
oversubscribed engine end-to-end with forced preemption."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.amu import AMU, AMUError, QoS, SimBackend
from repro.paging import (EventKind, EventLoop, PagePool, PageState,
                          PageTable, Pager, PagingError, WatermarkPolicy,
                          pages_for)
from repro.paging.sim import simulate_paged_serving
from repro.serve.config import EngineConfig, PagingConfig
from repro.serve.kv_cache import (SlotPool, join_kv_pages, split_kv_pages)


def make_pager(n_pages=8, page_size=4, base_latency=5e-6, **kw):
    pool = PagePool(n_pages, page_size)
    table = PageTable(pool)
    amu = AMU(backend=SimBackend(base_latency=base_latency, bandwidth=10e9),
              max_outstanding=64)
    return pool, table, Pager(pool, table, amu, page_nbytes=1 << 12, **kw)


# ---------------------------------------------------------------------------
# page table / pool invariants
# ---------------------------------------------------------------------------

def test_page_table_alloc_evict_refault_invariants():
    pool, table, pager = make_pager(n_pages=6, page_size=4)
    table.register("a")
    assert table.ensure_capacity("a", 9) == [0, 1, 2]     # ceil(9/4)
    assert pool.n_free == 3
    assert table.resident("a")
    for l in range(3):
        pool.mark_dirty(table.entry("a", l).phys)

    # evict all three -> parked, frames back in the pool
    assert pager.evict_lru(3) == 3
    assert pool.n_free == 6
    assert table.logical_pages("a", PageState.PARKED) == [0, 1, 2]
    assert not table.resident("a")

    # refault: prefetch reserves a frame (ARRIVING), arrival sets the bit
    assert pager.prefetch("a", 1)
    assert table.entry("a", 1).state is PageState.ARRIVING
    assert pool.n_free == 5
    assert not pager.prefetch("a", 1)          # idempotent while in flight
    pager.advance(1e-3)
    assert table.entry("a", 1).state is PageState.RESIDENT

    # drop releases everything, even pinned frames
    pager.wait_seq("a")
    pool.pin(table.entry("a", 0).phys)
    table.drop("a")
    assert pool.n_free == 6
    with pytest.raises(PagingError):
        table.entry("a", 0)


def test_pool_exhaustion_double_free_and_pinning():
    pool = PagePool(2, page_size=4)
    a = pool.alloc("s", 0)
    pool.alloc("s", 1)
    with pytest.raises(PagingError):
        pool.alloc("s", 2)                     # exhausted
    pool.pin(a)
    with pytest.raises(PagingError):
        pool.free(a)                           # pinned frames cannot free
    pool.unpin(a)
    pool.free(a)
    with pytest.raises(PagingError):
        pool.free(a)                           # double free
    assert pool.lru_victims(5) == [1]          # only the unpinned live frame


def test_pages_for():
    assert pages_for(0, 4) == 0
    assert pages_for(1, 4) == 1
    assert pages_for(4, 4) == 1
    assert pages_for(5, 4) == 2


def test_slotpool_heap_and_double_release():
    p = SlotPool(3)
    slots = [p.alloc() for _ in range(3)]
    assert slots == [0, 1, 2] and p.alloc() is None
    p.release(1)
    assert p.alloc() == 1
    p.release(2)
    p.release(0)
    assert p.alloc() == 0                      # lowest-first, heap order
    with pytest.raises(AMUError):
        p.release(2)                           # double release
    with pytest.raises(AMUError):
        p.release(99)                          # out of range


# ---------------------------------------------------------------------------
# pager: overlap, QoS windows
# ---------------------------------------------------------------------------

def test_pager_prefetch_hides_decode_tick():
    """A page prefetched at tick start must be resident by tick end with
    zero extra waiting: the fetch latency hides behind >= 1 decode tick."""
    pool, table, pager = make_pager(base_latency=5e-6)
    table.register_parked("s", 2)
    pager.store_far("s", 0, None)
    pager.store_far("s", 1, None)

    tick = 50e-6                               # one decode step >> fetch
    pager.prefetch_seq("s")
    t_before = pager.amu.backend.now
    pager.advance(tick)                        # the decode step happens
    assert table.resident("s")                 # landed inside the tick
    t_after = pager.amu.backend.now
    assert t_after - t_before == pytest.approx(tick)   # no extra stall
    # blocking the same fetch instead would have cost extra time
    pool2, table2, pager2 = make_pager(base_latency=5e-6)
    table2.register_parked("s", 2)
    pager2.store_far("s", 0, None)
    pager2.store_far("s", 1, None)
    t0 = pager2.amu.backend.now
    pager2.wait_seq("s")
    pager2.advance(tick)
    assert pager2.amu.backend.now - t0 > tick  # fetch serialized with tick


def test_pager_qos_windows_limit_outstanding():
    pool, table, pager = make_pager(n_pages=16, page_size=1, bulk_window=2,
                                    latency_window=4)
    table.register("s")
    table.ensure_capacity("s", 8)
    for l in range(8):
        pool.mark_dirty(table.entry("s", l).phys)
    for l in range(8):                         # 8 dirty evictions, window 2
        pager.evict("s", l)
    assert pager.windows.in_flight[QoS.BULK] <= 2
    assert pager.stats["window_queued"] >= 6
    for _ in range(6):                         # each poll completes one
        pager.advance(1.0)                     # window batch, pumps next
    assert pager.windows.in_flight[QoS.BULK] == 0
    assert pager.windows.in_flight[QoS.LATENCY] == 0


def make_faulty_pager(n_pages=8, page_size=4, **kw):
    """Pager whose SimBackend raises on issue while ``fail['on']``."""
    fail = {"on": True}

    def latency_fn(req):
        if fail["on"]:
            raise RuntimeError("injected far-memory fault")
        return 5e-6

    pool = PagePool(n_pages, page_size)
    table = PageTable(pool)
    amu = AMU(backend=SimBackend(base_latency=5e-6, bandwidth=10e9,
                                 latency_fn=latency_fn),
              max_outstanding=64)
    return fail, pool, table, Pager(pool, table, amu, page_nbytes=1 << 12,
                                    **kw)


def test_pager_failed_aload_releases_qos_window():
    """A failed aload must not permanently occupy its LATENCY window
    slot: the window is released, the reserved frame freed, the page
    reverted to PARKED, and a retry succeeds at full window width."""
    fail, pool, table, pager = make_faulty_pager(latency_window=2,
                                                 bulk_window=2)
    table.register_parked("s", 2)
    pager.store_far("s", 0, None)
    pager.store_far("s", 1, None)
    assert pager.prefetch("s", 0) and pager.prefetch("s", 1)
    assert pager.windows.in_flight[QoS.LATENCY] == 2
    pager.advance(1.0)                       # poll reaps both failures
    assert pager.windows.in_flight[QoS.LATENCY] == 0
    assert pager.stats["aload_failed"] == 2
    assert table.logical_pages("s", PageState.PARKED) == [0, 1]
    assert pool.n_free == pool.n_pages       # reserved frames returned
    fail["on"] = False                       # fault clears: retry works
    assert pager.prefetch_seq("s") == 2      # full window still available
    pager.advance(1.0)
    assert table.resident("s")


def test_pager_failed_astore_releases_qos_window():
    fail, pool, table, pager = make_faulty_pager(latency_window=4,
                                                 bulk_window=2)
    table.register("s")
    table.ensure_capacity("s", 8)            # 2 pages resident
    for l in range(2):
        pool.mark_dirty(table.entry("s", l).phys)
        pager.evict("s", l)                  # dirty: BULK astore, fails
    pager.advance(1.0)
    assert pager.windows.in_flight[QoS.BULK] == 0
    assert pager.stats["astore_failed"] == 2
    # the far dict already holds the payload, so the pages stay parked
    # and remain fetchable once the fault clears
    fail["on"] = False
    pager.prefetch_seq("s")
    pager.advance(1.0)
    assert table.resident("s")


def test_pager_failed_demand_fetch_raises_but_releases_window():
    fail, pool, table, pager = make_faulty_pager(latency_window=2)
    table.register_parked("s", 1)
    pager.store_far("s", 0, None)
    with pytest.raises(PagingError):
        pager.wait_page("s", 0)
    assert pager.windows.in_flight[QoS.LATENCY] == 0
    assert table.entry("s", 0).state is PageState.PARKED
    fail["on"] = False
    pager.wait_page("s", 0)                  # retry succeeds
    assert table.resident("s")


def test_pager_drain_of_failed_request_is_not_an_arrival():
    """Draining a full QoS window must reap a FAILED request (window
    released, page back to PARKED) — never count it as a landed page."""
    fail, pool, table, pager = make_faulty_pager(latency_window=1)
    table.register_parked("s", 2)
    pager.store_far("s", 0, None)
    pager.store_far("s", 1, None)
    assert pager.prefetch("s", 1)            # fails at issue, holds window
    assert pager.prefetch("s", 0)            # queued behind the window
    fail["on"] = False                       # fault clears for the retry
    pager.wait_page("s", 0)                  # _force_issue drains the fail
    assert table.entry("s", 0).state is PageState.RESIDENT
    assert table.entry("s", 1).state is PageState.PARKED   # reverted
    assert pager.stats["aload_failed"] == 1
    assert pager.stats["arrived"] == 1       # only the real arrival
    assert pager.windows.in_flight[QoS.LATENCY] == 0


def test_pager_clean_eviction_skips_astore():
    pool, table, pager = make_pager()
    table.register_parked("s", 2)
    pager.store_far("s", 0, None)
    pager.store_far("s", 1, None)
    pager.wait_seq("s")                        # fetched pages are clean
    astores_before = pager.amu.stats["astore"]
    assert pager.evict_lru(2) == 2
    assert pager.amu.stats["astore"] == astores_before   # no writeback
    assert pager.stats["clean_evict"] == 2


# ---------------------------------------------------------------------------
# exact-page-boundary regression: seq length an integer multiple of page_size
# ---------------------------------------------------------------------------

def _single_cache(L=2, S=16, Hkv=2, D=4, fill=None):
    from repro.models.model import Cache
    rng = np.random.default_rng(0)
    k = rng.standard_normal((L, 1, S, Hkv, D)).astype(np.float32) \
        if fill is None else np.full((L, 1, S, Hkv, D), fill, np.float32)
    v = rng.standard_normal((L, 1, S, Hkv, D)).astype(np.float32)
    return Cache(kv={"k": jnp.asarray(k), "v": jnp.asarray(v)}, ssm=(),
                 cross={}, pos=np.full((1,), S, np.int32)), k, v


@pytest.mark.parametrize("n_tokens", [8, 16])     # exact multiples of 8
def test_split_join_exact_page_boundary(n_tokens):
    """n_tokens == k * page_size must produce exactly k full pages (no
    empty trailing page, no dropped residue) and round-trip bit-exact."""
    single, k, v = _single_cache(S=16)
    residue, pages = split_kv_pages(single, 8, n_tokens)
    assert len(pages) == n_tokens // 8
    assert all(pg["k"].shape[2] == 8 for pg in pages)
    joined = join_kv_pages(residue, pages, 16)
    np.testing.assert_array_equal(np.asarray(joined.kv["k"])[:, :, :n_tokens],
                                  k[:, :, :n_tokens])
    np.testing.assert_array_equal(np.asarray(joined.kv["k"])[:, :, n_tokens:],
                                  0)


def test_split_one_past_boundary_adds_partial_page():
    single, k, v = _single_cache(S=16)
    residue, pages = split_kv_pages(single, 8, 9)
    assert [pg["k"].shape[2] for pg in pages] == [8, 1]
    joined = join_kv_pages(residue, pages, 16)
    np.testing.assert_array_equal(np.asarray(joined.kv["k"])[:, :, :9],
                                  k[:, :, :9])


def test_paged_gather_exact_boundary_length():
    """A sequence whose valid length fills its pages exactly must match
    the dense kernel (the last page has no masked residue)."""
    from repro.kernels.decode_attention import (decode_attention,
                                                paged_decode_attention)
    rng = np.random.default_rng(1)
    B, H, Hkv, D, page, per_seq = 2, 4, 2, 32, 16, 2
    N = B * per_seq
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((N, page, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((N, page, Hkv, D)), jnp.float32)
    pt = np.arange(N, dtype=np.int32).reshape(B, per_seq)
    lengths = np.array([32, 16], np.int32)     # == 2 pages / == 1 page
    out = paged_decode_attention(q, kp, vp, jnp.asarray(pt),
                                 jnp.asarray(lengths))
    kp_np, vp_np = np.asarray(kp), np.asarray(vp)
    for b in range(B):
        kd = np.concatenate([kp_np[pt[b, j]] for j in range(per_seq)])[None]
        vd = np.concatenate([vp_np[pt[b, j]] for j in range(per_seq)])[None]
        ref = decode_attention(q[b:b + 1], jnp.asarray(kd), jnp.asarray(vd),
                               valid_len=int(lengths[b]), bkv=16)
        np.testing.assert_allclose(np.asarray(out[b:b + 1]), np.asarray(ref),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# events / watermarks
# ---------------------------------------------------------------------------

def test_watermark_policy():
    pool = PagePool(8, 4)
    wp = WatermarkPolicy(low=2, critical=1)
    assert wp.can_admit(pool, 6)
    assert not wp.can_admit(pool, 7)           # would dip under low
    assert wp.deficit(pool, 7) == 1
    for i in range(7):
        pool.alloc("s", i)
    assert wp.should_preempt(pool)             # free (1) <= critical


def test_event_loop_dispatch_and_livelock_guard():
    loop = EventLoop()
    seen = []
    loop.on(EventKind.PAGE_ARRIVED, lambda ev: seen.append(ev.payload))
    loop.post(EventKind.PAGE_ARRIVED, ("s", 3))
    loop.tick()
    assert seen == [("s", 3)] and loop.ticks == 1
    loop.on(EventKind.ADMIT, lambda ev: loop.post(EventKind.ADMIT))
    loop.post(EventKind.ADMIT)
    with pytest.raises(PagingError):
        loop.drain(max_events=50)              # self-posting handler


# ---------------------------------------------------------------------------
# policy sim: the paper's claim at the serving level
# ---------------------------------------------------------------------------

def test_paged_sim_beats_blocking_at_2x_oversubscription():
    r = simulate_paged_serving(2.0)
    assert r["speedup"] >= 1.5                 # the acceptance number
    assert r["hit_rate"] >= 0.8                # prefetch lands in time
    assert r["bulk_writebacks"] > 0            # dirty tails pay BULK astore


def test_paged_sim_determinism():
    a = simulate_paged_serving(2.0)
    b = simulate_paged_serving(2.0)
    assert a == b


# ---------------------------------------------------------------------------
# engine end-to-end: oversubscription + forced preemption
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_setup():
    from repro.configs import get_smoke
    from repro.models import init_params
    cfg = get_smoke("phi4-mini-3.8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_oversubscribed_preempts_and_matches_solo(dense_setup):
    """3 sequences x 3 pages of demand on a 5-page pool: the engine must
    preempt, park cold pages, resume hot-tail-first, and the preempted
    request's tokens must equal a solo run (bit-exact page round-trip)."""
    from repro.serve.engine import Engine
    cfg, params = dense_setup
    prompt = np.arange(7) % cfg.vocab_size

    solo = Engine(cfg, params, EngineConfig(
        max_batch=1, max_len=64, prefill_buckets=(16,)))
    solo.submit(prompt, max_new_tokens=12)
    ref = solo.run()[0]

    eng = Engine(cfg, params, EngineConfig(
        max_batch=3, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(page_size=8, device_pages=5)))
    rid = eng.submit(prompt, max_new_tokens=12)
    eng.submit(np.arange(5) % cfg.vocab_size, max_new_tokens=12)
    eng.submit(np.arange(9) % cfg.vocab_size, max_new_tokens=12)
    out = eng.run()

    assert len(out) == 3 and all(len(v) == 12 for v in out.values())
    assert eng.stats["preemptions"] > 0        # pool pressure forced a park
    assert eng.stats["resumes"] == eng.stats["preemptions"]
    assert out[rid] == ref                     # exact resume, no re-prefill
    # page accounting drained cleanly
    assert eng.page_pool.n_free == eng.page_pool.n_pages
    assert eng.pager.stats["writeback"] > 0    # cold pages took BULK astore
    assert eng.pager.stats["arrived"] > 0      # resume came via LATENCY aload
    assert eng.events.history[EventKind.PREEMPT] == eng.stats["preemptions"]


def test_engine_admits_more_demand_than_pool(dense_setup):
    """Aggregate KV demand is ~2x the device pool; every request must
    still complete (the oversubscribed-serving acceptance criterion)."""
    from repro.serve.engine import Engine
    cfg, params = dense_setup
    # per request: ceil((5 + 11) / 4) = 4 pages; 6 requests = 24 pages
    # of total demand on a 12-page pool (2x oversubscription).
    eng = Engine(cfg, params, EngineConfig(
        max_batch=4, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(page_size=4, device_pages=12)))
    for i in range(6):
        eng.submit(np.arange(5 + i) % cfg.vocab_size, max_new_tokens=11)
    out = eng.run()
    assert len(out) == 6 and all(len(v) == 11 for v in out.values())
    total_demand = sum(pages_for(5 + i + 11, 4) for i in range(6))
    assert total_demand > eng.page_pool.n_pages
    assert eng.page_pool.n_free == eng.page_pool.n_pages


def test_engine_rejects_impossible_request(dense_setup):
    from repro.serve.engine import Engine
    cfg, params = dense_setup
    eng = Engine(cfg, params, EngineConfig(
        max_batch=2, max_len=64,
        paging=PagingConfig(page_size=8, device_pages=2)))
    with pytest.raises(PagingError):
        eng.submit(np.arange(30), max_new_tokens=30)   # needs > pool


def test_engine_watermark_blocks_admission(dense_setup):
    """With a high low-watermark the second request must wait for the
    first to finish (admission by free pages, not free slots)."""
    from repro.serve.engine import Engine
    cfg, params = dense_setup
    eng = Engine(cfg, params, EngineConfig(
        max_batch=2, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(page_size=8, device_pages=4,
                            watermark=WatermarkPolicy(low=3))))
    eng.submit(np.arange(6), max_new_tokens=4)         # 1..2 pages
    eng.submit(np.arange(6), max_new_tokens=4)
    out = eng.run()
    assert len(out) == 2 and all(len(v) == 4 for v in out.values())
    # admitting the second (1 page) while the first held one would leave
    # free < low, so the runs serialize: 3 decode steps each, no sharing
    assert eng.stats["steps"] >= 2 * 3                 # fully serialized
    # a prompt whose admission can never clear the watermark is rejected
    # up front instead of being silently dropped by run()
    with pytest.raises(PagingError):
        eng.submit(np.arange(10), max_new_tokens=4)    # 2 pages + low 3 > 4


def test_engine_preempt_resume_at_exact_page_boundary(dense_setup):
    """Prompts and decode lengths sized so sequences sit exactly on page
    boundaries when parked: the paged run must still match a dense
    (non-paged) run token for token."""
    from repro.serve.engine import Engine
    cfg, params = dense_setup
    # page_size 8: prompt 8 = 1 full page, prompt 16 = 2 full pages;
    # 8 new tokens keep every park/resume point page-aligned.
    prompts = [np.arange(8) % cfg.vocab_size,
               np.arange(16) % cfg.vocab_size,
               np.arange(8) % cfg.vocab_size]

    dense = Engine(cfg, params, EngineConfig(
        max_batch=3, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(enabled=False)))
    for p in prompts:
        dense.submit(p, max_new_tokens=8)
    ref = dense.run()

    eng = Engine(cfg, params, EngineConfig(
        max_batch=3, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(page_size=8, device_pages=5)))
    for p in prompts:
        eng.submit(p, max_new_tokens=8)
    out = eng.run()
    assert eng.stats["preemptions"] > 0
    assert out == ref
    assert eng.page_pool.n_free == eng.page_pool.n_pages


def test_engine_rejects_page_size_not_dividing_capacity(dense_setup):
    from repro.serve.engine import Engine
    cfg, params = dense_setup
    with pytest.raises(PagingError):
        Engine(cfg, params, EngineConfig(
            max_batch=2, max_len=64,
            paging=PagingConfig(page_size=24)))


def test_engine_paged_offload_matches_dense_offload(dense_setup):
    """Finished-sequence offload parks pages into THE far tier through
    the pager (single FarMemoryTier backend — no sequence-granularity
    side store); fetch_finished must reassemble exactly the KV a dense
    (non-paged) engine ends up with in its cache slot."""
    from repro.serve.engine import Engine
    from repro.serve.kv_cache import extract_slot
    cfg, params = dense_setup
    prompt = np.arange(7) % cfg.vocab_size

    dense = Engine(cfg, params, EngineConfig(
        max_batch=1, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(enabled=False)))
    dense.submit(prompt, max_new_tokens=4)
    dense.run()
    dense_tree = extract_slot(dense.cache, 0, 1)

    eng = Engine(cfg, params, EngineConfig(
        max_batch=1, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(page_size=8, offload_finished=True)))
    rid = eng.submit(prompt, max_new_tokens=4)
    eng.run()
    # the park traffic rode BULK astores on the shared AMU
    assert eng.far_tier.amu.stats["astore"] > 0
    assert (rid, "aux") in eng.far_tier
    paged_tree = eng.fetch_finished(rid)
    # fetch is consuming: a second reassembly has nothing to read
    assert (rid, "aux") not in eng.far_tier
    dk = np.asarray(dense_tree.kv["k"])
    pk = np.asarray(paged_tree.kv["k"])
    # valid KV covers the prompt plus all but the last generated token
    # (the final token is emitted without a further decode write)
    tokens = 7 + 4 - 1
    np.testing.assert_array_equal(pk[:, :, :tokens], dk[:, :, :tokens])
    np.testing.assert_array_equal(
        np.asarray(paged_tree.pos), np.asarray(dense_tree.pos))


def test_paged_decode_attention_matches_dense():
    import jax.numpy as jnp
    from repro.kernels.decode_attention import (decode_attention,
                                                paged_decode_attention)
    rng = np.random.default_rng(0)
    B, H, Hkv, D, page, per_seq = 3, 8, 2, 64, 16, 4
    N = B * per_seq + 2                        # spare frames stay unused
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((N, page, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((N, page, Hkv, D)), jnp.float32)
    pt = rng.permutation(N)[:B * per_seq].reshape(B, per_seq).astype(np.int32)
    lengths = np.array([37, 64, 5], np.int32)  # mixed depths in one call
    out = paged_decode_attention(q, kp, vp, jnp.asarray(pt),
                                 jnp.asarray(lengths))
    kp_np, vp_np = np.asarray(kp), np.asarray(vp)
    for b in range(B):
        kd = np.concatenate([kp_np[pt[b, j]] for j in range(per_seq)])[None]
        vd = np.concatenate([vp_np[pt[b, j]] for j in range(per_seq)])[None]
        ref = decode_attention(q[b:b + 1], jnp.asarray(kd), jnp.asarray(vd),
                               valid_len=int(lengths[b]), bkv=16)
        np.testing.assert_allclose(np.asarray(out[b:b + 1]), np.asarray(ref),
                                   atol=1e-5)

"""Fig-1 reproduction tests: the DES must reproduce the paper's claims
and agree with the closed-form Little's-law bounds."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sim import (AMUParams, CoreParams, LatencyModel,
                            bandwidth_sweep, little_bound_amu,
                            little_bound_blocking, simulate_amu,
                            simulate_blocking_core)

LINK = 50e9
MB = 1 << 22


def test_paper_claim_sync_collapses_with_latency():
    """Paper §1: OoO cores cannot tolerate 300ns-10us far-memory latency."""
    rows = bandwidth_sweep([200e-9, 1e-6, 3e-6, 10e-6], total_bytes=MB)
    utils = [r["sync_util"] for r in rows]
    assert all(a > b for a, b in zip(utils, utils[1:])), utils
    assert utils[-1] < 0.01          # 10us: <1% of the link


def test_paper_claim_amu_sustains_bandwidth():
    rows = bandwidth_sweep([200e-9, 1e-6, 3e-6, 10e-6], total_bytes=MB)
    assert all(r["amu_util"] > 0.85 for r in rows), rows
    assert all(r["speedup"] > 5 for r in rows)


def test_paper_claim_speedup_grows_with_latency():
    rows = bandwidth_sweep([200e-9, 1e-6, 10e-6], total_bytes=MB)
    sp = [r["speedup"] for r in rows]
    assert sp[0] < sp[1] < sp[2]


def test_granularity_exploits_bandwidth():
    """Paper §1 'variable granularity': larger granules raise utilization
    at fixed outstanding count."""
    lm = LatencyModel(kind="fixed", lo=3e-6, hi=3e-6)
    utils = []
    for g in (64, 512, 4096):
        r = simulate_amu(MB, lm, AMUParams(outstanding=32, granularity=g),
                         link_bw=LINK)
        utils.append(r.utilization)
    assert utils[0] < utils[1] < utils[2]


def test_des_matches_little_bound_blocking():
    core = CoreParams()
    for lat in (200e-9, 1e-6, 10e-6):
        lm = LatencyModel(kind="fixed", lo=lat, hi=lat)
        des = simulate_blocking_core(MB, lm, core, LINK)
        bound = little_bound_blocking(lat, core, LINK)
        assert des.achieved_bw <= bound * 1.02
        assert des.achieved_bw >= bound * 0.5      # within 2x of the bound


def test_des_matches_little_bound_amu():
    amu = AMUParams()
    for lat in (200e-9, 1e-6, 10e-6):
        lm = LatencyModel(kind="fixed", lo=lat, hi=lat)
        des = simulate_amu(MB, lm, amu, LINK)
        bound = little_bound_amu(lat, amu, LINK)
        assert des.achieved_bw <= bound * 1.02
        assert des.achieved_bw >= bound * 0.5


def test_wide_distribution_hurts_blocking_more():
    """In-order retirement: a bimodal tail stalls the window, so the
    blocking core loses MORE bandwidth than the mean-latency equivalent."""
    mean = 0.9 * 300e-9 + 0.1 * 10e-6
    fixed = simulate_blocking_core(
        MB, LatencyModel("fixed", mean, mean), link_bw=LINK)
    bimodal = simulate_blocking_core(
        MB, LatencyModel("bimodal", 300e-9, 10e-6, tail_frac=0.1),
        link_bw=LINK)
    assert bimodal.achieved_bw < fixed.achieved_bw * 1.05
    # while the AMU barely notices the tail
    amu_fixed = simulate_amu(MB, LatencyModel("fixed", mean, mean),
                             link_bw=LINK)
    amu_bi = simulate_amu(MB, LatencyModel("bimodal", 300e-9, 10e-6,
                                           tail_frac=0.1), link_bw=LINK)
    assert amu_bi.achieved_bw > 0.8 * amu_fixed.achieved_bw


@settings(max_examples=20, deadline=None)
@given(lat=st.floats(1e-7, 1e-5), out=st.integers(4, 1024))
def test_property_amu_dominates_blocking(lat, out):
    lm = LatencyModel("fixed", lat, lat)
    sync = simulate_blocking_core(MB, lm, link_bw=LINK)
    asyn = simulate_amu(MB, lm, AMUParams(outstanding=out), link_bw=LINK)
    assert asyn.achieved_bw >= sync.achieved_bw * 0.9


@settings(max_examples=20, deadline=None)
@given(out=st.integers(1, 512))
def test_property_mlp_bounded_by_outstanding(out):
    lm = LatencyModel("fixed", 2e-6, 2e-6)
    res = simulate_amu(MB, lm, AMUParams(outstanding=out), link_bw=LINK)
    assert res.mean_mlp <= out + 1e-6


def test_utilization_never_exceeds_one():
    for lat in (1e-7, 1e-6, 1e-5):
        lm = LatencyModel("lognormal", lat, lat * 10)
        assert simulate_amu(MB, lm, link_bw=LINK).utilization <= 1.0
        assert simulate_blocking_core(MB, lm, link_bw=LINK).utilization <= 1.0

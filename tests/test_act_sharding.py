"""Activation-sharding/precision policy tests.

The optimized policy must (a) be a pure no-op numerically (within bf16
noise), (b) pick the documented layouts, (c) never leak outside its
context manager."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke
from repro.dist import act_sharding as acts
from repro.models import init_params, train_loss


def test_policy_context_nesting_and_default():
    assert acts.current() == acts.BASELINE
    with acts.policy(acts.OPTIMIZED):
        assert acts.current().native_dtype
        with acts.policy(acts.BASELINE):
            assert not acts.current().native_dtype
        assert acts.current().seq_residual
    assert acts.current() == acts.BASELINE


def test_attn_plan_selection(monkeypatch):
    monkeypatch.setattr(acts, "_mesh_axis_sizes",
                        lambda: {"data": 16, "model": 16})
    with acts.policy(acts.ActPolicy(attn_explicit=True)):
        assert acts.attn_plan(96, 8, 4096) == ("heads", "model")
        assert acts.attn_plan(24, 8, 4096) == ("seq", "model")   # 24 % 16 != 0
        assert acts.attn_plan(24, 8, 100) is None                # seq unfit
    with acts.policy(acts.ActPolicy(attn_explicit=True, seq_residual=True)):
        # a seq-sharded residual stream (signalled by the layer) forces
        # seq-sharded attention even for divisible head counts
        with acts.residual_layout(True):
            assert acts.attn_plan(96, 8, 4096) == ("seq", "model")
        assert acts.attn_plan(96, 8, 4096) == ("heads", "model")
    with acts.policy(acts.BASELINE):
        assert acts.attn_plan(96, 8, 4096) is None


def test_residual_spec(monkeypatch):
    monkeypatch.setattr(acts, "_mesh_axis_sizes",
                        lambda: {"data": 16, "model": 16})
    with acts.policy(acts.OPTIMIZED):
        spec = acts.residual_spec(4096)
        assert spec is not None and "model" in str(spec)
        assert acts.residual_spec(100) is None          # not divisible
        g = acts.residual_spec(4096, gather=True)
        assert "model" not in str(g)
    assert acts.residual_spec(4096) is None             # baseline: off


def test_no_mesh_is_noop():
    with acts.policy(acts.OPTIMIZED):
        # single-device: plans and specs all degrade to None/no-op
        assert acts.attn_plan(96, 8, 4096) is None
        assert acts.residual_spec(4096) is None
        x = jnp.ones((4, 4))
        assert acts.constrain(x, None) is not None


@pytest.mark.parametrize("arch", ["command-r-plus-104b", "rwkv6-7b",
                                  "llama4-maverick-400b-a17b",
                                  "zamba2-1.2b"])
def test_optimized_policy_numerically_equivalent(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                     cfg.vocab_size),
    }
    with acts.policy(acts.BASELINE):
        l0, _ = train_loss(params, cfg, batch)
    with acts.policy(acts.OPTIMIZED):
        l1, _ = train_loss(params, cfg, batch)
    assert abs(float(l0) - float(l1)) < 2e-2

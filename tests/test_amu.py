"""AMU runtime semantics + property tests (hypothesis).

System invariants under test:
  * every issued request id is returned by getfin/wait EXACTLY once,
  * getfin never blocks and never returns an unfinished id,
  * outstanding never exceeds max_outstanding in flight,
  * QoS ordering: LATENCY issues before BULK when both are queued,
  * FAIL policy rejects (returns FAILURE_CODE) instead of blocking,
  * pattern granule decomposition covers the region exactly once.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.amu import (AMU, AccessConfig, AMUError, FAILURE_CODE, QoS,
                            QueueFullPolicy, RequestState, SimBackend)
from repro.core.offload import FarMemoryTier, StreamingPrefetcher
from repro.core.patterns import (GatherPattern, StreamPattern, StridePattern,
                                 coalescing_ratio, granules)


def _amu(max_outstanding=4, **kw):
    return AMU(backend=SimBackend(base_latency=1e-6, bandwidth=10e9),
               max_outstanding=max_outstanding, **kw)


def test_getfin_nonblocking_and_exactly_once():
    amu = _amu()
    rids = [amu.aload(nbytes=64, src=np.zeros(16, np.float32))
            for _ in range(3)]
    assert all(r >= 0 for r in rids)
    amu.backend.advance(1.0)
    seen = set()
    while True:
        r = amu.getfin()
        if r == FAILURE_CODE:
            break
        assert r not in seen
        seen.add(r)
    assert seen == set(rids)
    assert amu.getfin() == FAILURE_CODE     # drained: still non-blocking


def test_wait_specific_and_double_consume_rejected():
    amu = _amu()
    r0 = amu.aload(nbytes=64, src=np.zeros(16, np.float32))
    req = amu.wait(r0)
    assert req.state is RequestState.CONSUMED
    with pytest.raises(AMUError):
        amu.wait(r0)


def test_fail_policy_rejects_when_full():
    amu = _amu(max_outstanding=2, full_policy=QueueFullPolicy.FAIL)
    src = np.zeros(16, np.float32)
    assert amu.aload(src) >= 0
    assert amu.aload(src) >= 0
    assert amu.aload(src) == FAILURE_CODE
    assert amu.stats["rejected"] == 1


def test_qos_ordering():
    amu = _amu(max_outstanding=1)
    src = np.zeros(1024, np.float32)
    bulk = amu.astore(src, config=AccessConfig(qos=QoS.BULK))
    lat = amu.aload(src, config=AccessConfig(qos=QoS.LATENCY))
    # one slot: bulk went in flight first; among queued, LATENCY preempts
    std = amu.aload(src, config=AccessConfig(qos=QoS.STANDARD))
    amu.backend.advance(10.0)
    order = amu.drain()
    assert order.index(lat) < order.index(std)


def test_stats_and_latency_accounting():
    amu = _amu()
    r = amu.aload(nbytes=1 << 20, src=np.zeros(4, np.float32))
    amu.backend.advance(1.0)
    amu.wait(r)
    req = amu.request(r)
    assert req.latency > 0
    assert amu.stats["aload"] == 1 and amu.stats["completed"] == 1


@settings(max_examples=50, deadline=None)
@given(
    n_requests=st.integers(1, 40),
    max_outstanding=st.integers(1, 8),
    sizes=st.lists(st.integers(1, 1 << 16), min_size=1, max_size=40),
)
def test_property_all_complete_exactly_once(n_requests, max_outstanding,
                                            sizes):
    amu = _amu(max_outstanding=max_outstanding)
    rids = []
    for i in range(n_requests):
        nbytes = sizes[i % len(sizes)]
        rids.append(amu.aload(nbytes=nbytes, src=np.zeros(1, np.uint8)))
    amu.backend.advance(1e6)
    done = amu.drain()
    assert sorted(done) == sorted(rids)
    assert amu.outstanding == 0


@settings(max_examples=50, deadline=None)
@given(total=st.integers(1, 1 << 20), gran=st.integers(1, 1 << 16))
def test_property_stream_granules_cover_exactly(total, gran):
    pat = StreamPattern(total_bytes=total)
    ranges = list(pat.granule_ranges(gran))
    assert sum(n for _, n in ranges) == total
    # contiguous, non-overlapping
    pos = 0
    for off, n in ranges:
        assert off == pos and n > 0
        pos += n


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 1000), min_size=1, max_size=200),
       st.integers(8, 4096))
def test_property_gather_coalescing_never_loses_elements(indices, gran):
    elem = 8
    pat = GatherPattern(total_bytes=len(indices) * elem,
                        indices=tuple(indices), elem_bytes=elem)
    ranges = list(pat.granule_ranges(gran))
    assert sum(n for _, n in ranges) == len(indices) * elem
    assert coalescing_ratio(indices, elem, gran) >= 1.0


def test_stride_pattern():
    pat = StridePattern(total_bytes=4 * 64, block_bytes=64, stride_bytes=256,
                        count=4)
    ranges = list(pat.granule_ranges(32))
    assert len(ranges) == 8
    assert ranges[0] == (0, 32) and ranges[2] == (256, 32)


def test_far_tier_prefetch_overlap():
    amu = _amu(max_outstanding=8)
    tier = FarMemoryTier(amu)
    for i in range(6):
        tier.offload(f"w{i}", np.full(256, float(i), np.float32))
    pf = StreamingPrefetcher(tier, [f"w{i}" for i in range(6)], depth=3)
    pf.start()
    amu.backend.advance(1e3)
    vals = [pf.step()[0] for _ in range(6)]
    assert vals == [float(i) for i in range(6)]
    assert pf.fetch_overlap_events == 3   # depth kept full while consuming

"""SLO-aware scheduling tests: token exactness, starvation freedom,
goodput dominance, and the EngineConfig construction API.

The scheduler redesign's acceptance surface (PR 6):

  * whatever the SLO policy decides — EDF chunk ordering, batch-tier
    shedding, deadline-aware preemption onto QoS windows — the tokens
    generated must equal the dense engine's bit-for-bit (scheduling
    changes *when*, never *what*),
  * batch-tier requests are shed first under pressure but never starve:
    every admitted request completes,
  * under overload the SLO policy's interactive goodput must dominate
    watermark-FIFO's (the ``slo_goodput_sweep`` acceptance row),
  * the frozen ``EngineConfig`` path and the deprecated flat-kwarg shim
    build identical engines; unknown kwargs still raise ``TypeError``.
"""

import warnings

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke
from repro.models import init_params
from repro.paging import pages_for
from repro.serve import (ChunkingConfig, Engine, EngineConfig, PagingConfig,
                         SchedulerConfig, Tier, VirtualClock)
from repro.serve.workload import WorkloadSpec, generate


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("phi4-mini-3.8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params, {}


def _trace_requests(cfg, seed, n=6):
    """A small workload trace + deterministic prompt tokens for it."""
    spec = WorkloadSpec(rate=2000.0, prompt_median=8.0, prompt_sigma=0.5,
                        max_prompt=16, min_output=2, max_output=8,
                        interactive_frac=0.5, ttft_slo=20e-3, tpot_slo=5e-3)
    rng = np.random.default_rng(seed)
    out = []
    for wr in generate(n, spec, seed=seed):
        prompt = rng.integers(0, cfg.vocab_size,
                              wr.prompt_len).astype(np.int32)
        out.append((wr, prompt))
    return out


def _dense_reference(cfg, params, cache, reqs):
    key = tuple((tuple(int(t) for t in p), wr.output_len) for wr, p in reqs)
    if key not in cache:
        eng = Engine(cfg, params, EngineConfig(
            max_batch=3, max_len=64, prefill_buckets=(16,),
            paging=PagingConfig(enabled=False)))
        for wr, prompt in reqs:
            eng.submit(prompt, max_new_tokens=wr.output_len)
        cache[key] = eng.run()
    return cache[key]


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**16),
       page_size=st.sampled_from([4, 8]),
       spare_pages=st.integers(0, 2))
def test_property_slo_schedule_token_exact(setup, seed, page_size,
                                           spare_pages):
    """Random traces on a pool tight enough to force shedding and
    deadline-aware preemption: the SLO scheduler's outputs must equal
    the dense engine's token-for-token, and page accounting must drain."""
    cfg, params, ref_cache = setup
    reqs = _trace_requests(cfg, seed)
    ref = _dense_reference(cfg, params, ref_cache, reqs)

    need = max(pages_for(min(len(p) + wr.output_len, 64), page_size)
               for wr, p in reqs)
    eng = Engine(cfg, params, EngineConfig(
        max_batch=3, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(page_size=page_size,
                            device_pages=need + spare_pages),
        chunking=ChunkingConfig(chunk_tokens=4),
        scheduler=SchedulerConfig(policy="slo")))
    for wr, prompt in reqs:
        eng.submit(prompt, max_new_tokens=wr.output_len, tier=wr.tier,
                   ttft_slo=wr.ttft_slo, tpot_slo=wr.tpot_slo,
                   arrival_t=wr.arrival_t)
    out = eng.run()

    assert out == ref
    assert eng.stats["resumes"] == eng.stats["preemptions"]
    assert eng.page_pool.n_free == eng.page_pool.n_pages


def test_slo_schedule_no_starvation(setup):
    """Sustained interactive pressure sheds batch admissions, but every
    batch request still completes once the pressure drains (shedding
    defers, never drops)."""
    cfg, params, _ = setup
    eng = Engine(cfg, params, EngineConfig(
        max_batch=2, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(page_size=4, device_pages=8),
        chunking=ChunkingConfig(chunk_tokens=4),
        scheduler=SchedulerConfig(policy="slo", ttft_slo=10e-3,
                                  tpot_slo=5e-3)))
    rng = np.random.default_rng(3)
    n_batch, n_inter = 3, 9
    batch_rids = [eng.submit(rng.integers(0, cfg.vocab_size, 8),
                             max_new_tokens=6, tier=Tier.BATCH,
                             arrival_t=0.0)
                  for _ in range(n_batch)]
    for i in range(n_inter):
        eng.submit(rng.integers(0, cfg.vocab_size, 6), max_new_tokens=4,
                   tier=Tier.INTERACTIVE, arrival_t=i * 1e-3)
    out = eng.run()
    assert len(out) == n_batch + n_inter
    for rid in batch_rids:
        assert len(out[rid]) == 6            # batch finished, not dropped
    rep = eng.slo_report()
    assert rep["interactive"]["n"] == n_inter
    assert rep["batch"]["n"] == n_batch


def test_slo_goodput_dominates_watermark_in_sim():
    """The CI-gated acceptance: >= 1.2x interactive goodput over
    watermark-FIFO at 4x oversubscription on the production trace
    (deterministic virtual clock), and no loss at moderate load."""
    from repro.paging.sim import simulate_slo_schedule
    r4 = simulate_slo_schedule(4.0)
    assert r4["goodput_ratio"] >= 1.2
    assert r4["int_attain_slo"] >= r4["int_attain_wm"]
    r2 = simulate_slo_schedule(2.0)
    assert r2["goodput_ratio"] >= 1.0


def test_slo_beats_watermark_on_engine_trace(setup):
    """Same workload trace through the real engine under both policies:
    the SLO scheduler's interactive attainment is at least watermark's
    (engine-level sanity for the sim's head-to-head)."""
    cfg, params, _ = setup
    reqs = _trace_requests(cfg, 11, n=10)

    def run(policy):
        eng = Engine(cfg, params, EngineConfig(
            max_batch=2, max_len=64, prefill_buckets=(16,),
            paging=PagingConfig(page_size=4, device_pages=10),
            chunking=ChunkingConfig(chunk_tokens=4),
            scheduler=SchedulerConfig(policy=policy)))
        for wr, prompt in reqs:
            eng.submit(prompt, max_new_tokens=wr.output_len, tier=wr.tier,
                       ttft_slo=wr.ttft_slo, tpot_slo=wr.tpot_slo,
                       arrival_t=wr.arrival_t)
        eng.run()
        return eng.slo_report()

    wm = run("watermark")
    slo = run("slo")
    assert slo["interactive"]["attainment"] >= wm["interactive"]["attainment"]


def test_engine_config_and_legacy_shim_agree(setup):
    """Flat kwargs still construct (one DeprecationWarning) and behave
    exactly like the EngineConfig path; unknown kwargs raise."""
    cfg, params, _ = setup
    prompts = [np.arange(6) % cfg.vocab_size, np.arange(9) % cfg.vocab_size]

    def drive(eng):
        for p in prompts:
            eng.submit(p, max_new_tokens=4)
        return eng.run()

    new = drive(Engine(cfg, params, EngineConfig(
        max_batch=2, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(page_size=8, device_pages=6))))
    with pytest.warns(DeprecationWarning):
        old = drive(Engine(cfg, params, max_batch=2, max_len=64,
                           prefill_buckets=(16,), page_size=8,
                           device_pages=6))
    assert old == new

    with pytest.raises(TypeError, match="no_such_knob"):
        Engine(cfg, params, no_such_knob=1)

    with warnings.catch_warnings():
        warnings.simplefilter("error")       # config path must not warn
        Engine(cfg, params, EngineConfig(max_batch=2, max_len=64,
                                         prefill_buckets=(16,)))


def test_one_clock_stamps_every_timestamp(setup):
    """Every request timestamp rides the engine's one injected clock:
    with a shared VirtualClock, arrival/first-token/per-token/completion
    are all on its axis and monotone per request."""
    cfg, params, _ = setup
    clk = VirtualClock()
    eng = Engine(cfg, params, EngineConfig(
        max_batch=2, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(page_size=8, device_pages=8),
        scheduler=SchedulerConfig(clock=clk)))
    eng.submit(np.arange(5), max_new_tokens=4, arrival_t=0.0)
    eng.submit(np.arange(7), max_new_tokens=4, arrival_t=2e-3)
    eng.run()
    assert eng.clock is clk
    for r in eng.finished.values():
        assert r.token_ts and r.token_ts == sorted(r.token_ts)
        assert r.token_ts[0] >= r.arrival_t
        assert r.done_t >= r.token_ts[-1]
        assert r.ttft >= 0.0
        assert clk.now >= r.done_t


def test_cli_flags_generated_from_config():
    """launch/serve's flags come from the dataclass fields: a knob in
    the config is a flag on the CLI, help text included."""
    import argparse
    from repro.serve.config import add_config_args, config_from_args
    ap = argparse.ArgumentParser()
    add_config_args(ap)
    args = ap.parse_args(["--max-batch", "8", "--page-size", "4",
                          "--chunk-tokens", "16", "--policy", "slo",
                          "--ttft-slo", "0.05"])
    ec = config_from_args(args, paging_enabled=False)
    assert ec.max_batch == 8
    assert ec.paging.page_size == 4 and ec.paging.enabled is False
    assert ec.chunking.chunk_tokens == 16
    assert ec.scheduler.policy == "slo"
    assert ec.scheduler.ttft_slo == pytest.approx(0.05)

"""Property test: decode on the paged KV layout is bit-exact with dense.

The engine's acceptance criterion for the paged decode path: across
randomized admission/preempt/resume schedules — including resumes that
begin while pages are still ARRIVING — every request's generated tokens
must equal a dense (non-paged) engine's output exactly.  Uses the real
``hypothesis`` when installed, the deterministic conftest stand-in
otherwise.
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke
from repro.core.amu import AMU, SimBackend
from repro.models import init_params
from repro.paging import Pager, pages_for
from repro.serve.config import EngineConfig, PagingConfig
from repro.serve.engine import Engine


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("phi4-mini-3.8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params, {}


def _dense_reference(cfg, params, cache, requests):
    """Dense-engine outputs, cached per request set (module lifetime)."""
    key = tuple((tuple(int(t) for t in p), n) for p, n in requests)
    if key not in cache:
        eng = Engine(cfg, params, EngineConfig(
            max_batch=3, max_len=64, prefill_buckets=(16,),
            paging=PagingConfig(enabled=False)))
        for prompt, new in requests:
            eng.submit(prompt, max_new_tokens=new)
        cache[key] = eng.run()
    return cache[key]


def _slow_pager_factory(base_latency):
    """Pager over a SimBackend slow enough that resumed sequences spend
    multiple engine ticks with pages ARRIVING before re-entry."""
    def factory(pool, table, *, page_nbytes):
        amu = AMU(backend=SimBackend(base_latency=base_latency,
                                     bandwidth=10e9),
                  max_outstanding=64)
        return Pager(pool, table, amu, page_nbytes=page_nbytes)
    return factory


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**16),
       page_size=st.sampled_from([4, 8, 16]),
       spare_pages=st.integers(0, 3),
       hot_tail=st.integers(0, 2),
       latency=st.floats(1e-5, 4e-3))
def test_property_paged_decode_matches_dense(setup, seed, page_size,
                                             spare_pages, hot_tail,
                                             latency):
    cfg, params, ref_cache = setup
    rng = np.random.default_rng(seed)
    n_req = int(rng.integers(3, 6))
    requests = [(rng.integers(0, cfg.vocab_size,
                              int(rng.integers(2, 17))).astype(np.int32),
                 int(rng.integers(2, 13)))
                for _ in range(n_req)]

    ref = _dense_reference(cfg, params, ref_cache, requests)

    # pool sized barely above the largest single request: admission is
    # oversubscribed and growth forces preemption/resume churn
    need = max(pages_for(min(len(p) + n, 64), page_size)
               for p, n in requests)
    eng = Engine(cfg, params, EngineConfig(
        max_batch=3, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(
            page_size=page_size, device_pages=need + spare_pages,
            hot_tail_pages=hot_tail,
            pager_factory=_slow_pager_factory(latency))))
    for prompt, new in requests:
        eng.submit(prompt, max_new_tokens=new)
    out = eng.run()

    assert out == ref
    assert eng.page_pool.n_free == eng.page_pool.n_pages
    assert eng.stats["resumes"] == eng.stats["preemptions"]


@pytest.mark.parametrize("arch", ["zamba2-1.2b", "seamless-m4t-medium"])
def test_paged_matches_dense_other_families(arch):
    """Hybrid (Mamba2 + shared attn) and enc-dec also decode on the
    paged layout — their non-KV aux state (SSM state / cross KV) rides
    the park/resume path while the KV pages stay pooled."""
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = [np.arange(8) % cfg.vocab_size,
               np.arange(8) % cfg.vocab_size,
               np.arange(8) % cfg.vocab_size]

    def run(paging=PagingConfig()):
        eng = Engine(cfg, params, EngineConfig(
            max_batch=2, max_len=32, prefill_buckets=(8,),
            paging=paging))
        for p in prompts:
            eng.submit(p, max_new_tokens=6)
        return eng, eng.run()

    _, ref = run(paging=PagingConfig(enabled=False))
    eng, out = run(PagingConfig(page_size=4, device_pages=5,
                                hot_tail_pages=1))
    assert eng.paging and eng.stats["preemptions"] > 0
    assert out == ref
    assert eng.page_pool.n_free == eng.page_pool.n_pages


def test_resume_while_arriving_matches_dense(setup):
    """Deterministic schedule where a resumed sequence is re-admitted
    only after several ticks of ARRIVING pages (fetch latency spans
    multiple decode steps), then decodes on: still bit-exact."""
    cfg, params, _ = setup
    prompts = [np.arange(13) % cfg.vocab_size,
               np.arange(16) % cfg.vocab_size,
               np.arange(5) % cfg.vocab_size]

    dense = Engine(cfg, params, EngineConfig(
        max_batch=3, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(enabled=False)))
    for p in prompts:
        dense.submit(p, max_new_tokens=10)
    ref = dense.run()

    # 2.5 ticks of base latency: a parked page needs >= 3 engine ticks
    # in flight, so _try_finish_resumes repeatedly sees ARRIVING pages
    eng = Engine(cfg, params, EngineConfig(
        max_batch=3, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(page_size=4, device_pages=7, hot_tail_pages=1,
                            pager_factory=_slow_pager_factory(2.5e-3))))
    for p in prompts:
        eng.submit(p, max_new_tokens=10)
    out = eng.run()

    assert eng.stats["preemptions"] > 0
    assert eng.pager.stats["arrived"] > 0      # LATENCY aloads landed
    assert out == ref

"""Disaggregated prefill/decode tests (the PR-8 acceptance surface).

A PREFILL-role engine and a DECODE-role engine running over ONE shared
:class:`~repro.core.offload.FarMemoryTier` must produce exactly the
fused engine's tokens — under arbitrary graduation/admission
interleavings, slow pagers, and AMU faults injected into the handoff
fetch.  Tier entries may be discarded only after every transfer
verifiably landed: a faulted admission must leave every ``(rid, *)``
entry intact and succeed on retry.
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke
from repro.core.amu import AMU, AMUError, SimBackend
from repro.core.offload import FarMemoryTier
from repro.models import init_params
from repro.paging import PagingError
from repro.serve.config import (ChunkingConfig, EngineConfig, EngineRole,
                                PagingConfig)
from repro.serve.disagg import (HandoffBoard, make_shared_tier,
                                run_disaggregated, spool_load, spool_save,
                                tier_pager_factory)
from repro.serve.engine import Engine


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("phi4-mini-3.8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params, {}


def _pair(cfg, params, tier, *, pager_latency=1e-6, device_pages=20):
    """A (PREFILL, DECODE) engine pair over one shared ``tier``."""
    mk = tier_pager_factory(tier, base_latency=pager_latency)
    pe = Engine(cfg, params, EngineConfig(
        max_batch=3, max_len=64, prefill_buckets=(32,), role="prefill",
        paging=PagingConfig(page_size=8, device_pages=device_pages,
                            pager_factory=mk),
        chunking=ChunkingConfig(chunk_tokens=8)))
    de = Engine(cfg, params, EngineConfig(
        max_batch=3, max_len=64, prefill_buckets=(32,), role="decode",
        handoff=pe.handoff,
        paging=PagingConfig(page_size=8, device_pages=device_pages,
                            pager_factory=mk),
        chunking=ChunkingConfig(chunk_tokens=8)))
    return pe, de


def _fused_reference(cfg, params, cache, requests):
    """The fused engine's outputs for ``[(prompt, max_new), ...]`` —
    the disaggregated pipeline must match these token-for-token."""
    key = tuple((tuple(int(t) for t in p), n) for p, n in requests)
    if key not in cache:
        eng = Engine(cfg, params, EngineConfig(
            max_batch=3, max_len=64, prefill_buckets=(32,),
            paging=PagingConfig(page_size=8, device_pages=20),
            chunking=ChunkingConfig(chunk_tokens=8)))
        for prompt, new in requests:
            eng.submit(prompt, max_new_tokens=new)
        cache[key] = eng.run()
    return cache[key]


# ---------------------------------------------------------------------------
# role wiring
# ---------------------------------------------------------------------------

def test_fused_role_is_the_default_and_unchanged(setup):
    """FUSED engines carry no disaggregation surface: default role,
    no handoff board, no 'handoffs' stats key (metric snapshots stay
    byte-compatible with the pre-split engine)."""
    cfg, params, _ = setup
    eng = Engine(cfg, params, EngineConfig(
        max_batch=2, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(page_size=8)))
    assert eng.role is EngineRole.FUSED
    assert eng.handoff is None
    assert "handoffs" not in eng.stats
    with pytest.raises(PagingError):
        eng.admit_handoff(object())        # wrong role, checked first


def test_prefill_role_forces_offload_and_makes_board(setup):
    cfg, params, _ = setup
    tier = make_shared_tier()
    pe, de = _pair(cfg, params, tier)
    assert pe.role is EngineRole.PREFILL
    assert pe.offload_finished            # graduation IS the park
    assert isinstance(pe.handoff, HandoffBoard)
    assert de.handoff is pe.handoff
    assert pe.far_tier is tier and de.far_tier is tier
    assert pe.pager.amu is not de.pager.amu is not tier.amu
    assert "handoffs" in pe.stats and "handoffs" in de.stats


def test_run_disaggregated_validates_pair(setup):
    cfg, params, _ = setup
    tier = make_shared_tier()
    pe, de = _pair(cfg, params, tier)
    fused = Engine(cfg, params, EngineConfig(
        max_batch=2, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(page_size=8)))
    with pytest.raises(PagingError):
        run_disaggregated(fused, de)       # wrong prefill role
    pe2, _ = _pair(cfg, params, make_shared_tier())
    with pytest.raises(PagingError):
        run_disaggregated(pe2, de)         # different far tiers


# ---------------------------------------------------------------------------
# token-exactness
# ---------------------------------------------------------------------------

def test_disagg_pipeline_token_exact(setup):
    """The driven pipeline (graduation overlapping adoption) matches the
    fused engine exactly, including a one-token request that finishes on
    the prefill side (``rec.done`` — adopted straight into finished)."""
    cfg, params, cache = setup
    rng = np.random.default_rng(3)
    reqs = [(rng.integers(1, cfg.vocab_size, size=l).astype(np.int32), m)
            for l, m in ((7, 5), (13, 4), (21, 1), (5, 6))]
    ref = _fused_reference(cfg, params, cache, reqs)
    tier = make_shared_tier()
    pe, de = _pair(cfg, params, tier)
    for p, m in reqs:
        pe.submit(p, max_new_tokens=m)
    out = run_disaggregated(pe, de)
    assert set(out) == set(ref)
    for rid in ref:
        assert out[rid] == ref[rid]
    assert pe.stats["handoffs"] == len(reqs) == de.stats["handoffs"]
    # completed sequences left nothing behind in the shared tier
    for rid in ref:
        assert (rid, "aux") not in tier and (rid, 0) not in tier


def test_fault_during_handoff_admission_retries(setup):
    """An AMU fault inside the handoff aux fetch raises with every tier
    entry intact and zero decode-side state mutated; the same record
    admits cleanly on retry and the tokens still match fused."""
    cfg, params, cache = setup
    fail = {"on": False}

    def latency_fn(req):
        if fail["on"]:
            raise RuntimeError("injected handoff fault")
        return 1e-6

    tier = FarMemoryTier(AMU(SimBackend(base_latency=1e-6, bandwidth=10e9,
                                        latency_fn=latency_fn)))
    pe, de = _pair(cfg, params, tier)
    rng = np.random.default_rng(5)
    reqs = [(rng.integers(1, cfg.vocab_size, size=11).astype(np.int32), 5),
            (rng.integers(1, cfg.vocab_size, size=17).astype(np.int32), 4)]
    for p, m in reqs:
        pe.submit(p, max_new_tokens=m)
    pe.run()
    recs = pe.handoff.poll()
    assert len(recs) == 2

    fail["on"] = True
    with pytest.raises(AMUError):
        de.admit_handoff(recs[0])
    rid = recs[0].rid
    # nothing discarded, nothing admitted: full retryability
    assert (rid, "aux") in tier
    for logical in range(recs[0].n_pages):
        assert (rid, logical) in tier
    assert de.stats["handoffs"] == 0
    assert not de.queue and rid not in de.page_table.sequences()

    fail["on"] = False
    for rec in recs:
        de.admit_handoff(rec)
    out = de.run()
    ref = _fused_reference(cfg, params, cache, reqs)
    for r in ref:
        assert out[r] == ref[r]
    de.check_invariants()


@settings(max_examples=4, deadline=None)
@given(data=st.data())
def test_disagg_interleavings_token_exact(setup, data):
    """Property: ANY admission order, decode-step stagger, pager speed
    and fault placement yields exactly the fused engine's tokens, with
    both engines' invariants balanced afterwards."""
    cfg, params, cache = setup
    n = data.draw(st.integers(min_value=2, max_value=4))
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 16)))
    reqs = [(rng.integers(1, cfg.vocab_size,
                          size=int(rng.integers(3, 28))).astype(np.int32),
             int(rng.integers(1, 7)))
            for _ in range(n)]
    order = data.draw(st.permutations(list(range(n))))
    gaps = [data.draw(st.integers(min_value=0, max_value=3))
            for _ in range(n)]
    faulty = data.draw(st.sets(st.sampled_from(list(range(n)))))
    slow = data.draw(st.booleans())

    fail = {"on": False}

    def latency_fn(req):
        if fail["on"]:
            raise RuntimeError("injected handoff fault")
        return 5e-6 if slow else 1e-6

    tier = FarMemoryTier(AMU(SimBackend(base_latency=1e-6, bandwidth=10e9,
                                        latency_fn=latency_fn)))
    pe, de = _pair(cfg, params, tier,
                   pager_latency=20e-6 if slow else 1e-6)
    for p, m in reqs:
        pe.submit(p, max_new_tokens=m)
    pe.run()
    recs = {rec.rid: rec for rec in pe.handoff.poll()}
    assert len(recs) == n

    for i, gap in zip(order, gaps):
        for _ in range(gap):
            if not de.drained:
                de.step_once()
        rec = recs[i]
        if i in faulty and not rec.done:
            fail["on"] = True
            with pytest.raises(AMUError):
                de.admit_handoff(rec)
            assert (rec.rid, "aux") in tier   # retryable: entry intact
            fail["on"] = False
        de.admit_handoff(rec)
    out = de.run()

    ref = _fused_reference(cfg, params, cache, reqs)
    assert set(out) == set(ref)
    for rid in ref:
        assert out[rid] == ref[rid]
    pe.check_invariants()
    de.check_invariants()


def test_spool_roundtrip_across_tiers(setup, tmp_path):
    """The two-process handoff: records + tier entries spooled to disk
    by the prefill side install into a *different* tier on the decode
    side and still decode token-exact."""
    cfg, params, cache = setup
    rng = np.random.default_rng(9)
    reqs = [(rng.integers(1, cfg.vocab_size, size=l).astype(np.int32), m)
            for l, m in ((9, 4), (15, 5))]
    tier_a = make_shared_tier()
    pe, _ = _pair(cfg, params, tier_a)
    for p, m in reqs:
        pe.submit(p, max_new_tokens=m)
    pe.run()
    recs = pe.handoff.poll()
    path = str(tmp_path / "handoff.pkl")
    spool_save(path, recs, tier_a)

    tier_b = make_shared_tier()
    _, de = _pair(cfg, params, tier_b)
    loaded = spool_load(path, tier_b)
    assert [r.rid for r in loaded] == [r.rid for r in recs]
    for rec in loaded:
        de.admit_handoff(rec)
    out = de.run()
    ref = _fused_reference(cfg, params, cache, reqs)
    for rid in ref:
        assert out[rid] == ref[rid]


def test_decode_engine_mixes_handoffs_with_local_submissions(setup):
    """A DECODE engine is still a full engine: locally submitted
    requests interleave with adopted ones, and the rid counter jumps
    past handed-off rids so the id space never collides."""
    cfg, params, cache = setup
    rng = np.random.default_rng(11)
    hand = [(rng.integers(1, cfg.vocab_size, size=10).astype(np.int32), 4)]
    local = (rng.integers(1, cfg.vocab_size, size=8).astype(np.int32), 3)
    tier = make_shared_tier()
    pe, de = _pair(cfg, params, tier)
    pe.submit(hand[0][0], max_new_tokens=hand[0][1])
    pe.run()
    rec = pe.handoff.poll()[0]
    de.admit_handoff(rec)
    local_rid = de.submit(local[0], max_new_tokens=local[1])
    assert local_rid > rec.rid             # bumped past the adopted rid
    out = de.run()
    ref_h = _fused_reference(cfg, params, cache, hand)
    ref_l = _fused_reference(cfg, params, cache, [local])
    assert out[rec.rid] == ref_h[rec.rid]
    assert out[local_rid] == ref_l[0]      # fused ref numbered it rid 0

"""repro.dist.sharding spec trees must tree-match the parameter and
optimizer pytrees and follow the TP/EP/ZeRO rules, for dense and MoE
configs, on single-pod and multipod axis layouts.

Runs in the main (single-device) test process: ``param_specs`` /
``opt_state_specs`` accept a plain ``{axis: size}`` mapping, so no
forced device count is needed here (the end-to-end placement is covered
by ``test_dist.py``)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_smoke
from repro.dist.sharding import batch_specs, opt_state_specs, param_specs
from repro.dist.steps import abstract_opt_state, abstract_params
from repro.configs.base import ShapeConfig

MESH = {"data": 2, "model": 4}
MULTIPOD = {"pod": 2, "data": 2, "model": 2}

_structure = jax.tree_util.tree_structure


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "olmoe-1b-7b"])
def test_param_specs_tree_match_and_leaf_type(arch):
    pshapes = abstract_params(get_smoke(arch))
    pspecs = param_specs(MESH, pshapes)
    assert _structure(pspecs) == _structure(pshapes)
    for spec in jax.tree_util.tree_leaves(pspecs):
        assert isinstance(spec, P)          # never None: tree_map-safe


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "olmoe-1b-7b"])
def test_opt_state_specs_tree_match(arch):
    cfg = get_smoke(arch)
    pshapes = abstract_params(cfg)
    oshapes = abstract_opt_state(cfg)
    ospecs = opt_state_specs(MESH, pshapes, zero1=True)
    assert _structure(ospecs.m) == _structure(oshapes.m)
    assert _structure(ospecs.v) == _structure(oshapes.v)
    assert ospecs.step == P()               # replicated scalar


def test_dense_tp_layout_phi4_smoke():
    cfg = get_smoke("phi4-mini-3.8b")
    pspecs = param_specs(MESH, abstract_params(cfg))
    layers = pspecs["layers"]["attn"]
    # column-parallel q/k/v shard the output dim, row-parallel o the input
    assert layers["q"]["w"][-1] == "model"
    assert layers["o"]["w"][-2] == "model"
    assert pspecs["embed"]["table"][0] == "model"
    mlp = pspecs["layers"]["mlp"]
    assert mlp["gate"]["w"][-1] == "model"
    assert mlp["down"]["w"][-2] == "model"


def test_moe_expert_parallel_olmoe():
    """olmoe smoke: 8 experts over model=4 — expert dim (axis -3 of the
    layer-stacked (L, E, d, ff) tensors) shards over model."""
    cfg = get_smoke("olmoe-1b-7b")
    pspecs = param_specs(MESH, abstract_params(cfg))
    mlp = pspecs["layers"]["mlp"]
    for name in ("gate", "up", "down"):
        assert mlp[name][-3] == "model", f"expert dim of {name} not EP-sharded"


def test_zero1_shards_every_moment_leaf_multipod():
    """On the (pod, data, model) mesh every optimizer-moment leaf of the
    full mistral-nemo config must carry a pod/data axis (ZeRO-1)."""
    cfg = get_config("mistral-nemo-12b")
    pshapes = abstract_params(cfg)
    ospecs = opt_state_specs(MULTIPOD, pshapes, zero1=True)
    leaves = jax.tree_util.tree_leaves(ospecs.m)
    assert leaves, "empty moment spec tree"
    for spec in leaves:
        assert "pod" in str(spec) or "data" in str(spec), spec
    # zero1=False keeps the plain TP layout
    off = opt_state_specs(MULTIPOD, pshapes, zero1=False)
    assert off.m == param_specs(MULTIPOD, pshapes)


def test_batch_specs_divisibility():
    cfg = get_smoke("phi4-mini-3.8b")
    sharded = batch_specs(MESH, cfg, ShapeConfig("t", 32, 4, "train"))
    assert sharded["tokens"] == P("data", None)
    odd = batch_specs(MESH, cfg, ShapeConfig("t", 32, 3, "train"))
    assert odd["tokens"] == P(None, None)   # B=3 doesn't divide data=2

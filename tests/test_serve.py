"""Serving engine tests: continuous batching, slot lifecycle, KV parking."""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import init_params
from repro.serve.config import EngineConfig, PagingConfig
from repro.serve.engine import Engine


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke("phi4-mini-3.8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_completes_all_requests(dense_setup):
    cfg, params = dense_setup
    eng = Engine(cfg, params, EngineConfig(
        max_batch=3, max_len=64, prefill_buckets=(16, 32)))
    rng = np.random.default_rng(0)
    n = 7
    for i in range(n):
        eng.submit(rng.integers(0, cfg.vocab_size, 4 + i),
                   max_new_tokens=5)
    out = eng.run()
    assert len(out) == n
    assert all(len(v) == 5 for v in out.values())
    assert all(0 <= t < cfg.padded_vocab for v in out.values() for t in v)


def test_engine_continuous_batching_overlaps(dense_setup):
    """More requests than slots must share decode steps (no drain barrier):
    total decode steps << requests x tokens."""
    cfg, params = dense_setup
    eng = Engine(cfg, params, EngineConfig(
        max_batch=4, max_len=64, prefill_buckets=(16,)))
    for i in range(8):
        eng.submit(np.arange(4), max_new_tokens=6)
    out = eng.run()
    assert len(out) == 8
    assert eng.stats["steps"] < 8 * 6          # would be 48 serially
    assert eng.pool.n_free == 4                # all slots returned


def test_engine_deterministic(dense_setup):
    cfg, params = dense_setup
    def run_once():
        eng = Engine(cfg, params, EngineConfig(
            max_batch=2, max_len=64, prefill_buckets=(16,)))
        eng.submit(np.arange(6), max_new_tokens=5)
        eng.submit(np.arange(3), max_new_tokens=5)
        return eng.run()
    assert run_once() == run_once()


def test_engine_single_matches_batched(dense_setup):
    """A request decoded alone equals the same request decoded while other
    slots are busy (per-slot positions keep mixed-depth batches correct)."""
    cfg, params = dense_setup
    prompt = np.arange(7) % cfg.vocab_size

    solo = Engine(cfg, params, EngineConfig(
        max_batch=1, max_len=64, prefill_buckets=(16,)))
    solo.submit(prompt, max_new_tokens=4)
    solo_out = solo.run()[0]

    busy = Engine(cfg, params, EngineConfig(
        max_batch=3, max_len=64, prefill_buckets=(16,)))
    rid = busy.submit(prompt, max_new_tokens=4)
    busy.submit(np.arange(12) % cfg.vocab_size, max_new_tokens=6)
    busy.submit(np.arange(3) % cfg.vocab_size, max_new_tokens=6)
    busy_out = busy.run()[rid]
    assert busy_out == solo_out


def test_engine_kv_offload_parks_finished(dense_setup):
    cfg, params = dense_setup
    eng = Engine(cfg, params, EngineConfig(
        max_batch=2, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(offload_finished=True)))
    for i in range(3):
        eng.submit(np.arange(5), max_new_tokens=3)
    out = eng.run()
    assert len(out) == 3
    # page parks ride the pager's BULK astores on the one shared far tier
    assert eng.far_tier.amu.stats["astore"] > 0
    assert eng.pager.stats["writeback"] > 0
    # parked caches can be brought back (fetch reassembles the tree)
    key = next(iter(eng.finished))
    tree = eng.fetch_finished(key)
    assert jax.tree_util.tree_leaves(tree)


def test_engine_ssm_family():
    cfg = get_smoke("rwkv6-7b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_len=32))
    for i in range(3):
        eng.submit(np.arange(4 + i), max_new_tokens=4)
    out = eng.run()
    assert len(out) == 3 and all(len(v) == 4 for v in out.values())

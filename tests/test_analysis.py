"""HLO analyzer tests: trip-count-aware cost rollup must match analytics
(the naive cost_analysis undercounts while bodies by ~L x)."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis.hlo import collective_stats, parse_shape_bytes
from repro.analysis.hlo_program import HloProgram, analyze_hlo

_SRC = str(Path(__file__).parent.parent / "src")


def test_parse_shape_bytes():
    assert parse_shape_bytes("bf16[256,1024]{1,0}") == 256 * 1024 * 2
    assert parse_shape_bytes("f32[]") == 4
    assert parse_shape_bytes("(s32[], f32[8,8]{1,0})") == 4 + 256
    assert parse_shape_bytes("pred[16]") == 16


_TOY_HLO = """\
HloModule toy

%body (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,128]{1,0} get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %y = f32[128,128]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,128]{1,0} all-reduce(%y), replica_groups={}, to_apply=%body
  ROOT %t = (s32[], f32[128,128]{1,0}) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[128,128])) -> pred[] {
  %p = (s32[], f32[128,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[128,128]) -> f32[128,128] {
  %a = f32[128,128]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[128,128]{1,0}) tuple(%z, %a)
  %w = (s32[], f32[128,128]{1,0}) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[128,128]{1,0} get-tuple-element(%w), index=1
}
"""


def test_while_trip_count_multiplies_costs():
    cost = analyze_hlo(_TOY_HLO)
    # 10 iterations x one 128^3 dot
    assert cost.dot_flops == 10 * 2 * 128 ** 3
    # 10 iterations x one all-reduce of 64 KiB
    assert cost.collective_bytes == 10 * 128 * 128 * 4
    assert cost.collective_by_kind == {"all-reduce": 10 * 128 * 128 * 4}


def test_trip_count_from_backend_config():
    hlo = _TOY_HLO.replace(
        "condition=%cond, body=%body",
        'condition=%cond, body=%body, backend_config='
        '{"known_trip_count":{"n":"7"}}')
    cost = analyze_hlo(hlo)
    assert cost.dot_flops == 7 * 2 * 128 ** 3


def test_collective_stats_plain():
    st = collective_stats(_TOY_HLO)
    # naive (no trip counting) sees the all-reduce once
    assert st.bytes_by_kind["all-reduce"] == 128 * 128 * 4


def test_analyzer_matches_analytic_on_real_program():
    """Compile a scanned matmul stack under SPMD and compare against
    hand-computed flops (runs in a subprocess for the 8-device mesh)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, %r)
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.analysis.hlo_program import analyze_hlo
L, B, S, d = 8, 4, 64, 128
def f(params, x):
    def body(x, w):
        return jnp.tanh(x @ w), None
    y, _ = jax.lax.scan(body, x, params)
    return y.sum()
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("data", "model"))
params = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
x = jax.ShapeDtypeStruct((B, S, d), jnp.float32)
with mesh:
    compiled = jax.jit(jax.grad(f), in_shardings=(
        NamedSharding(mesh, P(None, None, "model")),
        NamedSharding(mesh, P("data", None, None)))).lower(params, x).compile()
cost = analyze_hlo(compiled.as_text())
analytic = L * (2*B*S*d*d) * 3 / 8
ratio = cost.dot_flops / analytic
assert 0.8 < ratio < 1.5, f"dot flops off: {ratio}"
print(f"RATIO {ratio:.3f}")
""" % _SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600,
                         env={**os.environ, "PYTHONPATH": _SRC})
    assert out.returncode == 0, out.stderr
    assert "RATIO" in out.stdout


def test_dus_aliasing_not_quadratic():
    """dynamic-update-slice into a big scan-carried buffer must charge the
    slice, not the whole buffer, per iteration."""
    hlo = """\
HloModule dus

%body (p: (s32[], f32[100,128,128])) -> (s32[], f32[100,128,128]) {
  %p = (s32[], f32[100,128,128]{2,1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %buf = f32[100,128,128]{2,1,0} get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %zero = s32[] constant(0)
  %slice = f32[1,128,128]{2,1,0} broadcast(%one), dimensions={}
  %up = f32[100,128,128]{2,1,0} dynamic-update-slice(%buf, %slice, %i, %zero, %zero)
  ROOT %t = (s32[], f32[100,128,128]{2,1,0}) tuple(%ni, %up)
}

%cond (p: (s32[], f32[100,128,128])) -> pred[] {
  %p = (s32[], f32[100,128,128]{2,1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(100)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[100,128,128]) -> f32[100,128,128] {
  %a = f32[100,128,128]{2,1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[100,128,128]{2,1,0}) tuple(%z, %a)
  %w = (s32[], f32[100,128,128]{2,1,0}) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[100,128,128]{2,1,0} get-tuple-element(%w), index=1
}
"""
    cost = analyze_hlo(hlo)
    buf_bytes = 100 * 128 * 128 * 4
    # quadratic charging would be >= 100 * buf_bytes; slice-aware must be
    # far below (100 iterations x ~2 slices + broadcast)
    assert cost.bytes < 10 * buf_bytes, cost.bytes

"""Two-tier KV hierarchy + prefix sharing tests.

The PR-5 acceptance surface: every cold page — preempted, watermark-
evicted, finished or prefix-shared — lives in ONE
:class:`~repro.core.offload.FarMemoryTier` behind the pager, and the
engine stays token-exact with the dense reference across arbitrary
interleavings of evict / park / finish / resume / prefix-hit, including
AMU faults mid-resume and prefix hits taken while the shared pages are
still ARRIVING.
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke
from repro.core.amu import AMU, AMUError, SimBackend
from repro.core.offload import FarMemoryTier
from repro.models import init_params
from repro.paging import (PREFIX_SEQ, PagePool, PageState, PageTable, Pager,
                          PagingError, PrefixCache, WatermarkPolicy,
                          page_hashes, pages_for)
from repro.serve.config import (ChunkingConfig, EngineConfig, PagingConfig,
                                SpeculationConfig)
from repro.serve.engine import Engine


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("phi4-mini-3.8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params, {}


def _slow_pager_factory(base_latency):
    def factory(pool, table, *, page_nbytes):
        amu = AMU(backend=SimBackend(base_latency=base_latency,
                                     bandwidth=10e9),
                  max_outstanding=64)
        return Pager(pool, table, amu, page_nbytes=page_nbytes)
    return factory


def _flaky_pager_factory(base_latency, fail):
    """Pager whose SimBackend faults at issue while ``fail['on']``."""
    def latency_fn(req):
        if fail["on"]:
            raise RuntimeError("injected far-memory fault")
        return base_latency

    def factory(pool, table, *, page_nbytes):
        amu = AMU(backend=SimBackend(base_latency=base_latency,
                                     bandwidth=10e9, latency_fn=latency_fn),
                  max_outstanding=64)
        return Pager(pool, table, amu, page_nbytes=page_nbytes)
    return factory


def _dense_reference(cfg, params, cache, requests):
    key = tuple((tuple(int(t) for t in p), n) for p, n in requests)
    if key not in cache:
        eng = Engine(cfg, params, EngineConfig(
            max_batch=3, max_len=64, prefill_buckets=(32,),
            paging=PagingConfig(enabled=False)))
        for prompt, new in requests:
            eng.submit(prompt, max_new_tokens=new)
        cache[key] = eng.run()
    return cache[key]


# ---------------------------------------------------------------------------
# FarMemoryTier: single backend, fault-safe fetch
# ---------------------------------------------------------------------------

def test_far_tier_get_survives_fault_and_retries():
    """A failed aload must not lose the home copy: get raises, the entry
    stays fetchable, and a retry after the fault clears succeeds (the
    old sequence-granularity offload lost the tree irrecoverably)."""
    fail = {"on": True}

    def latency_fn(req):
        if fail["on"]:
            raise RuntimeError("injected fault")
        return 1e-6

    amu = AMU(backend=SimBackend(base_latency=1e-6, bandwidth=10e9,
                                 latency_fn=latency_fn))
    tier = FarMemoryTier(amu)
    payload = np.arange(7)
    tier.put("page", payload, nbytes=payload.nbytes)
    with pytest.raises(AMUError):
        tier.get("page")
    assert "page" in tier                   # home copy survived the fault
    fail["on"] = False
    np.testing.assert_array_equal(tier.get("page"), payload)


def test_engine_single_far_tier_backend(setup):
    """The pager's parked pages and finished-sequence KV share ONE
    FarMemoryTier (the KVOffloadTier duplicate storage path is gone)."""
    cfg, params, _ = setup
    eng = Engine(cfg, params, EngineConfig(
        max_batch=2, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(page_size=8, device_pages=5,
                            offload_finished=True)))
    assert eng.far_tier is eng.pager.tier
    assert eng.far_tier.amu is eng.pager.amu
    rid = eng.submit(np.arange(7) % cfg.vocab_size, max_new_tokens=4)
    eng.submit(np.arange(9) % cfg.vocab_size, max_new_tokens=4)
    eng.run()
    # finished pages and the aux residue live in the one tier
    assert (rid, 0) in eng.far_tier and (rid, "aux") in eng.far_tier
    import repro.serve.kv_cache as kvc
    assert not hasattr(kvc, "KVOffloadTier")


def test_fetch_finished_fault_keeps_entries(setup):
    """The old KVOffloadTier.fetch popped its bookkeeping before the
    transfers were verified — a fault lost the KV forever.  The far-tier
    path must raise on the fault, keep every entry, and succeed on
    retry."""
    cfg, params, _ = setup
    fail = {"on": False}
    eng = Engine(cfg, params, EngineConfig(
        max_batch=1, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(page_size=8, offload_finished=True,
                            pager_factory=_flaky_pager_factory(1e-6,
                                                               fail))))
    rid = eng.submit(np.arange(12) % cfg.vocab_size, max_new_tokens=4)
    eng.run()
    fail["on"] = True
    with pytest.raises(AMUError):
        eng.fetch_finished(rid)
    assert (rid, "aux") in eng.far_tier     # nothing was discarded
    assert (rid, 0) in eng.far_tier
    fail["on"] = False
    tree = eng.fetch_finished(rid)          # retry reassembles
    assert np.asarray(tree.kv["k"]).shape[2] == eng.slot_tokens
    assert (rid, "aux") not in eng.far_tier


# ---------------------------------------------------------------------------
# watermark-driven eviction loop (capacity pressure without preemption)
# ---------------------------------------------------------------------------

def test_watermark_eviction_loop_frees_frames(setup):
    """With a low watermark set, cold RESIDENT frames (parked hot tails,
    idle prefix-cache frames) are pushed to the far tier proactively —
    the pager's balance() loop — instead of only on preemption."""
    cfg, params, _ = setup
    pre = np.arange(8) % cfg.vocab_size
    prompts = [np.concatenate([pre, (np.arange(4) + 3 * i) % cfg.vocab_size])
               for i in range(4)]
    eng = Engine(cfg, params, EngineConfig(
        max_batch=2, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(page_size=4, device_pages=8,
                            watermark=WatermarkPolicy(low=2)),
        chunking=ChunkingConfig(chunk_tokens=4, prefix_cache=True)))
    for p in prompts:
        eng.submit(p, max_new_tokens=5)
    out = eng.run()
    assert len(out) == 4
    assert eng.pager.stats.get("watermark_evictions", 0) > 0
    # evicted cache pages were clean (far home written at intern time)
    assert eng.pager.stats["clean_evict"] > 0


# ---------------------------------------------------------------------------
# prefix sharing: unit level
# ---------------------------------------------------------------------------

def test_page_hashes_roll_over_prefix():
    a = page_hashes(np.arange(16, dtype=np.int32), 4)
    b = page_hashes(np.arange(20, dtype=np.int32), 4)
    assert len(a) == 4 and b[:4] == a
    c = page_hashes(np.concatenate([[9], np.arange(1, 16)]).astype(np.int32), 4)
    assert c[0] != a[0] and c[1] != a[1]    # chained: one token flips all


def test_prefix_cache_caps_hits_before_last_token():
    """A full-prompt hit must leave at least the final token to compute
    (the first sampled token needs logits at plen - 1)."""
    pool = PagePool(8, 4)
    table = PageTable(pool)
    pager = Pager(pool, table, page_nbytes=1 << 10)
    cache = PrefixCache(pool, table, pager, page_size=4)
    prompt = np.arange(8, dtype=np.int32)
    table.register("donor")
    table.ensure_capacity("donor", 8)
    cache.intern(prompt, "donor", lambda phys: {"k": None, "v": None})
    assert cache.stats["interned"] == 2
    # same 8-token prompt: only page 0 is usable (page 1 holds token 7)
    assert len(cache.match(prompt)) == 1
    # longer prompt with the same prefix: both pages usable
    assert len(cache.match(np.arange(12, dtype=np.int32))) == 2
    # different first token: no hits (rolling hash covers the prefix)
    other = np.concatenate([[5], np.arange(1, 12)]).astype(np.int32)
    assert cache.match(other) == []


def test_cow_break_remaps_shared_frame():
    """remap_private gives a writer a private frame and keeps the other
    users of a COW frame intact."""
    pool = PagePool(8, 4)
    table = PageTable(pool)
    table.register("a")
    table.register("b")
    phys = table.ensure_capacity("a", 4) and table.entry("a", 0).phys
    table.append_shared("b", phys)
    pool.mark_cow(phys)
    table.pin_page("b", 0)
    assert pool.frames[phys].refs == 2
    old, new = table.remap_private("b", 0)
    assert old == phys and new != phys
    assert pool.frames[phys].refs == 1      # a keeps the original
    assert pool.frames[new].refs == 1 and pool.frames[new].pins == 1
    assert table.entry("b", 0).phys == new
    assert table.entry("a", 0).phys == phys
    # sole-owned frames are a no-op
    assert table.remap_private("a", 0) == (phys, phys)


# ---------------------------------------------------------------------------
# prefix sharing: engine end-to-end
# ---------------------------------------------------------------------------

def test_prefix_hits_skip_chunks_and_match_dense(setup):
    """Requests sharing a system prompt skip its chunks (device hits)
    yet generate exactly the dense engine's tokens."""
    cfg, params, ref_cache = setup
    pre = np.arange(12) % cfg.vocab_size
    requests = [(np.concatenate([pre, (np.arange(4) + 7 * i)
                                 % cfg.vocab_size]), 5) for i in range(6)]
    ref = _dense_reference(cfg, params, ref_cache, requests)

    eng = Engine(cfg, params, EngineConfig(
        max_batch=2, max_len=64, prefill_buckets=(32,),
        paging=PagingConfig(page_size=4),
        chunking=ChunkingConfig(chunk_tokens=4, prefix_cache=True)))
    for p, n in requests:
        eng.submit(p, max_new_tokens=n)
    out = eng.run()
    assert out == ref
    assert eng.stats["prefix_hits"] > 0
    assert eng.stats["prefix_tokens_saved"] >= 12   # >= one full prefix
    assert eng.prefix.stats["interned"] > 0


def test_prefix_far_hit_while_arriving_matches_dense(setup):
    """Prefix hits under a pool too small to keep the cache resident:
    the shared pages are fetched from the far tier with multi-tick
    latency, so later hits land while pages are still ARRIVING — the
    resume-while-ARRIVING path applied to admission."""
    cfg, params, ref_cache = setup
    pre = np.arange(12) % cfg.vocab_size
    requests = [(np.concatenate([pre, (np.arange(4) + 7 * i)
                                 % cfg.vocab_size]), 5) for i in range(6)]
    ref = _dense_reference(cfg, params, ref_cache, requests)

    eng = Engine(cfg, params, EngineConfig(
        max_batch=2, max_len=64, prefill_buckets=(32,),
        paging=PagingConfig(page_size=4, device_pages=9, hot_tail_pages=0,
                            pager_factory=_slow_pager_factory(2.5e-3)),
        chunking=ChunkingConfig(chunk_tokens=4, prefix_cache=True)))
    for p, n in requests:
        eng.submit(p, max_new_tokens=n)
    out = eng.run()
    assert out == ref
    assert eng.stats["prefix_far_hits"] > 0         # far tier served hits
    assert eng.pager.stats["arrived"] > 0           # via LATENCY aloads


def test_prefix_far_hit_fault_mid_admission_recovers(setup):
    """An AMU fault while a prefix far-hit's pages are being fetched
    must not lose the request: the pager reverts ARRIVING → PARKED,
    the retry refetches, and tokens still match dense."""
    cfg, params, ref_cache = setup
    pre = np.arange(12) % cfg.vocab_size
    requests = [(np.concatenate([pre, (np.arange(4) + 7 * i)
                                 % cfg.vocab_size]), 5) for i in range(4)]
    ref = _dense_reference(cfg, params, ref_cache, requests)

    fail = {"on": False}
    eng = Engine(cfg, params, EngineConfig(
        max_batch=1, max_len=64, prefill_buckets=(32,),
        paging=PagingConfig(page_size=4, device_pages=7, hot_tail_pages=0,
                            pager_factory=_flaky_pager_factory(1e-4,
                                                               fail)),
        chunking=ChunkingConfig(chunk_tokens=4, prefix_cache=True)))
    rids = [eng.submit(p, max_new_tokens=n) for p, n in requests]
    # run a few steps, then fault the link for a stretch of the run
    eng.run(max_steps=4)
    fail["on"] = True
    eng.run(max_steps=6)
    fail["on"] = False
    out = eng.run()
    assert out == ref
    assert eng.stats["prefix_hits"] > 0


# ---------------------------------------------------------------------------
# the property: random interleavings stay token-exact
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**16),
       page_size=st.sampled_from([4, 8]),
       spare_pages=st.integers(0, 4),
       hot_tail=st.integers(0, 1),
       low=st.integers(0, 2),
       latency=st.floats(1e-5, 3e-3),
       shared_prefix=st.integers(0, 12),
       speculate_k=st.sampled_from([0, 2]))
def test_property_two_tier_engine_matches_dense(setup, seed, page_size,
                                                spare_pages, hot_tail, low,
                                                latency, shared_prefix,
                                                speculate_k):
    """Random evict/park/finish/resume/prefix-hit interleavings: tight
    pools force preemption + watermark eviction, slow pagers stretch
    ARRIVING windows across steps, shared prefixes mix device and far
    hits — output must equal the dense engine token-for-token.  The
    ``speculate_k`` axis reruns the same churn with the verify-K path
    live: rewinds and draft-aware growth must not disturb exactness."""
    cfg, params, ref_cache = setup
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, cfg.vocab_size, shared_prefix).astype(np.int32)
    n_req = int(rng.integers(3, 6))
    requests = []
    for _ in range(n_req):
        tail = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(1, 13))).astype(np.int32)
        prompt = np.concatenate([pre, tail]) if rng.random() < 0.6 else tail
        requests.append((prompt[:28], int(rng.integers(2, 11))))

    ref = _dense_reference(cfg, params, ref_cache, requests)

    need = max(pages_for(min(len(p) + n, 64), page_size)
               for p, n in requests)
    eng = Engine(cfg, params, EngineConfig(
        max_batch=3, max_len=64, prefill_buckets=(32,),
        paging=PagingConfig(
            page_size=page_size, device_pages=need + spare_pages + low,
            hot_tail_pages=hot_tail, watermark=WatermarkPolicy(low=low),
            pager_factory=_slow_pager_factory(latency)),
        chunking=ChunkingConfig(chunk_tokens=4, prefix_cache=True),
        speculation=SpeculationConfig(speculate_k=speculate_k)))
    for prompt, new in requests:
        eng.submit(prompt, max_new_tokens=new)
    out = eng.run()

    assert out == ref
    eng.check_invariants()
    assert eng.stats["resumes"] == eng.stats["preemptions"]
    if speculate_k:
        assert (eng.stats["accepted"] + eng.stats["rejected"]
                == eng.stats["drafted"])
    # page accounting: only the prefix cache may retain frames
    cache_pages = len(eng.page_table.logical_pages(
        PREFIX_SEQ, PageState.RESIDENT))
    assert eng.page_pool.n_used == cache_pages


# ---------------------------------------------------------------------------
# speculation x far tier: faults and preemption against the verify-K path
# ---------------------------------------------------------------------------

def test_spec_fault_mid_run_recovers(setup):
    """An AMU fault while slots carry speculated (drafted-but-not-yet-
    verified) state: the faulted stretch stalls resumes/growth, drafts
    shed or replay, and the stream still matches dense exactly."""
    from tests.test_spec_decode import _proposer_factory

    cfg, params, ref_cache = setup
    requests = [((np.arange(10) + 5 * i) % cfg.vocab_size, 8)
                for i in range(4)]
    requests = [(p.astype(np.int32), n) for p, n in requests]
    ref = _dense_reference(cfg, params, ref_cache, requests)

    fail = {"on": False}
    need = max(pages_for(min(len(p) + n, 64), 4) for p, n in requests)
    eng = Engine(cfg, params, EngineConfig(
        max_batch=2, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(page_size=4, device_pages=need + 1,
                            pager_factory=_flaky_pager_factory(1e-4, fail)),
        speculation=SpeculationConfig(
            speculate_k=3,
            proposer_factory=_proposer_factory("oracle", ref, requests,
                                               cfg.vocab_size))))
    for p, n in requests:
        eng.submit(p, max_new_tokens=n)
    eng.run(max_steps=3)
    fail["on"] = True
    try:
        eng.run(max_steps=5)
    except PagingError:
        pass          # demand fetch surfaced the fault before any append
    fail["on"] = False
    out = eng.run()
    assert out == ref
    eng.check_invariants()
    assert eng.stats["drafted"] > 0
    assert eng.page_pool.n_free == eng.page_pool.n_pages


def test_spec_preempt_mid_verify_sheds_drafts(setup):
    """A pool too tight for every slot's full draft window: draft-aware
    growth preempts victims or sheds draft positions mid-step, and the
    rewind on rejection must still leave page accounting clean."""
    from tests.test_spec_decode import _proposer_factory

    cfg, params, ref_cache = setup
    requests = [((np.arange(12) + 3 * i) % cfg.vocab_size, 10)
                for i in range(4)]
    requests = [(p.astype(np.int32), n) for p, n in requests]
    ref = _dense_reference(cfg, params, ref_cache, requests)

    need = max(pages_for(min(len(p) + n, 64), 4) for p, n in requests)
    eng = Engine(cfg, params, EngineConfig(
        max_batch=3, max_len=64, prefill_buckets=(16,),
        paging=PagingConfig(page_size=4, device_pages=need,
                            pager_factory=_slow_pager_factory(1e-5)),
        speculation=SpeculationConfig(
            speculate_k=4,
            proposer_factory=_proposer_factory("wrong", ref, requests,
                                               cfg.vocab_size))))
    for p, n in requests:
        eng.submit(p, max_new_tokens=n)
    out = eng.run()
    assert out == ref
    eng.check_invariants()
    assert eng.stats["preemptions"] > 0
    assert eng.stats["drafted"] > 0
    assert eng.page_pool.n_free == eng.page_pool.n_pages

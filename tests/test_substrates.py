"""Substrate tests: checkpoint, optimizer, data pipeline, fault tolerance,
serving KV management."""

import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.checkpoint import (all_steps, latest_step, prune,
                                         restore, save, wait_pending)
from repro.configs import get_smoke
from repro.configs.base import ShapeConfig, TrainConfig
from repro.data.pipeline import PrefetchingLoader, SyntheticLM, make_loader
from repro.models.model import init_cache, init_params
from repro.optim.adamw import OptState, adamw_init, adamw_update, global_norm
from repro.runtime.fault_tolerance import (Heartbeat, StragglerDetector,
                                           elastic_plan, run_with_retries)
from repro.serve.kv_cache import SlotPool, extract_slot, insert_slot


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(12, dtype=jnp.int32).reshape(3, 4),
                   "c": jnp.float32(3.5)},
        "opt": OptState(m={"a": jnp.ones((8, 16))},
                        v={"a": jnp.zeros((8, 16))},
                        step=jnp.int32(7)),
    }


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save(tmp_path, 10, tree, metadata={"step": 10, "note": "x"})
    assert latest_step(tmp_path) == 10
    got, meta = restore(tmp_path, target=tree)
    assert meta["note"] == "x"
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_and_prune(tmp_path):
    tree = _tree()
    for s in (1, 2, 3, 4):
        save(tmp_path, s, tree, metadata={"step": s})
    # stale tmp dir from a "crashed" writer must not confuse restore
    (tmp_path / "step_00000099.tmp").mkdir()
    assert latest_step(tmp_path) == 4
    prune(tmp_path, keep=2)
    assert all_steps(tmp_path) == [3, 4]
    got, meta = restore(tmp_path, target=tree)
    assert meta["step"] == 4


def test_checkpoint_async(tmp_path):
    tree = _tree()
    save(tmp_path, 5, tree, metadata={"step": 5}, async_=True)
    wait_pending()
    assert latest_step(tmp_path) == 5


@settings(max_examples=10, deadline=None)
@given(shapes=st.lists(
    st.tuples(st.integers(1, 5), st.integers(1, 5)), min_size=1, max_size=5))
def test_property_checkpoint_roundtrip_arbitrary_trees(tmp_path_factory,
                                                       shapes):
    tmp = tmp_path_factory.mktemp("ckpt")
    rng = np.random.default_rng(0)
    tree = {f"x{i}": rng.standard_normal(s).astype(np.float32)
            for i, s in enumerate(shapes)}
    save(tmp, 1, tree)
    got, _ = restore(tmp, target=tree)
    for k in tree:
        np.testing.assert_array_equal(tree[k], np.asarray(got[k]))


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    tcfg = TrainConfig(lr=0.1, warmup_steps=10, total_steps=300,
                       weight_decay=0.0, grad_clip=0.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    for _ in range(300):
        grads = {"w": 2 * (params["w"] - target)}
        params, opt, _ = adamw_update(grads, opt, params, tcfg)
    assert float(jnp.abs(params["w"] - target).max()) < 1e-2


def test_adamw_grad_clip_and_metrics():
    tcfg = TrainConfig(lr=1e-3, grad_clip=1.0)
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    grads = {"w": jnp.full(4, 100.0)}
    new, opt, metrics = adamw_update(grads, opt, params, tcfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)
    assert int(opt.step) == 1
    # effective update magnitude bounded by lr after clipping
    assert float(jnp.abs(new["w"]).max()) < 2 * 1e-3 * 10


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_deterministic_and_learnable():
    a = SyntheticLM(101, 16, 4, seed=3)
    b = SyntheticLM(101, 16, 4, seed=3)
    ba, bb = next(a), next(b)
    np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
    # labels are the next-token shift of the same recurrence
    np.testing.assert_array_equal(ba["labels"][:, :-1], ba["tokens"][:, 1:])


def test_synthetic_resume_continues_stream():
    full = SyntheticLM(101, 8, 2, seed=5)
    b0, b1 = next(full), next(full)
    resumed = SyntheticLM(101, 8, 2, seed=5, start_step=1)
    r1 = next(resumed)
    # same task pool; the stream differs from step 0's batch
    assert not np.array_equal(b0["tokens"], r1["tokens"])


def test_prefetching_loader_async_depth():
    it = iter([{"x": np.full(64, i, np.float32)} for i in range(5)])
    loader = PrefetchingLoader(it, depth=3)
    got = [np.asarray(b["x"])[0] for b in loader]
    assert got == [0.0, 1.0, 2.0, 3.0, 4.0]
    assert loader.amu.stats["aload"] == 5


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_heartbeat_stall_detection():
    t = [0.0]
    hb = Heartbeat(timeout_s=10.0, clock=lambda: t[0])
    hb.beat()
    t[0] = 5.0
    assert not hb.stalled()
    t[0] = 16.0
    assert hb.stalled()


def test_straggler_detector():
    det = StragglerDetector(threshold=2.0, min_samples=5)
    for _ in range(10):
        det.record(1.0)
    rep = det.record(3.0)
    assert rep is not None and rep.ratio == pytest.approx(3.0)
    assert det.record(1.1) is None
    assert 0 < det.straggler_fraction < 0.2


def test_run_with_retries_restores():
    calls = {"n": 0, "restores": 0}

    def step(state):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("chip fell over")
        return state + 1

    def restore_fn():
        calls["restores"] += 1
        return 100

    out = run_with_retries(step, 0, restore_fn=restore_fn, max_retries=5)
    assert out == 101 and calls["restores"] == 2


def test_run_with_retries_exhausts():
    def step(state):
        raise RuntimeError("persistent")
    with pytest.raises(RuntimeError):
        run_with_retries(step, 0, restore_fn=lambda: 0, max_retries=2)


def test_elastic_plan_shrinks_data_axis():
    plan = elastic_plan((2, 16, 16), ("pod", "data", "model"), 400)
    assert plan.new_shape == (25, 16)
    assert plan.axes == ("data", "model")
    assert plan.lost_devices == 112
    plan2 = elastic_plan((16, 16), ("data", "model"), 255)
    assert plan2.new_shape == (15, 16)
    assert "spare" in plan2.note
    with pytest.raises(ValueError):
        elastic_plan((16, 16), ("data", "model"), 8)


# ---------------------------------------------------------------------------
# serving KV management
# ---------------------------------------------------------------------------

def test_slot_pool():
    pool = SlotPool(3)
    s = [pool.alloc() for _ in range(3)]
    assert s == [0, 1, 2] and pool.alloc() is None
    pool.release(1)
    assert pool.alloc() == 1


def test_extract_insert_slot_roundtrip():
    cfg = get_smoke("phi4-mini-3.8b")
    cache = init_cache(cfg, 4, 32)
    cache = cache._replace(pos=jnp.asarray([5, 6, 7, 8], jnp.int32))
    single = extract_slot(cache, 2, 4)
    assert single.kv["k"].shape[1] == 1
    fresh = init_cache(cfg, 4, 32)
    merged = insert_slot(fresh, single, 0, 4)
    assert int(merged.pos[0]) == 7 and int(merged.pos[1]) == 0

"""Worker script for distributed tests — run via subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main test
process keeps its single-device view.

Each mode exercises one distributed behaviour on a real (2,4) or (2,2,2)
host-device mesh and prints machine-checkable lines.
"""

import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402


def make_mesh(shape, axes):
    from repro.launch.mesh import make_mesh_compat
    return make_mesh_compat(shape, axes)


def mode_train_step():
    """Tiny model, real sharded train step on (data=2, model=4)."""
    from repro.configs import get_smoke
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.dist.steps import make_train_step
    from repro.models.model import init_params
    from repro.optim.adamw import adamw_init

    cfg = get_smoke("olmoe-1b-7b")          # MoE: exercises EP sharding
    shape = ShapeConfig("t", 32, 4, "train")
    tcfg = TrainConfig(microbatches=2, grad_compression="bf16", zero1=True)
    mesh = make_mesh((2, 4), ("data", "model"))
    with mesh:
        fn, specs = make_train_step(cfg, tcfg, mesh, shape)
        params = jax.jit(lambda k: init_params(cfg, k),
                         out_shardings=jax.tree_util.tree_map(
                             lambda s: NamedSharding(mesh, s),
                             specs["params"]))(jax.random.PRNGKey(0))
        opt = jax.jit(adamw_init, out_shardings=jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), specs["opt"]))(params)
        batch = {
            "tokens": jnp.zeros((4, 32), jnp.int32),
            "labels": jnp.ones((4, 32), jnp.int32),
        }
        batch = {k: jax.device_put(v, NamedSharding(mesh, specs["batch"][k]))
                 for k, v in batch.items()}
        p2, o2, metrics = fn(params, opt, batch)
        loss1 = float(metrics["loss"])
        p3, o3, metrics = fn(p2, o2, batch)
        loss2 = float(metrics["loss"])
    # some leaf must actually be sharded over model
    sharded = any(
        "model" in str(leaf.sharding.spec)
        for leaf in jax.tree_util.tree_leaves(p3)
        if hasattr(leaf, "sharding"))
    print(f"RESULT train loss1={loss1:.4f} loss2={loss2:.4f} "
          f"finite={np.isfinite(loss1) and np.isfinite(loss2)} "
          f"improved={loss2 < loss1} sharded={sharded}")


def mode_serve_step():
    from repro.configs import get_smoke
    from repro.configs.base import ShapeConfig
    from repro.dist.steps import make_serve_step, make_prefill_step
    from repro.models.model import init_params

    cfg = get_smoke("mistral-nemo-12b")
    shape = ShapeConfig("d", 64, 4, "decode")
    mesh = make_mesh((2, 4), ("data", "model"))
    with mesh:
        pf, pspecs = make_prefill_step(cfg, mesh,
                                       ShapeConfig("p", 64, 4, "prefill"))
        params = jax.jit(lambda k: init_params(cfg, k),
                         out_shardings=jax.tree_util.tree_map(
                             lambda s: NamedSharding(mesh, s),
                             pspecs["params"]))(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.zeros((4, 64), jnp.int32),
                 "labels": jnp.zeros((4, 64), jnp.int32)}
        logits, cache = pf(params, batch)
        fn, _ = make_serve_step(cfg, mesh, shape)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        l2, cache = fn(params, cache, tok)
        l3, cache = fn(params, cache,
                       jnp.argmax(l2, -1)[:, None].astype(jnp.int32))
    print(f"RESULT serve finite={bool(jnp.isfinite(l3).all())} "
          f"pos={int(cache.pos[0])} shape={l3.shape[0]}x{l3.shape[1]}")


def mode_prefill_equality():
    """Full-sequence prefill on a (2, 4) mesh must match single-device
    prefill — the regression guard for the jax-0.4.37 SPMD rope
    miscompile on the prefill/train path (attention._pin_qkv_for_rope):
    without the explicit layout pin, layer-0 k comes back scaled by
    exactly the data-axis size (2x) on this mesh shape."""
    from repro.configs import get_smoke
    from repro.configs.base import ShapeConfig
    from repro.dist.steps import make_prefill_step
    from repro.models.model import init_params, prefill

    cfg = get_smoke("phi4-mini-3.8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = np.arange(16, dtype=np.int32)[None, :] % cfg.vocab_size
    batch = {"tokens": jnp.asarray(np.repeat(toks, 2, axis=0))}
    logits_ref, cache_ref = jax.jit(
        lambda p, b: prefill(p, cfg, b, max_len=32))(params, batch)
    kref = np.asarray(cache_ref.kv["k"])

    mesh = make_mesh((2, 4), ("data", "model"))
    pf, _ = make_prefill_step(cfg, mesh, ShapeConfig("p", 16, 2, "prefill"),
                              max_len=32)
    logits, cache = pf(params, batch)
    k = np.asarray(cache.kv["k"])
    # the miscompile scales k by the data-axis size; bf16 layer compute
    # leaves only rounding-level differences when correct
    ratio = float(np.abs(k).sum() / np.abs(kref).sum())
    logits_ok = bool(np.allclose(np.asarray(logits), np.asarray(logits_ref),
                                 atol=2e-2))
    k_ok = bool(np.abs(k - kref).max() < 0.1)
    print(f"RESULT prefill_eq ratio={ratio:.3f} logits_ok={logits_ok} "
          f"k_ok={k_ok}")


def mode_engine():
    """Serving engine with its decode step mesh-sharded over (2, 4):
    the Engine builds its step via dist.steps.make_serve_step, so params
    placed TP-sharded must stay sharded across decode steps."""
    from repro.configs import get_smoke
    from repro.dist.sharding import param_specs
    from repro.dist.steps import abstract_params
    from repro.models.model import init_params
    from repro.serve.config import EngineConfig, PagingConfig
    from repro.serve.engine import Engine

    cfg = get_smoke("mistral-nemo-12b")
    mesh = make_mesh((2, 4), ("data", "model"))
    pspecs = param_specs(mesh, abstract_params(cfg))
    with mesh:
        params = jax.jit(lambda k: init_params(cfg, k),
                         out_shardings=jax.tree_util.tree_map(
                             lambda s: NamedSharding(mesh, s),
                             pspecs))(jax.random.PRNGKey(0))
        eng = Engine(cfg, params, EngineConfig(
            max_batch=3, max_len=64, mesh=mesh, prefill_buckets=(16,),
            paging=PagingConfig(page_size=8, device_pages=9)))
        for i in range(5):
            eng.submit(np.arange(4 + i) % cfg.vocab_size, max_new_tokens=6)
        out = eng.run()
    sharded = any("model" in str(leaf.sharding.spec)
                  for leaf in jax.tree_util.tree_leaves(params)
                  if hasattr(leaf, "sharding"))
    lens = sorted(len(v) for v in out.values())
    print(f"RESULT engine done={len(out)} lens={lens} sharded={sharded} "
          f"steps={eng.stats['steps']} shared={eng.stats['steps'] < 5 * 6}")


def mode_elastic():
    """Save on (2,4), restore and step on (1,4): elastic DP shrink."""
    import tempfile
    from repro.checkpoint.checkpoint import restore, save
    from repro.configs import get_smoke
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.dist.steps import make_train_step
    from repro.models.model import init_params
    from repro.optim.adamw import adamw_init
    from repro.runtime.fault_tolerance import elastic_plan

    cfg = get_smoke("phi4-mini-3.8b")
    shape = ShapeConfig("t", 32, 4, "train")
    tcfg = TrainConfig()
    d = tempfile.mkdtemp()

    mesh = make_mesh((2, 4), ("data", "model"))
    with mesh:
        fn, specs = make_train_step(cfg, tcfg, mesh, shape, donate=False)
        shard = lambda t, s: jax.tree_util.tree_map(
            lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)), t, s)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params)
        batch = {"tokens": jnp.zeros((4, 32), jnp.int32),
                 "labels": jnp.ones((4, 32), jnp.int32)}
        p2, o2, m = fn(shard(params, specs["params"]),
                       shard(opt, specs["opt"]), shard(batch, specs["batch"]))
        save(d, 1, (p2, o2), metadata={"step": 1, "loss": float(m["loss"])})

    plan = elastic_plan((2, 4), ("data", "model"), 4)
    mesh2 = make_mesh(plan.new_shape, plan.axes)
    with mesh2:
        fn2, specs2 = make_train_step(cfg, tcfg, mesh2, shape, donate=False)
        (p_r, o_r), meta = restore(d, target=(params, opt))
        p_r = jax.tree_util.tree_map(
            lambda x, sp: jax.device_put(jnp.asarray(x),
                                         NamedSharding(mesh2, sp)),
            p_r, specs2["params"])
        o_r = jax.tree_util.tree_map(
            lambda x, sp: jax.device_put(jnp.asarray(x),
                                         NamedSharding(mesh2, sp)),
            o_r, specs2["opt"])
        batch = {"tokens": jnp.zeros((4, 32), jnp.int32),
                 "labels": jnp.ones((4, 32), jnp.int32)}
        p3, o3, m2 = fn2(p_r, o_r, jax.tree_util.tree_map(
            lambda x: jax.device_put(x, NamedSharding(
                mesh2, P())), batch))
        print(f"RESULT elastic new_shape={plan.new_shape} "
              f"step={int(o3.step)} finite={bool(np.isfinite(float(m2['loss'])))}")


def mode_multipod_specs():
    """Param/opt specs on a (2,2,2) pod mesh: ZeRO over (pod,data)."""
    from repro.configs import get_config
    from repro.dist.sharding import opt_state_specs, param_specs
    from repro.dist.steps import abstract_params

    cfg = get_config("mistral-nemo-12b")
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    pshapes = abstract_params(cfg)
    pspecs = param_specs(mesh, pshapes)
    ospecs = opt_state_specs(mesh, pshapes, zero1=True)
    flat_p = jax.tree_util.tree_leaves(pspecs)
    flat_m = jax.tree_util.tree_leaves(ospecs.m)
    n_model = sum("model" in str(s) for s in flat_p)
    n_zero = sum(("pod" in str(s) or "data" in str(s)) for s in flat_m)
    print(f"RESULT specs model_sharded={n_model} zero_sharded={n_zero} "
          f"total={len(flat_p)}")


if __name__ == "__main__":
    {"train": mode_train_step, "serve": mode_serve_step,
     "engine": mode_engine, "elastic": mode_elastic,
     "specs": mode_multipod_specs,
     "prefill_eq": mode_prefill_equality}[sys.argv[1]]()

"""Property-based tests (hypothesis) on model/system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.core.spm import (SPMPlan, VMEM_BYTES, plan_attention_blocks,
                            plan_matmul_blocks)
from repro.models.attention import chunked_attention
from repro.models.layers import rope
from repro.models.model import chunked_cross_entropy
from repro.models.moe import expert_capacity, moe_block, moe_init


# ---------------------------------------------------------------------------
# RoPE: rotation preserves norms and relative positions
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), S=st.integers(2, 16),
       D=st.sampled_from([8, 16, 32]))
def test_rope_preserves_norm(seed, S, D):
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, S, 2, D))
    pos = jnp.broadcast_to(jnp.arange(S), (1, S))
    y = rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)


def test_rope_relative_shift_invariance():
    """<rope(q,p1), rope(k,p2)> depends only on p1-p2."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    def dot_at(p1, p2):
        qr = rope(q, jnp.asarray([[p1]]))
        kr = rope(k, jnp.asarray([[p2]]))
        return float(jnp.sum(qr * kr))
    assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), rel=1e-4)
    assert dot_at(5, 5) == pytest.approx(dot_at(0, 0), rel=1e-4)


# ---------------------------------------------------------------------------
# chunked attention == naive softmax attention (any chunking)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), chunk=st.sampled_from([7, 16, 33, 128]),
       Sq=st.integers(3, 24), window=st.sampled_from([0, 5]))
def test_property_chunked_attention_chunk_invariant(seed, chunk, Sq, window):
    from repro.kernels.ref import attention_ref
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (2, Sq, 4, 16))
    k = jax.random.normal(ks[1], (2, Sq, 2, 16))
    v = jax.random.normal(ks[2], (2, Sq, 2, 16))
    ref = attention_ref(q, k, v, causal=True, window=window)
    out = chunked_attention(q, k, v, causal=True, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# chunked cross entropy == direct xent
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), S=st.integers(2, 33),
       chunk=st.sampled_from([4, 8, 512]), V=st.sampled_from([32, 130]))
def test_property_chunked_xent_matches_direct(seed, S, chunk, V):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    B, d = 2, 16
    x = jax.random.normal(ks[0], (B, S, d)) * 0.5
    table = jax.random.normal(ks[1], (V, d)) * 0.5
    labels = jax.random.randint(ks[2], (B, S), 0, V)
    got = float(chunked_cross_entropy(x, table, labels, chunk=chunk))
    logits = (x.astype(jnp.bfloat16) @ table.astype(jnp.bfloat16).T
              ).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = float(jnp.mean(lse - gold))
    assert got == pytest.approx(want, rel=1e-4)


def test_chunked_xent_ignores_masked_labels():
    x = jnp.ones((1, 4, 8))
    table = jnp.ones((16, 8))
    all_masked = chunked_cross_entropy(x, table, jnp.full((1, 4), -1))
    assert float(all_masked) == 0.0


# ---------------------------------------------------------------------------
# MoE: dispatch conservation + capacity bounds
# ---------------------------------------------------------------------------

def _moe_cfg(E=8, k=2, cf=8.0):
    return ModelConfig(name="t", family="moe", num_layers=1, d_model=16,
                       num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=64,
                       head_dim=8, num_experts=E, experts_per_token=k,
                       capacity_factor=cf)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_moe_no_drop_equals_dense_mixture(seed):
    """With capacity high enough to keep every pair, MoE output must be
    exactly the gate-weighted mixture of selected experts."""
    cfg = _moe_cfg(E=4, k=2, cf=16.0)
    p = moe_init(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 6, 16))
    out, aux = moe_block(p, cfg, x, compute_dtype=jnp.float32)

    # dense reference: run every expert on every token, combine by gates
    logits = x.astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    gates, ids = jax.lax.top_k(probs, 2)
    gates = gates / gates.sum(-1, keepdims=True)
    g = jnp.einsum("bsd,edf->bsef", x, p["gate"])
    u = jnp.einsum("bsd,edf->bsef", x, p["up"])
    eo = jnp.einsum("bsef,efd->bsed", jax.nn.silu(g) * u, p["down"])
    want = jnp.zeros_like(x)
    for kk in range(2):
        sel = jnp.take_along_axis(eo, ids[..., kk][..., None, None],
                                  axis=2)[:, :, 0]
        want = want + gates[..., kk][..., None] * sel
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_bounded():
    """With cf=1.0 and adversarially skewed routing, outputs stay finite
    and dropped tokens contribute zero (not NaN)."""
    cfg = _moe_cfg(E=4, k=1, cf=0.25)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.ones((1, 16, 16))          # identical tokens -> one expert hot
    out, _ = moe_block(p, cfg, x)
    assert bool(jnp.isfinite(out).all())
    C = expert_capacity(cfg, 16)
    assert C == max(1, int(np.ceil(16 * 1 / 4 * 0.25)))


@settings(max_examples=20, deadline=None)
@given(S=st.integers(1, 64), E=st.sampled_from([4, 8, 64]),
       k=st.integers(1, 4), cf=st.floats(0.1, 4.0))
def test_property_expert_capacity_monotone(S, E, k, cf):
    cfg = _moe_cfg(E=E, k=min(k, E), cf=cf)
    C = expert_capacity(cfg, S)
    assert C >= 1
    assert C >= int(S * min(k, E) / E * cf) - 1


# ---------------------------------------------------------------------------
# SPM planner invariants
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(m=st.integers(8, 8192), k=st.integers(128, 16384),
       n=st.integers(128, 32768), depth=st.sampled_from([2, 3]))
def test_property_matmul_plan_fits_vmem(m, k, n, depth):
    plan = plan_matmul_blocks(m, k, n, pipeline_depth=depth)
    assert plan.vmem_bytes <= VMEM_BYTES
    bm, bk = plan.block_shapes["x"]
    _, bn = plan.block_shapes["w"]
    assert bm % 8 == 0 and bk % 128 == 0 and bn % 128 == 0


@settings(max_examples=30, deadline=None)
@given(q=st.integers(8, 1 << 19), kv=st.integers(128, 1 << 19),
       d=st.sampled_from([64, 80, 128]))
def test_property_attention_plan_fits_vmem(q, kv, d):
    plan = plan_attention_blocks(q, kv, d)
    assert plan.vmem_bytes <= VMEM_BYTES
    assert plan.block_shapes["kv"][0] % 128 == 0

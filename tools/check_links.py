"""Markdown link check: every relative link in the repo's docs resolves.

Scans the given markdown files (default: README.md, ROADMAP.md and
everything under docs/) for inline links/images ``[text](target)`` and
fails if a relative target — optionally with a ``#fragment`` — does not
exist on disk.  External (``http(s)://``, ``mailto:``) and pure-anchor
links are skipped; no third-party dependency needed, so the CI docs job
runs it straight off the checkout.

Usage::

    python tools/check_links.py [file.md ...]
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP = ("http://", "https://", "mailto:", "#")


def check_file(path: Path) -> list:
    errors = []
    text = path.read_text(encoding="utf-8")
    # fenced code blocks are not links
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in LINK.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (path.parent / rel).exists():
            errors.append(f"{path}: broken link -> {target}")
    return errors


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    root = Path(__file__).resolve().parent.parent
    if argv:
        files = [Path(a) for a in argv]
    else:
        files = [root / "README.md", root / "ROADMAP.md"]
        files += sorted((root / "docs").glob("*.md"))
    errors = []
    for f in files:
        if not f.exists():
            errors.append(f"{f}: file missing")
            continue
        errors.extend(check_file(f))
    for e in errors:
        print(f"FAIL: {e}")
    if not errors:
        print(f"OK: {len(files)} markdown files, all relative links resolve")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
